"""Event-driven serverless platform: concurrent tenants, background policy.

Demonstrates the AsyncPlatform API:

  * ``submit`` returns a future; a worker pool serves different tenants
    in parallel (per-instance locks keep each state machine race-free);
  * the background daemon deflates idle tenants (keep-alive ④) without
    any manual ``tick()`` calls;
  * a wake storm — 8 threads hitting one hibernating tenant — shares a
    single streamed inflate;
  * an anticipatory (⑤ SIGCONT) wake runs the streamed pipeline at low
    priority and *absorbs a request mid-stream*: the request demand-pulls
    the chunks it needs while the tail keeps inflating behind it.

Run:  PYTHONPATH=src python examples/async_platform.py
"""
import shutil
import threading
import time

import jax
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.manager import InstanceManager, ManagerConfig
from repro.models import model
from repro.serving import (AsyncPlatform, PlatformPolicy, Request,
                           ServingEngine)

SPOOL = "/tmp/repro_async_platform"
TENANTS = {"chat-app": "arctic-480b", "search-app": "phi4-mini-3.8b",
           "stream-app": "mamba2-130m"}


def main():
    shutil.rmtree(SPOOL, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(ManagerConfig(spool_dir=SPOOL, wake_mode="reap"),
                          factory)
    eng = ServingEngine(mgr)
    policy = PlatformPolicy(keep_warm_s=0.3, tick_interval_s=0.05,
                            max_queue_depth=32)
    rng = np.random.default_rng(0)

    with AsyncPlatform(eng, policy, TENANTS, workers=3) as plat:
        # ---- phase 1: a burst hits every tenant concurrently (cold starts)
        print("== phase 1: concurrent cold-start burst ==")
        futs = [plat.submit(Request(t, f"s{j}",
                                    rng.integers(0, 256, 6).astype(np.int32),
                                    max_new_tokens=4))
                for t in TENANTS for j in range(2)]
        for f in futs:
            r = f.result()
            print(f"  {r.request.instance_id:11s} {r.state_before:9s} -> "
                  f"{r.state_after:6s} ({r.spans['e2e'] * 1e3:.0f} ms)")

        # record working sets so wakes prefetch via REAP
        for t in TENANTS:
            eng.record_sample(t, Request(
                t, "probe", rng.integers(0, 256, 4).astype(np.int32),
                max_new_tokens=2, close_session=True))

        # ---- phase 2: the DAEMON deflates idle tenants (no manual tick)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and \
                any(s != "hibernate" for s in mgr.states().values()):
            time.sleep(0.05)
        print(f"== phase 2: daemon deflated idle tenants: {mgr.states()} ==")

        # ---- phase 3: wake storm on one tenant
        print("== phase 3: 8-thread wake storm on chat-app ==")
        wakes_before = mgr.wakes_performed
        barrier = threading.Barrier(8)
        storm = [None] * 8

        def hit(i):
            barrier.wait()
            storm[i] = plat.submit(Request(
                "chat-app", f"storm{i}",
                rng.integers(0, 256, 3).astype(np.int32), max_new_tokens=2))

        threads = [threading.Thread(target=hit, args=(i,)) for i in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        lats = sorted(f.result().spans["e2e"] for f in storm)
        print(f"  inflates performed: {mgr.wakes_performed - wakes_before} "
              f"(deduped: {mgr.wakes_deduped})")
        print(f"  storm e2e p50={lats[len(lats) // 2] * 1e3:.0f} ms "
              f"max={lats[-1] * 1e3:.0f} ms")

        # ---- phase 4: anticipatory pipelined wake absorbs a request
        print("== phase 4: anticipatory (sigcont) wake, request mid-stream ==")
        inst = mgr.instances["chat-app"]
        # fatten the working set so the stream is observable
        inst.recorder.start()
        inst.recorder.record_many(inst.units)
        inst.recorder.stop()
        wk = None
        deadline = time.monotonic() + 5.0
        while wk is None and time.monotonic() < deadline:
            if mgr.states()["chat-app"] == "hibernate":
                # ⑤ low-priority stream; None while the daemon's deflate
                # (④) is still completing — retry
                wk = mgr.predictive_wake("chat-app")
            if wk is None:
                time.sleep(0.05)
        if wk is None:
            print("  (daemon never hibernated chat-app within the window; "
                  "skipping phase 4)")
        else:
            pipe = inst.wake_pipeline
            active_at_submit = pipe is not None and pipe.active
            fut = plat.submit(Request(
                "chat-app", "mid-stream",
                rng.integers(0, 256, 3).astype(np.int32), max_new_tokens=2))
            r = fut.result()
            if pipe is not None:
                pipe.wait(30)
            print(f"  wake critical path: "
                  f"{wk.critical_path_seconds * 1e3:.1f} ms"
                  f" over {len(pipe.chunks) if pipe else 0} chunks"
                  f" (io {wk.io_seconds * 1e3:.1f} ms,"
                  f" inflate {wk.inflate_seconds * 1e3:.1f} ms)")
            print(f"  request absorbed mid-stream={active_at_submit}: "
                  f"{r.state_before} -> {r.state_after} "
                  f"({r.spans['e2e'] * 1e3:.0f} ms, {r.faults} demand faults)")

    print("== summary ==")
    print(f"  states: {mgr.states()}")
    print(f"  log events: {sorted({e[1] for e in plat.log})}")


if __name__ == "__main__":
    main()

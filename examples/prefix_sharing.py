"""Beyond-paper demo: COW KV prefix sharing on the refcounted allocator.

The paper refcounts pages for process clone/COW (§3.3).  The LLM analogue:
N sessions sharing a long system prompt hold ONE physical copy of its KV
pages.  This example measures pool usage and per-session PSS with and
without forking, and shows hibernation handles shared pages correctly.

Run:  PYTHONPATH=src python examples/prefix_sharing.py
"""
import shutil

import jax
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.manager import InstanceManager, ManagerConfig
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.core.state import Rung

SPOOL = "/tmp/repro_prefix"
N_SESSIONS = 6
SYS_PROMPT = list(range(1, 49))      # 48-token shared system prompt


def main():
    shutil.rmtree(SPOOL, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(ManagerConfig(spool_dir=SPOOL), factory)
    eng = ServingEngine(mgr)
    inst = eng.start_instance("i0", "llama3.2-3b")
    pool = mgr.pool

    # --- baseline: every session prefills the system prompt privately
    for j in range(N_SESSIONS):
        eng.handle(Request("i0", f"private{j}",
                           np.asarray(SYS_PROMPT, np.int32),
                           max_new_tokens=1))
    private_bytes = pool.rss_bytes("i0")
    print(f"private prefills: {N_SESSIONS} sessions -> "
          f"{private_bytes >> 10} KB of KV pages")
    for j in range(N_SESSIONS):
        inst.kv.close_session(f"private{j}")
    inst.kv.trim()

    # --- COW: prefill once, fork the page table N-1 times
    eng.handle(Request("i0", "base", np.asarray(SYS_PROMPT, np.int32),
                       max_new_tokens=1))
    for j in range(1, N_SESSIONS):
        inst.kv.fork_session("base", f"fork{j}")
    shared_bytes = pool.rss_bytes("i0")
    print(f"COW forks:        {N_SESSIONS} sessions -> "
          f"{shared_bytes >> 10} KB of KV pages "
          f"({shared_bytes / private_bytes:.0%} of private)")

    # forks diverge independently
    r1 = eng.handle(Request("i0", "fork1", np.asarray([99], np.int32),
                            max_new_tokens=3))
    r2 = eng.handle(Request("i0", "fork2", np.asarray([7], np.int32),
                            max_new_tokens=3))
    print(f"fork1 continues -> {r1.tokens}; fork2 -> {r2.tokens}")

    # hibernation round-trips shared pages through the swap files once
    eng.record_sample("i0", Request("i0", "probe", np.asarray([3], np.int32),
                                    max_new_tokens=1, close_session=True))
    st = mgr.descend("i0", Rung.HIBERNATED)
    print(f"deflated: {st.kv_pages_swapped} kv pages swapped "
          f"({(st.reap_bytes + st.swap_bytes) >> 10} KB)")
    r = eng.handle(Request("i0", "fork1", np.asarray([5], np.int32),
                           max_new_tokens=2))
    print(f"woken, fork1 -> {r.tokens} (faults={r.faults})")


if __name__ == "__main__":
    main()

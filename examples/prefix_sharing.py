"""Beyond-paper demo: COW KV prefix sharing on the refcounted allocator.

The paper refcounts pages for process clone/COW (§3.3).  The LLM analogue:
N sessions sharing a long system prompt hold ONE physical copy of its KV
pages.  This example measures pool usage and per-session PSS with and
without forking, shows hibernation handles shared pages correctly, and
finishes with the automatic path: the deployment-wide PrefixRegistry
adopting a registered prompt across tenants — no fork calls, bit-exact.

Run:  PYTHONPATH=src python examples/prefix_sharing.py
"""
import shutil

import jax
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.manager import InstanceManager, ManagerConfig
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.core.state import Rung

SPOOL = "/tmp/repro_prefix"
N_SESSIONS = 6
SYS_PROMPT = list(range(1, 49))      # 48-token shared system prompt


def main():
    shutil.rmtree(SPOOL, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    # --- baseline: registry disabled — every session pays a private
    # prefill and holds its own copy of the prompt's KV pages
    mgr_off = InstanceManager(
        ManagerConfig(spool_dir=SPOOL + "_off", prefix_sharing=False),
        factory)
    eng_off = ServingEngine(mgr_off)
    eng_off.start_instance("i0", "llama3.2-3b")
    for j in range(N_SESSIONS):
        eng_off.handle(Request("i0", f"private{j}",
                               np.asarray(SYS_PROMPT, np.int32),
                               max_new_tokens=1))
    private_bytes = mgr_off.pool.rss_bytes("i0")
    print(f"private prefills: {N_SESSIONS} sessions -> "
          f"{private_bytes >> 10} KB of KV pages")

    mgr = InstanceManager(ManagerConfig(spool_dir=SPOOL), factory)
    eng = ServingEngine(mgr)
    inst = eng.start_instance("i0", "llama3.2-3b")
    pool = mgr.pool

    # --- COW: prefill once, fork the page table N-1 times
    eng.handle(Request("i0", "base", np.asarray(SYS_PROMPT, np.int32),
                       max_new_tokens=1))
    for j in range(1, N_SESSIONS):
        inst.kv.fork_session("base", f"fork{j}")
    shared_bytes = pool.rss_bytes("i0")
    print(f"COW forks:        {N_SESSIONS} sessions -> "
          f"{shared_bytes >> 10} KB of KV pages "
          f"({shared_bytes / private_bytes:.0%} of private)")

    # forks diverge independently
    r1 = eng.handle(Request("i0", "fork1", np.asarray([99], np.int32),
                            max_new_tokens=3))
    r2 = eng.handle(Request("i0", "fork2", np.asarray([7], np.int32),
                            max_new_tokens=3))
    print(f"fork1 continues -> {r1.tokens}; fork2 -> {r2.tokens}")

    # hibernation round-trips shared pages through the swap files once
    eng.record_sample("i0", Request("i0", "probe", np.asarray([3], np.int32),
                                    max_new_tokens=1, close_session=True))
    st = mgr.descend("i0", Rung.HIBERNATED)
    print(f"deflated: {st.kv_pages_swapped} kv pages swapped "
          f"({(st.reap_bytes + st.swap_bytes) >> 10} KB)")
    r = eng.handle(Request("i0", "fork1", np.asarray([5], np.int32),
                           max_new_tokens=2))
    print(f"woken, fork1 -> {r.tokens} (faults={r.faults})")

    # --- the automatic path: the prefix registry.  fork_session shares
    # within one tenant by hand; the registry does it deployment-wide.
    # i0's very first prefill of SYS_PROMPT already registered it under
    # its salted token-hash, so a brand-new tenant's sessions adopt the
    # resident pages — first token emitted without a forward pass.
    # (Memory caveat: this 48-token prompt spans one PARTIAL page, so a
    # session's first appended decode token COW-breaks it back to a
    # private copy; page-aligned prompts keep the pages shared for the
    # session's whole life — benchmarks/prefix_density.py measures that.)
    eng.start_instance("i1", "llama3.2-3b")
    ra = eng.handle(Request("i1", "adopted", np.asarray(SYS_PROMPT, np.int32),
                            max_new_tokens=1))
    rb = eng.handle(Request("i0", "replay", np.asarray(SYS_PROMPT, np.int32),
                            max_new_tokens=1))
    st = mgr.prefix_registry.stats()
    print(f"registry: adopted={ra.adopted_prefix} (cross-tenant, "
          f"bit-exact first token: {ra.tokens == rb.tokens}); "
          f"{st['registrations']} registered, {st['adoptions']} adoptions")


if __name__ == "__main__":
    main()

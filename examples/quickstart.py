"""Quickstart: one tenant through the full container lifecycle.

  cold start -> warm request -> REAP record -> hibernate -> request-driven
  wake -> woken request -> evict

Run:  PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-3b]
"""
import argparse
import shutil

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, tiny_config
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import memory_report
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.core.state import Rung

SPOOL = "/tmp/repro_quickstart"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    args = ap.parse_args()

    shutil.rmtree(SPOOL, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(ManagerConfig(spool_dir=SPOOL, wake_mode="reap"),
                          factory)
    eng = ServingEngine(mgr)

    def req(sid, toks, n=6, **kw):
        cfg = mgr.instances["tenant0"].cfg
        if cfg.frontend.kind == "vision":
            kw.setdefault("embeds", np.ones(
                (cfg.frontend.num_embeddings, cfg.frontend.embed_dim),
                np.float32))
        if cfg.is_encoder_decoder:
            kw.setdefault("frames",
                          np.ones((8, cfg.frontend.embed_dim), np.float32))
        return Request("tenant0", sid, np.asarray(toks, np.int32),
                       max_new_tokens=n, **kw)

    def report(stage):
        inst = mgr.instances["tenant0"]
        print(f"  [{stage:9s}] state={inst.state.value:10s} "
              f"weights={inst.weight_bytes() >> 10:6d} KB "
              f"kv={inst.kv_bytes() >> 10:4d} KB")

    print(f"== quickstart: {args.arch} (reduced config) ==")
    inst = eng.start_instance("tenant0", args.arch)
    report("cold")

    r = eng.handle(req("chat", [1, 2, 3, 4]))
    print(f"  warm request -> tokens {r.tokens} "
          f"({r.spans['e2e'] * 1e3:.0f} ms)")
    report("warm")

    # §3.4.2: record the working set with a sample request
    ws = eng.record_sample("tenant0", req("probe", [5, 6], n=3,
                                          close_session=True))
    print(f"  REAP recorded {len(ws)} working-set units")

    # ④ SIGSTOP: deflate
    st = mgr.descend("tenant0", Rung.HIBERNATED)
    print(f"  deflated: reap={st.reap_bytes >> 10} KB "
          f"swap={st.swap_bytes >> 10} KB "
          f"kv_pages={st.kv_pages_swapped} in {st.seconds * 1e3:.0f} ms")
    report("hibernate")

    # ⑦ request wakes it; the chat session continues where it left off
    r = eng.handle(req("chat", [9, 8]))
    print(f"  wake request -> tokens {r.tokens} "
          f"({r.spans['e2e'] * 1e3:.0f} ms, prefetched "
          f"{r.prefetched_bytes >> 10} KB, {r.faults} faults)")
    report("woken")

    rep = memory_report(inst)
    print(f"  PSS total: {rep.pss_total / 2**20:.2f} MB")
    mgr.evict("tenant0")
    print("  evicted; swap files deleted.")


if __name__ == "__main__":
    main()

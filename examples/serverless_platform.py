"""End-to-end serving driver: a multi-tenant serverless platform under a
bursty request trace, with keep-alive deflation and memory pressure.

Three tenants (dense / MoE / SSM families), batched requests, and a
policy loop that hibernates idle tenants instead of evicting them.
Uses the AsyncPlatform API: ``submit`` returns futures and a worker
pool serves tenants concurrently; the policy pass is driven explicitly
here (``tick_interval_s`` daemon cadence exists too — see
examples/async_platform.py for the fully event-driven variant).

Run:  PYTHONPATH=src python examples/serverless_platform.py
"""
import shutil
import time

import jax
import numpy as np

from repro.configs import get_config, tiny_config
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import memory_report
from repro.models import model
from repro.serving import (AsyncPlatform, PlatformPolicy, Request,
                           ServingEngine)

SPOOL = "/tmp/repro_platform"
TENANTS = {"chat-app": "llama3.2-3b", "search-app": "arctic-480b",
           "stream-app": "mamba2-130m"}


def main():
    shutil.rmtree(SPOOL, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(ManagerConfig(spool_dir=SPOOL, wake_mode="reap"),
                          factory)
    eng = ServingEngine(mgr)
    # long daemon cadence: this driver runs the policy pass explicitly
    policy = PlatformPolicy(keep_warm_s=0.0, tick_interval_s=3600.0)
    plat = AsyncPlatform(eng, policy, TENANTS, workers=len(TENANTS))

    rng = np.random.default_rng(0)
    lat = {t: [] for t in TENANTS}

    with plat:
        # ---- phase 1: a burst hits every tenant (concurrent cold starts)
        print("== phase 1: cold-start burst ==")
        futs = [plat.submit(Request(t, f"s{j}",
                                    rng.integers(0, 256, 6).astype(np.int32),
                                    max_new_tokens=4))
                for t in TENANTS for j in range(2)]
        for f in futs:
            r = f.result()
            lat[r.request.instance_id].append(r.spans["e2e"])
            print(f"  {r.request.instance_id:11s} {r.state_before:9s} -> "
                  f"{r.state_after:6s} tokens={r.tokens}")

        # record working sets, then the platform deflates idle tenants
        for tenant in TENANTS:
            eng.record_sample(tenant, Request(
                tenant, "probe", rng.integers(0, 256, 4).astype(np.int32),
                max_new_tokens=2, close_session=True))
        acted = plat.policy_pass()
        print(f"== keep-alive expired: deflated {acted} ==")
        print("  states:", mgr.states())

        # ---- phase 2: sparse traffic wakes tenants on demand
        print("== phase 2: request-driven wakes ==")
        for tenant in TENANTS:
            r = plat.submit(Request(
                tenant, "s0", rng.integers(0, 256, 3).astype(np.int32),
                max_new_tokens=4)).result()
            lat[r.request.instance_id].append(r.spans["e2e"])
            print(f"  {r.request.instance_id:11s} {r.state_before:9s} -> "
                  f"{r.state_after:6s} faults={r.faults} "
                  f"prefetch={r.prefetched_bytes >> 10}KB "
                  f"({r.spans['e2e'] * 1e3:.0f} ms)")

        # ---- phase 3: memory pressure packs everyone down
        total = mgr.resident_bytes()
        deflated = mgr.handle_memory_pressure(total // 3,
                                              try_lock=eng.instance_lock)
        print(f"== phase 3: memory pressure -> deflated {deflated} ==")
        print("  states:", mgr.states())
        print(f"  resident: {mgr.resident_bytes() >> 20} MB "
              f"(was {total >> 20} MB); tenants kept: {len(mgr.instances)}/3")

    print("== summary ==")
    for t in TENANTS:
        xs = lat[t]
        print(f"  {t:11s} first(cold-ish)={xs[0] * 1e3:7.0f} ms  "
              f"wake={xs[-1] * 1e3:6.0f} ms")
    for iid, inst in mgr.instances.items():
        rep = memory_report(inst, mgr.shared)
        print(f"  {iid:11s} state={rep.state:9s} "
              f"pss={rep.pss_total / 2**20:6.2f} MB "
              f"disk={rep.disk_stored_pss / 2**20:5.2f}"
              f"/{rep.disk_logical / 2**20:5.2f} MB (stored/logical)")
    st = mgr.store.stats()
    print(f"  swap store: {st['segments']} segments, "
          f"{st['stored_bytes'] >> 10} KB stored for "
          f"{st['logical_bytes'] >> 10} KB logical "
          f"(dedup hits={st['dedup_hits']}, elided={st['elisions']}, "
          f"sunk={st['sink_events']})")


if __name__ == "__main__":
    main()

"""Train a ~100M-parameter model for a few hundred steps on CPU.

Uses the scaled llama3.2 family config (the assigned arch reduced to
CPU-trainable size), the synthetic copy-task pipeline, AdamW with
warmup+cosine, remat, and periodic checkpointing.  Loss should drop
from ~ln(V) toward the copy-task floor.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_config
from repro.data import DataConfig, SyntheticPipeline
from repro.models import model
from repro.training import (AdamWConfig, checkpoint, init_state,
                            make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train/ckpt")
    args = ap.parse_args()

    cfg = scaled_config(get_config(args.arch), d_model=args.d_model,
                        layers=args.layers)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} (scaled) params={n_params / 1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    pipe = SyntheticPipeline(DataConfig(cfg.vocab_size, args.seq,
                                        args.batch, seed=0),
                             frontend=cfg.frontend)

    t0 = time.monotonic()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step + 1) / \
                (time.monotonic() - t0)
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm "
                  f"{float(m['grad_norm']):.2f}  {tok_s:,.0f} tok/s")
        if step and step % 100 == 0:
            checkpoint.save(args.ckpt, params, step=step)
    checkpoint.save(args.ckpt, params, step=args.steps)
    print(f"checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()

"""Long-context serving demo: the two sub-quadratic long_500k paths,
scaled to CPU (1,024-token context, reduced models).

  1. dense + sliding window — ring-buffered KV cache of `window` slots:
     memory is O(window), not O(context); logits equal full windowed
     attention (verified inline).
  2. SSM (mamba2) — O(1) state decode: cache size is context-independent.

This is the design that makes the assigned long_500k shape feasible:
524,288-token decode costs a 4,096-slot cache on dense archs and a fixed
(heads x state x head_dim) state on SSM archs (EXPERIMENTS.md §Roofline,
long_500k rows).

Run:  PYTHONPATH=src python examples/long_context.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, tiny_config
from repro.models import model

CTX = 1024
WINDOW = 64


def dense_ring():
    cfg = tiny_config(get_config("llama3.2-3b"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, CTX),
                              0, cfg.vocab_size)

    # ring decode: cache holds WINDOW slots regardless of context length
    t0 = time.monotonic()
    _, cache = model.prefill(params, cfg, toks[:, :1], max_len=WINDOW,
                             window=WINDOW)
    step = jax.jit(lambda p, t, c: model.decode_step(p, cfg, t, c,
                                                     window=WINDOW))
    logits = None
    for t in range(1, CTX):
        logits, cache = step(params, toks[:, t], cache)
    dt = time.monotonic() - t0

    cache_bytes = sum(np.asarray(v).nbytes
                      for v in jax.tree.leaves(cache["layers"]))
    # reference: full-sequence forward with the same window
    x, _, _ = model.forward_hidden(params, cfg, toks, window=WINDOW)
    ref = model.unembed(params, cfg, x[:, -1])
    err = float(jnp.max(jnp.abs(logits - ref)))
    full_bytes = cache_bytes * CTX // WINDOW
    print(f"dense+SWA : {CTX} tokens, ring cache {cache_bytes >> 10} KB "
          f"(full cache would be ~{full_bytes >> 10} KB), "
          f"max|logit delta| vs windowed reference = {err:.2e}, "
          f"{CTX / dt:.0f} tok/s")


def ssm_state():
    cfg = tiny_config(get_config("mamba2-130m"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, CTX),
                              0, cfg.vocab_size)
    t0 = time.monotonic()
    _, cache = model.prefill(params, cfg, toks[:, :1], max_len=1)
    step = jax.jit(lambda p, t, c: model.decode_step(p, cfg, t, c))
    for t in range(1, CTX):
        logits, cache = step(params, toks[:, t], cache)
    dt = time.monotonic() - t0
    state_bytes = sum(np.asarray(v).nbytes
                      for v in jax.tree.leaves(cache["layers"]))
    # the state is the whole cache: context-independent
    print(f"mamba2 SSD: {CTX} tokens, state cache {state_bytes >> 10} KB "
          f"(identical at 524k tokens), {CTX / dt:.0f} tok/s")


if __name__ == "__main__":
    dense_ring()
    ssm_state()

"""Relative-link and anchor checker for the docs tree.

Walks the given markdown files, extracts every inline link, and fails
on: relative links to files that don't exist, and ``#anchor`` fragments
(same-file or cross-file) that don't match any heading's GitHub-style
slug.  External (http/https/mailto) links are *not* fetched — CI must
not flake on someone else's server.

Usage: ``python tools/linkcheck.py README.md docs/*.md``
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub's anchor algorithm: lowercase, drop punctuation, spaces
    become hyphens (markdown emphasis/code markers stripped first)."""
    text = re.sub(r"[*_`]|\[|\]|\(.*?\)", "", heading).strip()
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    """Anchor slugs of every heading outside code fences."""
    out = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def links_of(path: Path):
    """(line_no, target) for every inline link outside code fences."""
    in_fence = False
    for i, line in enumerate(path.read_text(encoding="utf-8").splitlines(),
                             start=1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield i, m.group(1)


def check(files) -> int:
    anchor_cache = {}

    def anchors(p: Path) -> set:
        if p not in anchor_cache:
            anchor_cache[p] = anchors_of(p)
        return anchor_cache[p]

    errors = []
    for f in files:
        f = Path(f)
        for line_no, target in links_of(f):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            ref, _, frag = target.partition("#")
            dest = (f.parent / ref).resolve() if ref else f.resolve()
            if ref and not dest.exists():
                errors.append(f"{f}:{line_no}: broken link -> {target}")
                continue
            if frag and dest.suffix == ".md":
                if frag not in anchors(dest):
                    errors.append(
                        f"{f}:{line_no}: missing anchor -> {target}")
    for e in errors:
        print(e)
    print(f"linkcheck: {len(errors)} error(s) in {len(list(files))} file(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:]))

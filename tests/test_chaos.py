"""Failure-domain chaos suite: node crashes, replicated recovery,
corruption detection/repair, torn writes, and transport hardening —
every fault injected deterministically from a fixed seed."""
import os
import socket
import struct
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.cluster import ClusterPolicy
from repro.cluster.faults import (FaultError, FaultInjector, FaultyTransport,
                                  FrameFaults, corrupt_one_byte)
from repro.cluster.health import HealthPolicy
from repro.cluster.migrate import MigrationError, migrate_instance
from repro.cluster.transport import (LoopbackTransport, SocketTransport,
                                     StoreServer, TransportError)
from repro.core.state import Rung
from repro.core.store import CorruptSegmentError, SwapStore
from repro.core.swap import ReapFile
from repro.serving.engine import NodeDownError
from repro.serving.frontdoor import FrontDoor, FrontDoorPolicy

from test_cluster import (ARCH, SALT, _assert_identical, _cluster,
                          _full_wake, _snapshot, _tenant)

SEEDS = [7, 13, 29]          # the chaos-smoke seeds CI pins

POLICY = ClusterPolicy(replication_factor=2, max_replications_per_round=64,
                       health=HealthPolicy(suspect_after_s=3.0,
                                           dead_after_s=10.0))


def _hibernate_and_replicate(router, node, iids, now=0.0):
    for iid in iids:
        node.manager.descend(iid, Rung.HIBERNATED)
    acts = router.anti_entropy(now)
    assert len([a for a in acts if a[0] == "replicate"]) == len(iids)
    return acts


def _kill_and_detect(router, node, t0=0.0):
    """Crash ``node`` and walk the lease detector to DEAD in virtual
    time (SUSPECT at +suspect_after, DEAD at +dead_after) — recovery
    fires inside check_health."""
    router.check_health(t0)                  # seed every lease
    node.kill()
    assert router.check_health(t0 + 1.0) == []
    sus = router.check_health(t0 + 4.0)
    assert (node.node_id, *[s.value for s in sus[0][1:]]) == \
        (node.node_id, "alive", "suspect")
    dead = router.check_health(t0 + 11.0)
    assert any(nid == node.node_id and new.value == "dead"
               for nid, _old, new in dead)


# ------------------------------------------------------------ acceptance
def test_node_crash_rehomes_every_tenant_byte_identical(tiny_factory,
                                                        spool_dir):
    """Kill a node homing 8 hibernated tenants: every tenant is
    re-homed onto survivors from replicated segments and wakes
    byte-identical; the survivors' own tenants are untouched."""
    router, (n0, n1, n2) = _cluster(tiny_factory, spool_dir, n=3,
                                    policy=POLICY)
    iids = [f"t{i}" for i in range(8)]
    snaps = {}
    for i, iid in enumerate(iids):
        snaps[iid] = _snapshot(_tenant(router, n0, iid, seed=i))
    bystander = _tenant(router, n1, "bystander", seed=99)
    by_snap = _snapshot(bystander)
    _hibernate_and_replicate(router, n0, iids)
    # replicas landed on OTHER stores and are pinned there (digest
    # affinity may cluster them on one survivor — that's fine)
    assert sum(len(n.replicas) for n in (n1, n2)) == 8
    assert any(n.store.stats()["pinned_segments"] > 0 for n in (n1, n2))

    _kill_and_detect(router, n0)
    assert router.tenants_lost == 0
    assert router.tenants_rehomed == 8
    for iid in iids:
        home = router.node_of(iid)
        assert home is not None and home.node_id != "n0"
        assert home.manager.instances[iid].state.value == "hibernate"
        _assert_identical(_full_wake(home, iid), snaps[iid])
    # survivors: untouched bystander, GC-clean stores (no orphans, no
    # quarantine, nothing left pinned for the recovered tenants)
    _assert_identical(bystander, by_snap)
    for n in (n1, n2):
        assert n.store.orphan_digests(0.0) == []
        assert n.store.stats()["quarantined"] == 0
        assert not n.replicas
    stats = router.migration_stats()
    assert stats["tenants_rehomed"] == 8 and stats["nodes_dead"] == 1
    router.close()


def test_tenant_without_replica_is_lost_not_wedged(tiny_factory, spool_dir):
    """replication_factor=1 (no replicas): a crash loses the tenant —
    placement clears, the router says so, and nothing hangs."""
    pol = ClusterPolicy(replication_factor=1,
                        health=HealthPolicy(suspect_after_s=3.0,
                                            dead_after_s=10.0))
    router, (n0, n1) = _cluster(tiny_factory, spool_dir, policy=pol)
    _tenant(router, n0, "t0")
    n0.manager.descend("t0", Rung.HIBERNATED)
    assert router.anti_entropy(0.0) == []
    _kill_and_detect(router, n0)
    assert router.tenants_lost == 1 and router.tenants_rehomed == 0
    assert "t0" not in router.placement
    router.close()


def test_anti_entropy_reheals_after_holder_dies(tiny_factory, spool_dir):
    """The anti-entropy round re-replicates when a *holder* (not the
    home) dies: the tenant's replica count returns to k-1."""
    router, (n0, n1, n2) = _cluster(tiny_factory, spool_dir, n=3,
                                    policy=POLICY)
    _tenant(router, n0, "t0")
    _hibernate_and_replicate(router, n0, ["t0"])
    holder = next(n for n in (n1, n2) if "t0" in n.replicas)
    other = n2 if holder is n1 else n1
    _kill_and_detect(router, holder)
    assert router.node_of("t0") is n0         # home untouched
    acts = router.anti_entropy(20.0)
    assert ("replicate", "t0", "n0", other.node_id) in acts
    assert "t0" in other.replicas
    assert not other.store.missing_digests(other.replicas["t0"].digests)
    router.close()


# ------------------------------------------------------- mid-migration kill
@pytest.mark.parametrize("point", ["migrate.exported", "migrate.shipped"])
def test_crash_mid_migration_leaves_gc_clean_stores(tiny_factory, spool_dir,
                                                    point):
    """Crash the transfer between export and ship, and in the
    import-vs-adopt window: the source falls back to a plain hibernated
    tenant (wakes byte-identical) and the target sweeps everything it
    imported — no refcount leak, no orphan bytes."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=3)
    snap = _snapshot(inst)
    n0.manager.descend("t0", Rung.HIBERNATED)
    inj = FaultInjector(seed=7).arm(point, FaultInjector.crash())
    with inj, pytest.raises(MigrationError) as ei:
        router.migrate("t0", "n1")
    h = ei.value.handle
    assert not h.ok and isinstance(h.error, FaultError)
    assert inj.fired(point) == 1
    assert "t0" in n0.manager.instances
    assert "t0" not in n1.manager.instances
    assert router.node_of("t0") is n0
    assert n1.store.orphan_digests(0.0) == []       # swept on abort
    _assert_identical(_full_wake(n0, "t0"), snap)
    router.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_wire_corruption_rejected_at_import(tiny_factory, spool_dir, seed):
    """Every segment corrupted on the wire: the receiver's content
    verification rejects the frames, adoption aborts the migration, and
    the source tenant is intact — no store ever holds poisoned bytes."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=seed)
    snap = _snapshot(inst)
    n0.manager.descend("t0", Rung.HIBERNATED)
    inj = FaultInjector(seed=seed)
    t = FaultyTransport(LoopbackTransport(dst_node=n1), inj,
                        FrameFaults(corrupt_p=1.0))
    with inj, pytest.raises(MigrationError):
        migrate_instance(n0, None, "t0", ARCH, transport=t)
    assert t.corrupted > 0
    assert n1.store.import_rejects == t.corrupted
    assert n1.store.stats()["quarantined"] == 0
    assert "t0" in n0.manager.instances and "t0" not in n1.manager.instances
    _assert_identical(_full_wake(n0, "t0"), snap)
    router.close()


# ------------------------------------------------------------- corruption
def _corrupt_unit_segment(node, iid, rng_seed):
    """Flip one byte of the stored payload backing one of the tenant's
    swapped-out units; returns ``(client, key, digest)`` so the test can
    force a read through the exact path a sharer would take."""
    import random
    rng = random.Random(rng_seed)
    store = node.store
    client = node.manager.instances[iid].swap_file
    with store._lock:
        key, digest = next(
            (k, m.digest) for k, m in sorted(client.extents.items(),
                                             key=lambda km: str(km[0]))
            if m.digest is not None
            and store._segments[m.digest].stored_nbytes > 0)
        seg = store._segments[digest]
        blob = os.pread(store.fd, seg.stored_nbytes, seg.offset)
        os.pwrite(store.fd, corrupt_one_byte(blob, rng), seg.offset)
    return client, key, digest


@pytest.mark.parametrize("seed", SEEDS)
def test_disk_corruption_detected_quarantined_repaired(tiny_factory,
                                                       spool_dir, seed):
    """A single flipped byte on the home store: the read path detects it
    (CRC), quarantines the segment, repairs it from the replica peer,
    and the wake is byte-identical — no sharer ever sees bad bytes."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir, policy=POLICY)
    inst = _tenant(router, n0, "t0", seed=seed)
    snap = _snapshot(inst)
    _hibernate_and_replicate(router, n0, ["t0"])
    client, key, digest = _corrupt_unit_segment(n0, "t0", seed)

    # a sharer reads the unit: detection + quarantine + peer repair all
    # happen inside this one read — it returns good bytes
    n0.store.read(client, [key])
    _assert_identical(_full_wake(n0, "t0"), snap)
    assert n0.store.corruptions >= 1
    assert n0.store.repairs >= 1
    assert router.repairs_served >= 1
    assert n0.store.stats()["quarantined"] == 0     # repaired, not parked
    router.close()


def test_scrub_finds_and_repairs_before_any_read(tiny_factory, spool_dir):
    """The background scrub catches rot a reader hasn't hit yet and
    repairs it from the replica — the later wake never sees it."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir, policy=POLICY)
    inst = _tenant(router, n0, "t0", seed=5)
    snap = _snapshot(inst)
    _hibernate_and_replicate(router, n0, ["t0"])
    _client, _key, digest = _corrupt_unit_segment(n0, "t0", 5)

    res = n0.store.scrub(repair=True)
    assert res["corrupt_found"] == 1 and res["repaired"] == 1
    assert n0.store.missing_digests([digest]) == []
    reads_before = n0.store.corruptions
    _assert_identical(_full_wake(n0, "t0"), snap)
    assert n0.store.corruptions == reads_before     # wake hit clean bytes
    router.close()


def test_unrepairable_corruption_quarantines_and_reship_repairs(spool_dir):
    """Store-level: with no replica peer, a corrupt segment raises on
    read and counts as missing; a later verified re-ship of the same
    content repairs it in place (refs preserved)."""
    import random
    store = SwapStore(os.path.join(spool_dir, "solo", "store.cas"),
                      salt=SALT)
    c = store.client("t")
    arr = np.arange(4096, dtype=np.float32) * 1.5
    store.put(c, "k", arr)
    digest = c.extents["k"].digest
    with store._lock:
        seg = store._segments[digest]
        blob = os.pread(store.fd, seg.stored_nbytes, seg.offset)
        os.pwrite(store.fd, corrupt_one_byte(blob, random.Random(1)),
                  seg.offset)
    with pytest.raises(CorruptSegmentError):
        store.read(c, ["k"])
    assert store.missing_digests([digest]) == [digest]
    assert store.stats()["quarantined"] == 1
    # re-ship IS the repair: a verified wire frame lands in place
    store.import_segments([(digest, seg.level, seg.raw_nbytes, blob)])
    got = store.read(c, ["k"])["k"]
    np.testing.assert_array_equal(got.reshape(arr.shape), arr)
    assert store.stats()["quarantined"] == 0
    with store._lock:
        assert store._segments[digest].refs == 1    # refs survived repair
    store.close()


def test_reapfile_write_batch_is_atomic(spool_dir, monkeypatch):
    """A crash mid-rebuild (rename fails) leaves the previous REAP
    snapshot fully intact — file, extents, and readable bytes."""
    import repro.core.swap as swap
    rf = ReapFile(os.path.join(spool_dir, "t", "reap.bin"))
    a = np.arange(64, dtype=np.float32)
    rf.write_batch([("a", a)])
    before = dict(rf.extents)

    def boom(src, dst):
        raise OSError("injected crash at commit point")
    monkeypatch.setattr(swap.os, "rename", boom)
    with pytest.raises(OSError):
        rf.write_batch([("a", a * 2), ("b", a * 3)])
    monkeypatch.undo()

    assert dict(rf.extents) == before               # old snapshot intact
    assert not os.path.exists(rf.path + ".tmp")     # torn temp cleaned up
    np.testing.assert_array_equal(rf.read_batch()["a"], a)
    rf.write_batch([("a", a * 2), ("b", a * 3)])    # and recovery works
    np.testing.assert_array_equal(rf.read_batch()["b"], a * 3)
    rf.delete()


# ------------------------------------------------------------- transport
def test_server_survives_malformed_and_oversized_frames(spool_dir):
    """Garbage and over-declared frames are per-connection protocol
    errors: the connection dies, imported orphans are swept, and the
    accept loop keeps serving fresh clients."""
    store = SwapStore(os.path.join(spool_dir, "srv", "store.cas"),
                      salt=SALT)
    srv = StoreServer(store, node_id="srv", io_timeout_s=5.0,
                      max_frame_bytes=1 << 20)
    try:
        from repro.cluster.wire import MSG_AUTH
        bad = (
            # well-framed AUTH whose payload is undecodable garbage
            struct.pack(">IB", 4, MSG_AUTH) + b"\xee\xee\xee\xee",
            # length prefix over the server's max_frame_bytes bound
            struct.pack(">IB", 1 << 30, MSG_AUTH),
            # a frame that isn't AUTH at all (auth failure, not a crash)
            b"\x00" * 16,
        )
        for payload in bad:
            s = socket.create_connection(srv.address, timeout=5.0)
            s.sendall(payload)
            assert s.recv(4096) is not None     # server answers or closes
            s.close()
        deadline = time.monotonic() + 5.0
        while srv.protocol_errors < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert srv.protocol_errors >= 2
        assert srv.auth_failures >= 1
        # the accept loop survived: a real client still works
        t = SocketTransport.connect(srv.address, SALT, node_id="c")
        assert t.missing_digests([b"x" * 16]) == [b"x" * 16]
        t.close()
    finally:
        srv.close()
        store.close()


def test_client_io_deadline_raises_instead_of_wedging(spool_dir):
    """A peer that accepts but never speaks: the client's handshake hits
    its io deadline and raises TransportError instead of blocking."""
    silent = socket.socket()
    silent.bind(("127.0.0.1", 0))
    silent.listen(1)
    try:
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            SocketTransport.connect(silent.getsockname(), SALT,
                                    io_timeout_s=0.3)
        assert time.monotonic() - t0 < 5.0
    finally:
        silent.close()


def test_server_io_deadline_reaps_stalled_connection(spool_dir):
    """A client that connects and goes silent is reaped by the server's
    io deadline; the slot frees and new clients still get served."""
    store = SwapStore(os.path.join(spool_dir, "srv2", "store.cas"),
                      salt=SALT)
    srv = StoreServer(store, node_id="srv2", io_timeout_s=0.3)
    try:
        stalled = socket.create_connection(srv.address, timeout=5.0)
        stalled.settimeout(5.0)
        while stalled.recv(4096):               # drain HELLO, then EOF
            pass                                # server closed it
        stalled.close()
        t = SocketTransport.connect(srv.address, SALT)
        t.close()
    finally:
        srv.close()
        store.close()


# ------------------------------------------------------------- idempotency
class _FlakyTarget:
    """Stand-in dispatch surface: first attempt streams a partial prefix
    then dies with NodeDownError; the retry replays the full sequence
    (what a deterministic engine on the re-homed node does)."""

    def __init__(self, tokens, die_after):
        self.tokens = tokens
        self.die_after = die_after
        self.arch_of = {"t0": ARCH}
        self.submits = 0

    def submit(self, req):
        self.submits += 1
        fut = Future()
        if self.submits == 1:
            for t in self.tokens[:self.die_after]:
                req.on_token(t)
            fut.set_exception(NodeDownError("node n0 crashed"))
        else:
            for t in self.tokens:                # full deterministic replay
                req.on_token(t)
            fut.set_result({"tokens": list(self.tokens)})
        return fut


def test_frontdoor_redispatch_never_double_emits():
    """The crash re-dispatch property: tokens 0..2 emitted, node dies,
    retry replays 0..7 — the client stream sees each position exactly
    once and completes normally."""
    tokens = [10, 11, 12, 13, 14, 15, 16, 17]
    tgt = _FlakyTarget(tokens, die_after=3)
    door = FrontDoor(tgt, policy=FrontDoorPolicy(redispatch_attempts=1))
    stream = door.submit("t0", [1, 2, 3], session_id="s0",
                         max_new_tokens=8, idempotency_key="req-1")
    assert list(stream) == tokens                # exactly once, in order
    assert stream.attempts == 2 and stream.emitted == len(tokens)
    assert tgt.submits == 2
    st = door.stats()
    assert st["redispatches"] == 1 and st["errors"] == 0
    assert st["completed"] == 1
    # the completed key replays the finished stream, no new dispatch
    again = door.submit("t0", [1, 2, 3], session_id="s0",
                        max_new_tokens=8, idempotency_key="req-1")
    assert again is stream and tgt.submits == 2
    assert door.stats()["idem_hits"] == 1


def test_frontdoor_gives_up_after_redispatch_budget():
    class _DeadTarget:
        arch_of = {"t0": ARCH}

        def submit(self, req):
            fut = Future()
            fut.set_exception(NodeDownError("everything is down"))
            return fut

    door = FrontDoor(_DeadTarget(),
                     policy=FrontDoorPolicy(redispatch_attempts=1))
    stream = door.submit("t0", [1], session_id="s0", idempotency_key="k")
    with pytest.raises(NodeDownError):
        list(stream)
    assert stream.attempts == 2                  # original + one retry
    assert door.stats()["errors"] == 1
    # a failed key is NOT cached: a later retry re-dispatches fresh
    assert door.stats()["idem_cached"] == 0


def test_request_to_crashed_node_lands_on_survivor(tiny_factory, spool_dir):
    """Synchronous serve path end to end: the home crashes, the next
    request triggers evidence-based recovery and is answered by the
    survivor from the replica — same tokens a healthy node produces."""
    from benchmarks.common import request_for

    router, (n0, n1) = _cluster(tiny_factory, spool_dir, policy=POLICY)
    inst = _tenant(router, n0, "t0", seed=2)
    cfg = inst.cfg
    ref = router.handle(request_for(cfg, "t0", "ref", 8, 4, seed=1,
                                    close_session=True), now=0.0)
    _hibernate_and_replicate(router, n0, ["t0"], now=1.0)
    n0.kill()
    got = router.handle(request_for(cfg, "t0", "ref2", 8, 4, seed=1,
                                    close_session=True), now=2.0)
    assert got.tokens == ref.tokens
    assert router.node_of("t0") is n1
    assert router.tenants_rehomed == 1
    router.close()

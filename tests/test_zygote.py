"""Zygote pool: fork-vs-cold byte identity, refcount isolation,
governor retirement economics, fork-storm dedup, pre-fork daemon, and
node-death chaos (a fork writes nothing a crash can orphan)."""
import os
import threading

import numpy as np
import pytest

from repro.cluster import ClusterPolicy, ClusterRouter, Node
from repro.cluster.health import HealthPolicy
from repro.core.forecast import ForecastConfig, ForecastDaemon
from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import (ContainerState, Event, InvalidTransition,
                              Rung, StateMachine)
from repro.core.zygote import (NEW_TENANT_KEY, ZygoteConfig, is_zygote_id,
                               zygote_id)
from repro.serving.engine import Request, ServingEngine

S = ContainerState
ARCH = "llama3.2-3b"
FAMILIES = ["llama3.2-3b", "arctic-480b", "mamba2-130m"]
SALT = b"zygote-test-salt"


def _loader(tiny_factory):
    def loader(base_id):
        import jax

        from repro.core.instance import _path_str
        cfg, params = tiny_factory(base_id)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return {_path_str(p): np.asarray(v) for p, v in flat
                if _path_str(p) == "embed"}
    return loader


def _mgr(tiny_factory, spool_dir, *, shared=True, zcfg=None, **kw):
    cfg = ManagerConfig(spool_dir=spool_dir,
                        zygote_pool=zcfg or ZygoteConfig(), **kw)
    return InstanceManager(
        cfg, tiny_factory,
        shared_loader=_loader(tiny_factory) if shared else None)


def _req(cfg, iid, sid="s0", new_tokens=3):
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    return Request(iid, sid, prompt, max_new_tokens=new_tokens)


# ------------------------------------------------------------ state graph
def test_zygote_state_graph():
    """A zygote never serves: REQUEST (and every deflate event) is
    illegal in ZYGOTE; its only exits are being forked or retired."""
    sm = StateMachine()
    sm.fire(Event.ZYGOTE_SPAWN)
    assert sm.state is S.ZYGOTE
    for ev in (Event.REQUEST, Event.SIGSTOP, Event.MMAP_DROP,
               Event.PARTIAL_STOP, Event.SIGCONT, Event.MIGRATE,
               Event.COLD_START):
        with pytest.raises(InvalidTransition):
            sm.fire(ev)
    assert sm.fire(Event.FORK) is S.DEAD          # consumed by a fork
    sm2 = StateMachine()
    sm2.fire(Event.ZYGOTE_SPAWN)
    assert sm2.fire(Event.EVICT) is S.DEAD        # governor retire
    # the forked tenant is born WARM through its own transition, so its
    # history distinguishes a warm fork from a true cold start
    sm3 = StateMachine()
    assert sm3.fire(Event.FORK) is S.WARM


# ------------------------------------------------------------ fork vs cold
@pytest.mark.parametrize("arch", FAMILIES)
def test_fork_first_response_byte_identical(tiny_factory, spool_dir, arch):
    """Fork admission is an optimization, never a different model: the
    first response of a forked tenant is byte-identical to a
    cold-started one, per family."""
    mgr = _mgr(tiny_factory, spool_dir)
    eng = ServingEngine(mgr)
    cold = eng.start_instance("cold", arch, shared_paths={"embed"})
    cold_toks = list(eng.handle(_req(cold.cfg, "cold")).tokens)
    mgr.evict("cold")
    zyg = mgr.zygotes.spawn(arch, shared_paths={"embed"})
    assert zyg.state is S.ZYGOTE and is_zygote_id(zyg.instance_id)
    inst = eng.fork_instance("forked", arch, shared_paths={"embed"})
    assert inst is not None and inst.state is S.WARM
    # the donor died by being forked; the tenant inherited its handles
    assert zyg.instance_id not in mgr.instances
    assert inst.compiled is zyg.compiled
    fork_toks = list(eng.handle(_req(inst.cfg, "forked")).tokens)
    assert fork_toks == cold_toks
    assert mgr.forks_performed == 1
    # the fork entered the graph through (COLD, FORK), not COLD_START
    assert inst.sm.history[0][2] is Event.FORK


def test_fork_without_pool_or_donor_falls_back(tiny_factory, spool_dir):
    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir), tiny_factory)
    assert mgr.zygotes is None
    assert mgr.fork_start("t", ARCH) is None      # no pool configured
    mgr2 = _mgr(tiny_factory, spool_dir + "2", shared=False)
    assert mgr2.fork_start("t", ARCH) is None     # pool, but no donor


def test_platform_admits_unknown_tenant_by_fork(tiny_factory, spool_dir):
    """The serve path tries the fork first: an unknown tenant's first
    request rides a live donor (logged ``fork_start``), and only a
    pool miss cold-starts."""
    from repro.serving.scheduler import Platform, PlatformPolicy
    mgr = _mgr(tiny_factory, spool_dir)
    eng = ServingEngine(mgr)
    zyg = mgr.zygotes.spawn(ARCH, shared_paths={"embed"})
    plat = Platform(eng, PlatformPolicy(), {"t": ARCH, "u": ARCH})
    plat.submit(_req(zyg.cfg, "t"))
    resps = plat.step()
    assert len(resps) == 1 and len(resps[0].tokens) == 3
    assert any(e[1] == "fork_start" and e[2] == "t" for e in plat.log)
    assert mgr.forks_performed == 1
    plat.submit(_req(zyg.cfg, "u"))               # pool is empty now
    plat.step()
    assert any(e[1] == "cold_start" and e[2] == "u" for e in plat.log)


# ------------------------------------------------------------ refcounts
def test_retiring_donor_never_frees_forked_tenants_pages(tiny_factory,
                                                         spool_dir):
    """Refcount isolation: the tenant acquires its own shared-registry
    ref before the donor releases, so retiring every remaining zygote
    leaves the forked tenant's shared base loaded and byte-intact."""
    mgr = _mgr(tiny_factory, spool_dir,
               zcfg=ZygoteConfig(per_family=2))
    mgr.zygotes.spawn(ARCH, shared_paths={"embed"})
    inst = mgr.fork_start("t", ARCH, shared_paths={"embed"})
    assert inst is not None
    assert mgr.shared.refcount(ARCH) == 1         # tenant's own ref
    golden = np.asarray(inst.weights["embed"]).copy()
    z2 = mgr.zygotes.spawn(ARCH, shared_paths={"embed"})
    assert mgr.shared.refcount(ARCH) == 2
    mgr.zygotes.retire(z2.instance_id)
    assert mgr.shared.refcount(ARCH) == 1
    assert mgr.shared.is_loaded(ARCH)
    np.testing.assert_array_equal(np.asarray(inst.weights["embed"]),
                                  golden)
    assert mgr.zygotes.stats()["live"] == 0
    mgr.evict("t")                                # last ref drops the base
    assert mgr.shared.refcount(ARCH) == 0


# ------------------------------------------------------------ governor
def test_governor_retires_idle_zygote_under_pressure(tiny_factory,
                                                     spool_dir):
    """A zygote is governor-charged: under budget pressure its bytes are
    priced against fork avoidance and it retires through the ladder's
    TERMINATED rung (no idle gate — it was never 'used')."""
    mgr = _mgr(tiny_factory, spool_dir, shared=False)
    zyg = mgr.zygotes.spawn(ARCH)
    zid = zyg.instance_id
    gov = mgr.governor
    before = gov.governed_bytes()
    assert before > 0
    acts = gov.step(now=100.0, budget_bytes=1)
    assert any(a.instance_id == zid and a.rung_to == Rung.TERMINATED
               for a in acts)
    assert zid not in mgr.instances
    assert zyg.state is S.DEAD
    assert mgr.zygotes.stats()["live"] == 0
    assert gov.governed_bytes() < before


def test_governor_prefers_zygote_over_hot_tenant(tiny_factory, spool_dir):
    """With a hot tenant (due soon) and a never-admitted family's zygote
    (default fork gap: an hour), the zygote is the better victim — its
    fork-avoidance value (bytes x predicted admission gap / cold-start
    cost) beats the hot tenant's imminent-wake value.  The hot tenant is
    a different family, so its admissions don't train the zygote's."""
    mgr = _mgr(tiny_factory, spool_dir, shared=False)
    zyg = mgr.zygotes.spawn(ARCH)
    inst = mgr.cold_start("hot", "mamba2-130m")
    gov = mgr.governor
    now = 100.0
    for t in (98.0, 99.0, 100.0):
        gov.observe_arrival("hot", now=t)
    inst.last_used = now
    one = gov._anon_resident_bytes(inst) + inst.metadata_bytes()
    acts = gov.step(now=now, budget_bytes=gov.governed_bytes() - one // 2)
    assert acts and acts[0].instance_id == zyg.instance_id
    assert mgr.instances["hot"].state is S.WARM


def test_charge_governor_off_exempts_zygote_bytes(tiny_factory, spool_dir):
    mgr = _mgr(tiny_factory, spool_dir, shared=False)
    mgr.zygotes.spawn(ARCH)
    charged = mgr.governor.governed_bytes()
    mgr.zygotes.cfg.charge_governor = False
    exempt = mgr.governor.governed_bytes()
    assert exempt < charged
    assert charged - exempt == mgr.zygotes.uncharged_bytes()


def test_reap_idle_retires_stale_donor(tiny_factory, spool_dir):
    import time as _time
    mgr = _mgr(tiny_factory, spool_dir, shared=False,
               zcfg=ZygoteConfig(retire_idle_s=5.0))
    zyg = mgr.zygotes.spawn(ARCH)
    assert mgr.zygotes.reap_idle(_time.monotonic() + 1.0) == []
    retired = mgr.zygotes.reap_idle(_time.monotonic() + 10.0)
    assert retired == [zyg.instance_id]
    assert zyg.instance_id not in mgr.instances


# ------------------------------------------------------------ fork storms
def test_fork_storm_dedups_to_one_fork(tiny_factory, spool_dir):
    """N concurrent first-requests of one unknown tenant share a single
    fork: one donor consumed, every caller gets the same instance."""
    mgr = _mgr(tiny_factory, spool_dir, shared=False)
    mgr.zygotes.spawn(ARCH)
    n = 6
    barrier = threading.Barrier(n)
    results = [None] * n

    def storm(i):
        barrier.wait()
        results[i] = mgr.fork_start("t", ARCH)

    threads = [threading.Thread(target=storm, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(r is results[0] and r is not None for r in results)
    assert mgr.forks_performed == 1
    assert mgr.forks_deduped == n - 1
    assert mgr.zygotes.stats() == {"spawned": 1, "forked": 1,
                                   "retired": 0, "live": 0}


# ------------------------------------------------------------ economics
def test_admissions_train_fork_gap_and_prefork(tiny_factory, spool_dir):
    """Cold starts and forks both feed the per-family admission EWMA
    (and the forecaster's synthetic stream); a family predicted due
    within the margin and missing a donor becomes a pre-fork candidate,
    and the daemon spawns it."""
    mgr = _mgr(tiny_factory, spool_dir, shared=False,
               zcfg=ZygoteConfig(prefork_margin_s=15.0),
               governor_policy=GovernorConfig(
                   forecast=ForecastConfig(season_period_s=100.0)))
    zp = mgr.zygotes
    for i, t in enumerate((0.0, 10.0, 20.0)):
        mgr.cold_start(f"t{i}", ARCH)
        mgr.evict(f"t{i}")
        zp.note_admission(ARCH, now=t)
    assert zp.predicted_fork_gap(ARCH, 25.0) <= 15.0
    assert mgr.governor.forecaster.stats()["observations"] >= 3
    daemon = ForecastDaemon(mgr)
    acted = daemon.step(25.0)
    assert daemon.preforked_zygotes == 1
    assert any(is_zygote_id(a) for a in acted)
    assert zp.has(ARCH)
    # cooldown: the next pass does not spawn a second donor
    assert daemon.step(26.0) == []
    # a family with no admission history predicts far away
    assert zp.predicted_fork_gap("arctic-480b", 25.0) \
        == zp.cfg.default_gap_s


def test_spawn_caps(tiny_factory, spool_dir):
    mgr = _mgr(tiny_factory, spool_dir, shared=False,
               zcfg=ZygoteConfig(per_family=1, max_total=2))
    assert mgr.zygotes.spawn(ARCH) is not None
    assert mgr.zygotes.spawn(ARCH) is None           # per-family cap
    assert mgr.zygotes.ensure(ARCH) is not None      # already live
    assert mgr.zygotes.spawn("mamba2-130m") is not None
    assert mgr.zygotes.spawn("arctic-480b") is None  # total cap
    assert mgr.zygotes.families() == {ARCH: 1, "mamba2-130m": 1}


# ------------------------------------------------------------ cluster
def test_placement_prefers_node_with_zygote(tiny_factory, spool_dir):
    """Zygote affinity: with equal headroom, a new tenant lands on (and
    forks from) the node advertising a live donor of its family."""
    def _node(nid):
        mcfg = ManagerConfig(spool_dir=os.path.join(spool_dir, nid),
                             store_salt=SALT,
                             zygote_pool=ZygoteConfig())
        return Node(nid, tiny_factory, spool_dir=spool_dir,
                    manager_cfg=mcfg)
    n0, n1 = _node("n0"), _node("n1")
    router = ClusterRouter([n0, n1])
    n1.manager.zygotes.spawn(ARCH)
    assert n1.zygote_families() == {ARCH: 1}
    assert n0.zygote_bytes(ARCH) == 0 < n1.zygote_bytes(ARCH)
    node = router.place("t", ARCH, now=0.0)
    assert node is n1
    assert n1.manager.forks_performed == 1
    assert any(e[1] == "place_fork" for e in router.log)
    router.close()


def test_chaos_node_death_mid_fork_storm_gc_clean(tiny_factory, spool_dir):
    """Kill a node mid-fork-storm: a fork writes nothing to the CAS
    store, so every store stays GC-clean (no orphans, no quarantine)
    and the tenant re-admits on the survivor."""
    policy = ClusterPolicy(replication_factor=2,
                           health=HealthPolicy(suspect_after_s=3.0,
                                               dead_after_s=10.0))

    def _node(nid):
        mcfg = ManagerConfig(spool_dir=os.path.join(spool_dir, nid),
                             store_salt=SALT,
                             zygote_pool=ZygoteConfig())
        return Node(nid, tiny_factory, spool_dir=spool_dir,
                    manager_cfg=mcfg)
    n0, n1 = _node("n0"), _node("n1")
    router = ClusterRouter([n0, n1], policy=policy)
    for n in (n0, n1):
        n.manager.zygotes.spawn(ARCH)
    barrier = threading.Barrier(4 + 1)

    def storm(i):
        barrier.wait()
        try:
            n0.manager.fork_start(f"t{i}", ARCH)
        except Exception:
            pass                      # racing the crash is the point
    threads = [threading.Thread(target=storm, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    barrier.wait()
    n0.kill()                         # mid-storm
    for t in threads:
        t.join()
    router.check_health(0.0)
    router.check_health(11.0)         # lease lapses -> DEAD -> recovery
    assert router.detector.is_dead("n0")
    for n in (n0, n1):
        assert n.store.orphan_digests(0.0) == []
        assert n.store.stats()["quarantined"] == 0
    # survivor still admits: its own donor serves the next new tenant
    node = router.place("fresh", ARCH, now=12.0)
    assert node is n1
    assert n1.manager.forks_performed == 1
    router.close()

"""Training substrate: optimizer math, learnability, checkpoints, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, tiny_config
from repro.data import DataConfig, SyntheticPipeline
from repro.models import model
from repro.training import (AdamWConfig, checkpoint, init_state,
                            make_train_step)


def test_adamw_against_manual():
    """One AdamW step vs a hand-computed update."""
    from repro.training import optim
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                      weight_decay=0.0, grad_clip=0.0, warmup_steps=0,
                      total_steps=10**9, min_lr_ratio=1.0)
    p = {"w": jnp.asarray([[1.0, 2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.5, -0.5]], jnp.float32)}
    st = init_state(p)
    p2, st2, m = optim.apply_updates(cfg, p, g, st)
    mh = 0.5 / 1.0                              # m/(1-b1^1) = 0.1*0.5/0.1
    vh = 0.25 / 1.0
    want = 1.0 - 0.1 * (0.1 * 0.5 / 0.1) / (np.sqrt(0.01 * 0.25 / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"])[0, 0], want, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clip():
    from repro.training import optim
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    _, _, m = optim.apply_updates(cfg, p, g, init_state(p))
    assert float(m["grad_norm"]) == pytest.approx(400.0)


def test_lr_schedule_shape():
    from repro.training.optim import lr_schedule
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_loss_decreases():
    cfg = tiny_config(get_config("llama3.2-3b"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=100)))
    st = init_state(params)
    pipe = SyntheticPipeline(DataConfig(cfg.vocab_size, 64, 8, seed=7))
    first = last = None
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        params, st, m = step(params, st, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.8, (first, last)


def test_remat_same_loss():
    cfg = tiny_config(get_config("yi-6b"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    pipe = SyntheticPipeline(DataConfig(cfg.vocab_size, 32, 2))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    l1, _ = model.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                          remat=False)
    l2, _ = model.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                          remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_config(get_config("hymba-1.5b"))
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params, step=42)
    p2, step = checkpoint.restore(path, params)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_is_shared_loader_source(tmp_path, tiny_factory):
    """A checkpoint doubles as the shared-weights 'backing file' (§3.5)."""
    cfg, params = tiny_factory("llama3.2-3b")
    path = str(tmp_path / "base")
    checkpoint.save(path, params)
    flat = checkpoint.load_flat(path)
    assert "embed" in flat and "layers/attn/wq" in flat


def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
    p1, p2 = SyntheticPipeline(cfg), SyntheticPipeline(cfg)
    b1, b2 = p1.batch(5), p2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(6)["tokens"], b1["tokens"])
    # labels are next-token shifted with -1 terminator
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()

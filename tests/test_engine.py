"""Serving engine integration: the paper's lifecycle end-to-end on real
models, including the correctness property that matters most — a
hibernate/wake cycle must not change what the model computes."""
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import ContainerState
from repro.serving import Request, ServingEngine
from repro.core.state import Rung

S = ContainerState

ARCHS = ["llama3.2-3b", "deepseek-v2-236b", "mamba2-130m", "hymba-1.5b",
         "whisper-large-v3", "llava-next-34b"]


def _engine(tiny_factory, spool_dir, wake_mode="reap"):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode=wake_mode),
        tiny_factory)
    return ServingEngine(mgr), mgr


def _req(cfg, iid, sid, toks, n=4, **kw):
    if cfg.frontend.kind == "vision":
        kw.setdefault("embeds", np.ones(
            (cfg.frontend.num_embeddings, cfg.frontend.embed_dim),
            np.float32))
    if cfg.is_encoder_decoder:
        kw.setdefault("frames", np.ones(
            (8, cfg.frontend.embed_dim), np.float32))
    return Request(iid, sid, np.asarray(toks, np.int32),
                   max_new_tokens=n, **kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_lifecycle_states(arch, tiny_factory, spool_dir):
    eng, mgr = _engine(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", arch)
    cfg = inst.cfg
    assert inst.state == S.WARM
    r1 = eng.handle(_req(cfg, "i0", "s0", [1, 2, 3]))
    assert (r1.state_before, r1.state_after) == ("warm", "warm")
    assert len(r1.tokens) == 4
    assert all(0 <= t < cfg.vocab_size for t in r1.tokens)
    mgr.descend("i0", Rung.HIBERNATED)
    assert inst.state == S.HIBERNATE
    assert inst.weight_bytes() == 0
    r2 = eng.handle(_req(cfg, "i0", "s1", [4, 5]))
    assert (r2.state_before, r2.state_after) == ("hibernate", "woken")
    r3 = eng.handle(_req(cfg, "i0", "s2", [6]))
    assert (r3.state_before, r3.state_after) == ("woken", "woken")


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-236b",
                                  "hymba-1.5b"])
@pytest.mark.parametrize("wake_mode", ["reap", "pagefault"])
def test_hibernation_does_not_change_outputs(arch, wake_mode, tiny_factory,
                                             spool_dir):
    """THE correctness property: tokens generated after a hibernate/wake
    cycle equal those of an instance that never hibernated — including a
    continuing session whose KV pages went through the swap files."""
    prompt1, prompt2 = [1, 2, 3, 4, 5], [7, 8]

    def run(hibernate: bool):
        eng, mgr = _engine(tiny_factory, spool_dir + f"/{hibernate}",
                           wake_mode)
        inst = eng.start_instance("i0", arch)
        r1 = eng.handle(_req(inst.cfg, "i0", "s", prompt1, n=3))
        if hibernate:
            eng.record_sample("i0", _req(inst.cfg, "i0", "probe", [9], n=2,
                                         close_session=True))
            mgr.descend("i0", Rung.HIBERNATED)
        r2 = eng.handle(_req(inst.cfg, "i0", "s", prompt2, n=4))
        return r1.tokens, r2.tokens

    base1, base2 = run(hibernate=False)
    hib1, hib2 = run(hibernate=True)
    assert base1 == hib1
    assert base2 == hib2, f"wake ({wake_mode}) changed generation"


def test_woken_memory_leq_warm(tiny_factory, spool_dir):
    """Fig. 7's Woken-up < Warm claim: after a REAP wake only the working
    set is resident."""
    eng, mgr = _engine(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "deepseek-v2-236b")
    cfg = inst.cfg
    warm_bytes = inst.weight_bytes() + inst.kv_bytes()
    eng.record_sample("i0", _req(cfg, "i0", "probe", [1, 2], n=2,
                                 close_session=True))
    mgr.descend("i0", Rung.HIBERNATED)
    hib_bytes = inst.weight_bytes() + inst.kv_bytes()
    eng.handle(_req(cfg, "i0", "s1", [3, 4], n=2, close_session=True))
    woken_bytes = inst.weight_bytes() + inst.kv_bytes()
    assert hib_bytes < 0.01 * warm_bytes
    assert woken_bytes <= warm_bytes


def test_continuous_batching(tiny_factory, spool_dir):
    eng, mgr = _engine(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "llama3.2-3b")
    cfg = inst.cfg
    reqs = [_req(cfg, "i0", f"s{j}", [j + 1, j + 2], n=2 + j) for j in range(3)]
    resps = eng.serve_batch("i0", reqs)
    for j, r in enumerate(resps):
        assert len(r.tokens) == 2 + j
    # batched decode must agree with serving each request alone
    eng2, _ = _engine(tiny_factory, spool_dir + "/solo")
    eng2.start_instance("i0", "llama3.2-3b")
    for j, r in enumerate(resps):
        solo = eng2.handle(_req(cfg, "i0", f"s{j}", [j + 1, j + 2], n=2 + j))
        assert solo.tokens == r.tokens


def test_reap_faults_fewer_than_pagefault(tiny_factory, spool_dir):
    """REAP wake needs (near) zero faults for a request matching the
    recorded sample; pagefault wake faults every touched unit."""
    results = {}
    for mode in ("reap", "pagefault"):
        eng, mgr = _engine(tiny_factory, spool_dir + f"/{mode}", mode)
        inst = eng.start_instance("i0", "llama3.2-3b")
        cfg = inst.cfg
        eng.record_sample("i0", _req(cfg, "i0", "probe", [1, 2, 3], n=2,
                                     close_session=True))
        mgr.descend("i0", Rung.HIBERNATED)
        r = eng.handle(_req(cfg, "i0", "s", [1, 2, 3], n=2,
                            close_session=True))
        results[mode] = r
    assert results["reap"].faults < results["pagefault"].faults
    assert results["reap"].prefetched_bytes > 0
    assert results["pagefault"].faulted_bytes > 0


def test_compiled_cache_survives_hibernation(tiny_factory, spool_dir):
    """The kept-alive 'blocked runtime threads': jitted executables must
    not be recompiled after a wake."""
    eng, mgr = _engine(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "llama3.2-3b")
    cfg = inst.cfg
    eng.handle(_req(cfg, "i0", "s0", [1, 2, 3], n=2, close_session=True))
    n_compiled = len(inst.compiled)
    mgr.descend("i0", Rung.HIBERNATED)
    eng.handle(_req(cfg, "i0", "s1", [4, 5, 6], n=2, close_session=True))
    assert len(inst.compiled) == n_compiled    # same shapes -> cache hits


def test_windowed_serving_matches_model(tiny_factory, spool_dir):
    """ServingEngine(window=W) must reproduce the model-level sliding-
    window decode exactly (the long_500k serving mode, CPU scale)."""
    import jax.numpy as jnp
    from repro.models import model

    W = 8
    cfg, params = tiny_factory("llama3.2-3b")
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]

    # engine path
    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir), tiny_factory)
    eng = ServingEngine(mgr, window=W)
    eng.start_instance("i0", "llama3.2-3b")
    got = eng.handle(Request("i0", "s", np.asarray(prompt, np.int32),
                             max_new_tokens=4)).tokens

    # model-level reference with windowed attention
    logits, cache = model.prefill(params, cfg, jnp.asarray([prompt]),
                                  max_len=64, window=W)
    want = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    for _ in range(3):
        logits, cache = model.decode_step(
            params, cfg, jnp.asarray([want[-1]]), cache, window=W)
        want.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
    assert got == want

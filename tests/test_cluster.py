"""Cluster fabric: migration protocol, dedup transfer, placement,
rebalance escalation, and in-flight-request handoff."""
import threading

import numpy as np
import pytest

from repro.cluster import ClusterPolicy, ClusterRouter, MigrationError, Node
from repro.cluster.migrate import migrate_instance
from repro.core.governor import GovernorConfig
from repro.core.state import ContainerState, Event, InvalidTransition
from repro.serving.paged_kv import PagedKVCache
from repro.core.state import Rung

S = ContainerState
ARCH = "llama3.2-3b"
SALT = b"cluster-test-salt"


def _cluster(tiny_factory, spool_dir, n=2, budget=None, policy=None,
             governor_cfg=None):
    nodes = [Node(f"n{i}", tiny_factory, spool_dir=spool_dir, salt=SALT,
                  budget_bytes=budget, governor_cfg=governor_cfg)
             for i in range(n)]
    return ClusterRouter(nodes, policy=policy), nodes


def _tenant(router, node, iid, arch=ARCH, seed=0, kv_tokens=48):
    """Start a tenant on a specific node (bypassing placement scoring),
    give it deterministic weights-from-factory plus synthetic KV, and a
    recorded working set — no jit compute involved."""
    router.placement[iid] = node.node_id
    router.arch_of[iid] = arch
    inst = node.manager.cold_start(iid, arch)
    inst.kv = PagedKVCache(iid, inst.cfg, node.manager.pool)
    rng = np.random.default_rng(seed)
    sess = inst.kv.new_session("ctx")
    for layer in range(inst.cfg.num_layers):
        inst.kv.write_tokens(
            "ctx", layer,
            rng.standard_normal((kv_tokens, inst.kv.token_elems)), 0)
    sess.num_tokens = kv_tokens
    sess.token_ids = list(range(kv_tokens))
    # working set: embed block 0 + layer-0 KV pages (critical-ish prefix)
    ws = [k for k in inst.units if k[1] == "embed" and k[2] == 0]
    ws += [("kv", "ctx", 0, p) for p in range(len(sess.pages[0]))]
    inst.recorder.start()
    inst.recorder.record_many(ws)
    inst.recorder.stop()
    return inst


def _snapshot(inst):
    """Byte-snapshot of every anon weight unit + all KV content."""
    weights = {p: a.copy() for p, a in inst.weights.items()
               if p not in inst.shared_paths}
    kv = {}
    for sid, sess in inst.kv.sessions.items():
        for layer in range(len(sess.pages)):
            kv[(sid, layer)] = inst.kv.read_tokens(sid, layer,
                                                   sess.num_tokens).copy()
    return weights, kv


def _full_wake(node, iid):
    inst = node.manager.instances[iid]
    node.manager.ensure_awake(iid)
    if inst.wake_pipeline is not None:
        inst.wake_pipeline.wait(60)
    inst.quiesce_bg()                 # partial wakes restore in background
    inst.ensure_all_resident()
    missing = inst.kv.nonresident_logical_keys()
    if missing:
        with inst.install_lock:
            inst.kv.fault_in(missing, inst.swap_file, inst.reap_file)
    return inst


def _assert_identical(inst, snap):
    weights, kv = snap
    for p, a in weights.items():
        np.testing.assert_array_equal(inst.weights[p], a, err_msg=p)
    for (sid, layer), a in kv.items():
        got = inst.kv.read_tokens(sid, layer, a.shape[0])
        np.testing.assert_array_equal(got, a, err_msg=f"{sid}/L{layer}")


# ------------------------------------------------------------- migration
@pytest.mark.parametrize("rung", ["hibernated", "partial", "mmap_clean"])
def test_migrate_then_wake_matches_in_place_wake(tiny_factory, spool_dir,
                                                 rung):
    """The acceptance property: for every migratable rung, migrate→wake
    restores exactly the bytes an in-place wake restores — the twin
    tenant (identical content, never migrated) is the reference."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=7)
    twin = _tenant(router, n0, "twin", seed=7)
    snap = _snapshot(inst)
    _assert_identical(twin, snap)              # twins really are twins

    for i in (inst, twin):
        nid = i.instance_id
        if rung == "hibernated":
            n0.manager.descend(nid, Rung.HIBERNATED)
        elif rung == "partial":
            victims = [t[2] for t in
                       n0.manager.governor._partial_candidates(i)][:6]
            n0.manager.descend(nid, Rung.PARTIAL, keys=victims)
        else:
            # no shared registry in this cluster: emulate the rung via
            # the state machine + flag, as the governor's mmap descent does
            i.sm.fire(Event.MMAP_DROP)
            i.mmap_dropped = True

    h = router.migrate("t0", "n1")
    assert h.ok, h.error
    assert "t0" not in n0.manager.instances
    assert n1.manager.instances["t0"].state == S.HIBERNATE

    moved = _full_wake(n1, "t0")
    ref = _full_wake(n0, "twin")
    _assert_identical(moved, snap)
    _assert_identical(ref, snap)
    if rung == "hibernated":
        # the twin's REAP file exists too: first-touch order survived the
        # move byte-for-byte (the streaming wake layout is intact)
        assert list(moved.reap_file.extents) == list(ref.reap_file.extents)
    router.close()


def test_dedup_transfer_ships_base_weights_once(tiny_factory, spool_dir):
    """Second same-deployment migration to a node is metadata+deltas:
    the base-weight digests are already in the target's CAS store."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    _tenant(router, n0, "t0", seed=1)
    _tenant(router, n0, "t1", seed=2)          # same arch, different KV
    n0.manager.descend("t0", Rung.HIBERNATED)
    n0.manager.descend("t1", Rung.HIBERNATED)

    h0 = router.migrate("t0", "n1")
    h1 = router.migrate("t1", "n1")
    assert h0.ok and h1.ok
    # first migration pays the base weights; the second rides its dedup
    assert h1.stats.bytes_shipped < 0.3 * h1.stats.full_snapshot_bytes
    assert h1.stats.bytes_shipped < h0.stats.bytes_shipped
    assert h1.stats.bytes_dedup > 0
    # both wake intact on the target
    for iid in ("t0", "t1"):
        inst = _full_wake(n1, iid)
        assert inst.state in (S.WOKEN, S.WARM, S.HIBERNATE)
    router.close()


def test_source_gc_after_migration_spares_survivors(tiny_factory,
                                                    spool_dir):
    """Migrating a tenant away releases its source store refs, but a
    surviving local tenant sharing base-weight segments stays readable
    and wakes bit-exact."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    _tenant(router, n0, "gone", seed=3)
    survivor = _tenant(router, n0, "stay", seed=4)
    snap = _snapshot(survivor)
    n0.manager.descend("gone", Rung.HIBERNATED)
    n0.manager.descend("stay", Rung.HIBERNATED)
    before = n0.store.live_bytes

    h = router.migrate("gone", "n1")
    assert h.ok
    # the migrated tenant's unique segments (its private KV) are gone,
    # shared base-weight segments the survivor references are not
    assert n0.store.live_bytes < before
    inst = _full_wake(n0, "stay")
    _assert_identical(inst, snap)
    router.close()


def test_migrating_state_is_fenced(tiny_factory, spool_dir):
    """Governor TERMINATED (EVICT) of a MIGRATING instance is illegal,
    and the governor's scoring never selects a MIGRATING tenant."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0")
    n0.manager.descend("t0", Rung.HIBERNATED)
    inst.sm.fire(Event.MIGRATE)                # fence without a transfer
    assert inst.state == S.MIGRATING
    with pytest.raises(InvalidTransition):
        inst.sm.fire(Event.EVICT)
    # a pressure pass must not touch (or crash on) the fenced tenant
    acts = n0.governor.step(now=1e6, budget_bytes=1)
    assert all(a.instance_id != "t0" for a in acts)
    assert inst.state == S.MIGRATING
    # migration of a migrating tenant is refused
    with pytest.raises(MigrationError):
        migrate_instance(n0, n1, "t0", ARCH)
    inst.sm.fire(Event.MIGRATE_ABORT)          # release the fence
    assert inst.state == S.HIBERNATE
    router.close()


def test_request_handoff_blocks_on_transfer(tiny_factory, spool_dir):
    """Requests racing a migration block on the transfer handle and get
    rerouted to the target — mirroring the shared wake pipeline."""
    from benchmarks.common import request_for

    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0")
    cfg = inst.cfg
    # serve once so compile caches exist (keeps the threaded phase fast)
    router.handle(request_for(cfg, "t0", "warmup", 8, 1, seed=0,
                              close_session=True))
    n0.manager.descend("t0", Rung.HIBERNATED)

    results, errors = [], []

    def client(k):
        try:
            results.append(router.handle(
                request_for(cfg, "t0", f"s{k}", 8, 1, seed=k,
                            close_session=True)))
        except BaseException as e:             # test capture: assert below
            errors.append(e)

    h = router.migrate("t0", "n1", block=False)
    threads = [threading.Thread(target=client, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    h.wait(60)
    for t in threads:
        t.join(60)
    assert h.ok, h.error
    assert not errors
    assert len(results) == 4
    # exactly one copy of the tenant exists, on the target
    assert "t0" not in n0.manager.instances
    assert "t0" in n1.manager.instances
    assert router.placement["t0"] == "n1"
    router.close()


# ------------------------------------------------------------- placement
def test_placement_prefers_digest_affinity(tiny_factory, spool_dir):
    """Equal budgets: the node already holding the deployment's base
    digests in its CAS store wins placement."""
    budget = 512 << 20
    router, (n0, n1) = _cluster(tiny_factory, spool_dir, budget=budget)
    seeded = _tenant(router, n1, "seed0", seed=5)
    n1.manager.descend("seed0", Rung.HIBERNATED)                # digests land in n1's store
    assert n1.store.live_bytes > 0
    now = 1.0
    # the seeded tenant's EWMA says "not due for ages" — n1's imminent
    # wake burden must not drown its affinity advantage
    n1.governor.observe_arrival("seed0", now=-2000.0)
    n1.governor.observe_arrival("seed0", now=-1000.0)
    s0 = router.placement_score(n0, ARCH, now)
    s1 = router.placement_score(n1, ARCH, now)
    assert s1 > s0
    node = router.place("fresh", ARCH, now=now)
    assert node is n1
    assert seeded.state == S.HIBERNATE
    router.close()


# ------------------------------------------------------------- rebalance
def _pressure_cluster(tiny_factory, spool_dir, policy):
    gov_cfg = GovernorConfig(terminate_idle_s=None)
    router, nodes = _cluster(tiny_factory, spool_dir, n=2, policy=policy,
                             governor_cfg=gov_cfg)
    n0, n1 = nodes
    for i in range(3):
        _tenant(router, n0, f"t{i}", seed=10 + i, kv_tokens=16)
        n0.manager.descend(f"t{i}", Rung.HIBERNATED)
    # budget holds two husks, not three: sustained breach on n0
    husk = n0.manager.instances["t0"].metadata_bytes()
    n0.governor.budget_bytes = int(2.5 * husk)
    n1.governor.budget_bytes = 64 << 20
    return router, n0, n1


def test_rebalance_migrates_before_terminating(tiny_factory, spool_dir):
    router, n0, n1 = _pressure_cluster(
        tiny_factory, spool_dir,
        ClusterPolicy(sustained_breach_rounds=2, migration=True))
    acts = router.rebalance(now=1000.0)
    # first breach: not sustained — no pressure action yet (anti-entropy
    # replication rides every round and is orthogonal to escalation)
    assert [a for a in acts if a[0] != "replicate"] == []
    acts = router.rebalance(now=1001.0)
    kinds = {a[0] for a in acts}
    assert "migrate" in kinds
    assert "terminate" not in kinds            # migration cleared pressure
    assert n0.pressure_bytes() <= 0
    assert len(n1.manager.instances) >= 1
    # every tenant still exists somewhere in the cluster
    alive = set(n0.manager.instances) | set(n1.manager.instances)
    assert alive == {"t0", "t1", "t2"}
    router.close()


def test_rebalance_without_migration_terminates(tiny_factory, spool_dir):
    """The no-migration baseline: a sustained breach with nowhere to go
    falls back to TERMINATED eviction — tenants are destroyed."""
    router, n0, n1 = _pressure_cluster(
        tiny_factory, spool_dir,
        ClusterPolicy(sustained_breach_rounds=2, migration=False))
    router.rebalance(now=1000.0)
    acts = router.rebalance(now=1001.0)
    kinds = {a[0] for a in acts}
    assert kinds == {"terminate"}
    alive = set(n0.manager.instances) | set(n1.manager.instances)
    assert len(alive) < 3                      # somebody died
    router.close()


# ------------------------------------------------------------- recorder
def test_migration_prunes_dead_miss_counters(tiny_factory, spool_dir):
    """The coldness dict ships pruned: keys of closed/trimmed sessions
    must not leak onto the target (the prune_misses migration-path fix)."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=6)
    dead = ("kv", "long-closed-session", 3, 9)
    live_w = next(iter(inst.units))
    inst.recorder.note_misses([dead, live_w])
    n0.manager.descend("t0", Rung.HIBERNATED)
    assert dead in inst.recorder.misses or True  # may be pruned by deflate
    inst.recorder.misses[dead] = 5             # force the leak candidate
    h = router.migrate("t0", "n1")
    assert h.ok
    moved = n1.manager.instances["t0"]
    assert dead not in moved.recorder.misses
    assert moved.recorder.miss_count(live_w) >= 1
    router.close()


# ------------------------------------------------------------- damping
def test_migration_cooldown_damps_ping_pong(tiny_factory, spool_dir):
    """A tenant that just migrated is not a victim again until the
    cooldown expires — the oscillation damper."""
    router, n0, n1 = _pressure_cluster(
        tiny_factory, spool_dir,
        ClusterPolicy(sustained_breach_rounds=1, migration=True,
                      migration_cooldown_s=1e9,
                      terminate_last_resort=False))
    for iid in ("t0", "t1", "t2"):           # all migrated "just now"
        router._cooldown[iid] = 999.0
    acts = router.rebalance(now=1000.0)
    assert not any(a[0] == "migrate" for a in acts)
    assert router.cooldown_skips >= 1
    assert set(n0.manager.instances) == {"t0", "t1", "t2"}  # nobody moved
    st = router.migration_stats()
    assert st["cooldown_skips"] == router.cooldown_skips
    assert st["tenants_in_cooldown"] == 3
    assert st["migration_cooldown_s"] == 1e9

    # cooldown expired: the same pressure now escalates to migration,
    # and the fresh migrant re-enters cooldown
    acts = router.rebalance(now=1000.0 + 2e9)
    moved = [a for a in acts if a[0] == "migrate"]
    assert moved
    assert router._cooldown[moved[0][1]] == 1000.0 + 2e9
    router.close()


def test_breach_hysteresis_preserves_streak(tiny_factory, spool_dir):
    """Pressure clearing *within* the hysteresis margin must not reset
    the sustained-breach streak — hovering at the budget edge stays
    'hot' and escalates on the next breach."""
    router, n0, n1 = _pressure_cluster(
        tiny_factory, spool_dir,
        ClusterPolicy(sustained_breach_rounds=2, migration=True,
                      breach_hysteresis=0.5, migration_cooldown_s=0.0))
    def pressure_acts(now):
        # anti-entropy replication rides every round; only pressure
        # actions (migrate/terminate) are under test here
        return [a for a in router.rebalance(now=now)
                if a[0] != "replicate"]

    tight = n0.governor.budget_bytes
    assert pressure_acts(1.0) == []              # breach: streak 1
    # clear the breach by a sliver — far inside the 50% margin
    n0.governor.budget_bytes = int(tight * 1.3)
    assert pressure_acts(2.0) == []              # streak survives
    assert router._breach["n0"] == 1
    n0.governor.budget_bytes = tight
    acts = router.rebalance(now=3.0)             # streak 2: escalate
    assert any(a[0] == "migrate" for a in acts)
    assert router.migration_stats()["breach_hysteresis"] == 0.5
    router.close()


def test_transfer_failure_blacklists_target_and_retries(
        tiny_factory, spool_dir, monkeypatch):
    """A failed transfer blacklists its target and tries the next-best
    peer (bounded); a sick node can't absorb every rebalance round."""
    import repro.cluster.router as router_mod
    gov_cfg = GovernorConfig(terminate_idle_s=None)
    router, nodes = _cluster(
        tiny_factory, spool_dir, n=3, governor_cfg=gov_cfg,
        policy=ClusterPolicy(sustained_breach_rounds=1,
                             migration_cooldown_s=0.0,
                             migration_retries=2,
                             terminate_last_resort=False))
    n0 = nodes[0]
    for i in range(3):
        _tenant(router, n0, f"t{i}", seed=20 + i, kv_tokens=16)
        n0.manager.descend(f"t{i}", Rung.HIBERNATED)
    husk = n0.manager.instances["t0"].metadata_bytes()
    n0.governor.budget_bytes = int(2.5 * husk)
    for node in nodes[1:]:
        node.governor.budget_bytes = 64 << 20

    def always_fails(src, dst, iid, arch, **kw):
        err = MigrationError("injected: target disk full")
        err.handle = object()                # transfer, not fence refusal
        raise err

    monkeypatch.setattr(router_mod, "migrate_instance", always_fails)
    acts = router.rebalance(now=1000.0)
    assert not any(a[0] == "migrate" for a in acts)
    # every peer was tried, failed, and blacklisted
    assert router.migration_retries >= 2
    assert set(router._blacklist) == {"n1", "n2"}
    assert all(until == 1000.0 + router.policy.blacklist_cooldown_s
               for until in router._blacklist.values())
    assert set(n0.manager.instances) == {"t0", "t1", "t2"}
    assert "retries" in router.migration_stats()
    router.close()

"""Cluster-wide resident KV prefix registry: COW adoption semantics.

The registry's contract, in order of how expensive a violation is:

  * adopted decode is BIT-EXACT vs recomputing the prefill privately —
    the whole point of sharing is that nobody can tell;
  * the never-overwrite discipline: a sharer writing past the prefix
    breaks COW to a private copy, the registry page stays pristine;
  * refcount sanity: deflating/terminating one sharer never frees or
    double-counts pages another sharer (or the registry) still maps;
  * last-sharer-down spills to the CAS store and revives by digest;
  * migration ships records + segments, the target rebuilds by digest.
"""
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.prefix import PREFIX_OWNER
from repro.core.state import Rung
from repro.serving.engine import Request, ServingEngine

ARCH = "llama3.2-3b"
PROMPT = list(range(100, 140))


@pytest.fixture()
def eng(tiny_factory, spool_dir):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"), tiny_factory)
    return ServingEngine(mgr), mgr


def _prefill(eng, iid, sid, prompt=PROMPT, n=4):
    return eng.handle(Request(iid, sid, np.asarray(prompt, np.int32),
                              max_new_tokens=n))


# ---------------------------------------------------------------- adoption
def test_register_then_adopt_same_tenant(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    assert not r1.adopted_prefix            # first prefill registers
    reg = mgr.prefix_registry
    assert reg.stats()["registrations"] == 1
    r2 = _prefill(eng, "t0", "s1")
    assert r2.adopted_prefix                # second session adopts
    assert r2.tokens == r1.tokens           # bit-exact, no forward pass


def test_cross_tenant_adoption_bit_exact(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    eng.start_instance("t1", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    r2 = _prefill(eng, "t1", "sX")
    assert r2.adopted_prefix and r2.tokens == r1.tokens
    # and the decode continuation stays identical
    c1 = eng.handle(Request("t0", "s0", [7], max_new_tokens=4))
    c2 = eng.handle(Request("t1", "sX", [7], max_new_tokens=4))
    assert c1.tokens == c2.tokens


def test_adoption_partitions_on_arch(eng):
    """Different arch => different weights => the digest must not match."""
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    eng.start_instance("t1", "yi-6b")
    _prefill(eng, "t0", "s0")
    r = _prefill(eng, "t1", "sX")
    assert not r.adopted_prefix


def test_short_prompts_never_register(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    _prefill(eng, "t0", "s0", prompt=[1, 2])   # < prefix_min_tokens
    assert mgr.prefix_registry.stats()["registrations"] == 0


def test_prefix_sharing_off_is_inert(tiny_factory, spool_dir):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, prefix_sharing=False),
        tiny_factory)
    eng = ServingEngine(mgr)
    assert mgr.prefix_registry is None
    eng.start_instance("t0", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    r2 = _prefill(eng, "t0", "s1")
    assert not r2.adopted_prefix and r2.tokens == r1.tokens


# ---------------------------------------------------------- COW discipline
def test_donor_divergence_leaves_registry_pristine(eng):
    """The donor keeps decoding (writes the shared last page -> COW
    break); a later adopter must still see the original prefill."""
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    eng.handle(Request("t0", "s0", [7], max_new_tokens=6))  # diverge donor
    r2 = _prefill(eng, "t0", "s1")           # adopt AFTER divergence
    assert r2.adopted_prefix and r2.tokens == r1.tokens


def test_sharers_decode_independently(eng):
    """Three sharers of one prefix each continue with different suffixes;
    each trajectory equals the same suffix run privately."""
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    _prefill(eng, "t0", "s0")
    _prefill(eng, "t0", "s1")
    _prefill(eng, "t0", "s2")
    outs = [eng.handle(Request("t0", f"s{i}", [7 + i],
                               max_new_tokens=4)).tokens for i in range(3)]
    # private replay on a prefix-sharing-off twin
    mgr2 = InstanceManager(
        ManagerConfig(spool_dir=mgr.cfg.spool_dir + "_twin",
                      prefix_sharing=False), mgr.factory)
    eng2 = ServingEngine(mgr2)
    eng2.start_instance("t0", ARCH)
    for i in range(3):
        _prefill(eng2, "t0", f"s{i}")
    outs2 = [eng2.handle(Request("t0", f"s{i}", [7 + i],
                                 max_new_tokens=4)).tokens
             for i in range(3)]
    assert outs == outs2


# ---------------------------------------------------------- refcount/spill
def test_refcounts_balance_after_close_and_trim(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    _prefill(eng, "t0", "s0")
    _prefill(eng, "t0", "s1")
    inst = mgr.instances["t0"]
    for sid in ("s0", "s1"):
        eng.handle(Request("t0", sid, [3], max_new_tokens=1,
                           close_session=True))
    inst.kv.trim()
    # last sharer down: the entry spilled to the CAS tier
    st = mgr.prefix_registry.stats()
    assert st["entries"] == 1 and st["resident_entries"] == 0
    assert mgr.pool.rss_bytes("t0") == 0
    assert mgr.pool.pss_bytes(PREFIX_OWNER) == 0


def test_spill_and_revive_by_digest(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    inst = mgr.instances["t0"]
    eng.handle(Request("t0", "s0", [3], max_new_tokens=1,
                       close_session=True))
    inst.kv.trim()
    reg = mgr.prefix_registry
    assert reg.stats()["resident_entries"] == 0
    r2 = _prefill(eng, "t0", "sNew")         # revives from CAS, no prefill
    assert r2.adopted_prefix and r2.tokens == r1.tokens
    assert reg.stats()["revives"] == 1


def test_governor_spills_unmapped_prefix_first(eng):
    """Both sharers hibernated => the registry copy is governor-spillable
    without touching either tenant; wakes reattach by digest."""
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    eng.start_instance("t1", ARCH)
    _prefill(eng, "t0", "s0")
    r2 = _prefill(eng, "t1", "sX")
    assert r2.adopted_prefix
    for iid in ("t0", "t1"):
        eng.record_sample(iid, Request(iid, "p", [9], max_new_tokens=1,
                                       close_session=True))
        mgr.descend(iid, Rung.HIBERNATED)
    reg = mgr.prefix_registry
    cands = reg.spill_candidates()
    assert cands, "no resident sharers -> must be spillable"
    assert reg.spill(cands[0][1]) > 0
    mgr.ensure_awake("t0")
    mgr.ensure_awake("t1")
    c1 = eng.handle(Request("t0", "s0", [5], max_new_tokens=4))
    c2 = eng.handle(Request("t1", "sX", [5], max_new_tokens=4))
    assert c1.tokens == c2.tokens


def test_deflating_one_sharer_never_swaps_prefix_pages(eng):
    """Hibernating a sharer must not export the registry's pages to its
    swap tier (they are shared, not private state) nor disturb the other
    sharer's decode.

    Uses a page-aligned prompt: a partial last prefix page is COW-broken
    by the first decode write and becomes legitimately-private state."""
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    eng.start_instance("t1", ARCH)
    aligned = list(range(100, 164))          # exactly one 64-token page
    r1 = _prefill(eng, "t0", "s0", prompt=aligned)
    _prefill(eng, "t1", "sX", prompt=aligned)
    eng.record_sample("t1", Request("t1", "p", [9], max_new_tokens=1,
                                    close_session=True))
    st = mgr.descend("t1", Rung.HIBERNATED)
    inst1 = mgr.instances["t1"]
    # no ("kv", "sX", ...) page within the prefix range went to any tier
    n_prefix = inst1.kv._n_pages(inst1.kv.sessions["sX"].prefix_tokens)
    spilled = [k for k in list(inst1.swap_file.extents)
               + list(inst1.reap_file.extents)
               if k[0] == "kv" and k[1] == "sX" and k[3] < n_prefix]
    assert not spilled, spilled
    # the awake sharer still decodes off the registry pages
    c1 = eng.handle(Request("t0", "s0", [7], max_new_tokens=4))
    mgr.ensure_awake("t1")
    c2 = eng.handle(Request("t1", "sX", [7], max_new_tokens=4))
    assert c1.tokens == c2.tokens


def test_evicting_a_sharer_keeps_survivors_intact(eng):
    eng, mgr = eng
    eng.start_instance("t0", ARCH)
    eng.start_instance("t1", ARCH)
    r1 = _prefill(eng, "t0", "s0")
    _prefill(eng, "t1", "sX")
    mgr.evict("t1")
    c1 = eng.handle(Request("t0", "s0", [7], max_new_tokens=4))
    # replay privately to prove the pages were not clobbered
    mgr2 = InstanceManager(
        ManagerConfig(spool_dir=mgr.cfg.spool_dir + "_twin",
                      prefix_sharing=False), mgr.factory)
    eng2 = ServingEngine(mgr2)
    eng2.start_instance("t0", ARCH)
    _prefill(eng2, "t0", "s0")
    c2 = eng2.handle(Request("t0", "s0", [7], max_new_tokens=4))
    assert c1.tokens == c2.tokens


# ---------------------------------------------------------------- registry
def test_digest_is_salted_and_exact_matched(eng):
    eng, mgr = eng
    reg = mgr.prefix_registry
    d1 = reg.digest_of(ARCH, PROMPT)
    assert d1 != reg.digest_of(ARCH, PROMPT[:-1] + [999])
    assert d1 != reg.digest_of("other-arch", PROMPT)
    # a different deployment salt yields unrelated digests
    from repro.core.prefix import PrefixRegistry
    other = PrefixRegistry(mgr.pool, None, salt=b"y" * 16)
    assert other.digest_of(ARCH, PROMPT) != d1


def test_registry_uses_store_digest_discipline(eng):
    eng, mgr = eng
    reg = mgr.prefix_registry
    buf = ARCH.encode() + b"\x00" + \
        np.asarray(PROMPT, np.int64).tobytes()
    assert reg.digest_of(ARCH, PROMPT) == mgr.store.keyed_digest(buf)


def test_resident_bytes_counts_shared_pages_once(tiny_factory, spool_dir):
    """N adopters of one prefix must not multiply the node's governed
    bytes: PSS accounting splits each shared page across its mappers, so
    growth under sharing is far below an identical sharing-off run."""
    long_prompt = list(range(1, 161))        # 2.5 pages of prefix

    def grow(share, tag):
        mgr = InstanceManager(
            ManagerConfig(spool_dir=spool_dir + tag, prefix_sharing=share),
            tiny_factory)
        eng2 = ServingEngine(mgr)
        eng2.start_instance("t0", ARCH)
        _prefill(eng2, "t0", "s0", prompt=long_prompt)
        base = mgr.resident_bytes()
        for i in range(1, 5):
            _prefill(eng2, "t0", f"s{i}", prompt=long_prompt)
        return mgr.resident_bytes() - base

    shared, private = grow(True, "_on"), grow(False, "_off")
    assert shared < private / 2, (shared, private)

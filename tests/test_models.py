"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, tiny_config
from repro.models import model

FULL_DIMS = {
    # spot-check the assigned full configs are exactly as specified
    "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = FULL_DIMS[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V)
    assert cfg.citation


def _inputs(cfg, B=2, S=16, seed=1):
    rng = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend.kind == "vision":
        kw["embeds"] = jnp.ones((B, cfg.frontend.num_embeddings,
                                 cfg.frontend.embed_dim), jnp.float32)
    if cfg.is_encoder_decoder:
        kw["enc_frames"] = jnp.ones((B, 8, cfg.frontend.embed_dim),
                                    jnp.float32)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, tiny_factory):
    """Reduced variant: one forward; shapes + finiteness."""
    cfg, params = tiny_factory(arch)
    tokens, kw = _inputs(cfg)
    logits, aux = model.logits_full(params, cfg, tokens, **kw)
    S_out = tokens.shape[1] + (kw["embeds"].shape[1]
                               if "embeds" in kw else 0)
    assert logits.shape == (2, S_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, tiny_factory):
    """Reduced variant: one train step on CPU; finite loss + grads applied."""
    from repro.training import AdamWConfig, init_state, make_train_step
    cfg, params = tiny_factory(arch)
    tokens, kw = _inputs(cfg, B=2, S=16)
    labels = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if "embeds" in kw:
        batch["embeds"] = kw["embeds"]
    if "enc_frames" in kw:
        batch["frames"] = kw["enc_frames"]     # train batches use "frames"
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=True)
    p2, st, m = step(params, init_state(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    changed = jax.tree.map(
        lambda a, b: bool((np.asarray(a) != np.asarray(b)).any()),
        params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "chatglm3-6b",
                                  "deepseek-v2-236b", "mamba2-130m",
                                  "hymba-1.5b", "whisper-large-v3"])
def test_decode_matches_full_forward(arch, tiny_factory):
    """prefill + decode_step logits == full-forward logits at each pos."""
    cfg, params = tiny_factory(arch)
    B, S = 1, 10
    tokens, kw = _inputs(cfg, B=B, S=S, seed=3)
    full_logits, _ = model.logits_full(params, cfg, tokens, **kw)

    pre, cache = model.prefill(
        params, cfg, tokens[:, :6], max_len=32,
        embeds=kw.get("embeds"), enc_frames=kw.get("enc_frames"))
    fe = kw["embeds"].shape[1] if "embeds" in kw else 0
    np.testing.assert_allclose(np.asarray(pre),
                               np.asarray(full_logits[:, fe + 5]),
                               rtol=2e-3, atol=2e-3)
    for t in range(6, S):
        logits, cache = model.decode_step(params, cfg, tokens[:, t], cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, fe + t]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer():
    """Ring-buffered cache (long_500k dense variant): decode with a window
    smaller than the generated length equals windowed full attention."""
    cfg = tiny_config(get_config("llama3.2-3b"))
    W = 8
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    S = 20
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                                cfg.vocab_size)
    # reference: full forward with window
    x, _, _ = model.forward_hidden(params, cfg, tokens, window=W)
    ref = model.unembed(params, cfg, x[:, -1])
    # ring decode: cache only W slots
    _, cache = model.prefill(params, cfg, tokens[:, :1], max_len=W, window=W)
    logits = None
    for t in range(1, S):
        logits, cache = model.decode_step(params, cfg, tokens[:, t], cache,
                                          window=W)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_moe_router_counts():
    cfg = tiny_factory_cfg = tiny_config(get_config("arctic-480b"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    _, aux = model.logits_full(params, cfg, tokens)
    counts = np.asarray(aux["expert_counts"])     # (L, E)
    assert counts.shape == (cfg.num_layers, cfg.moe.num_experts)
    # every token routed top_k times per layer
    assert (counts.sum(-1) == 2 * 8 * cfg.moe.top_k).all()


def test_param_count_sanity():
    """Analytic param_count tracks the real leaf count (±20%: the analytic
    form skips norms/biases)."""
    for arch in ("llama3.2-3b", "mamba2-130m", "deepseek-v2-236b"):
        cfg = tiny_config(get_config(arch))
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        real = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        # padded vocab inflates embed; compare order of magnitude
        est = cfg.param_count()
        assert 0.5 < est / real < 2.0, (arch, est, real)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.page_copy import ops as pc_ops, ref as pc_ref
from repro.kernels.paged_attention import ops as pa_ops, ref as pa_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# page_copy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("P,R,n", [(16, 1, 4), (64, 4, 64), (8, 2, 8)])
def test_page_gather(P, R, n, dtype):
    pool = jnp.asarray(RNG.integers(-100, 100, (P, R, 128)), dtype)
    idx = jnp.asarray(RNG.integers(0, P, (n,)), jnp.int32)
    out = pc_ops.gather_pages(pool, idx)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(pc_ref.gather_pages(pool, idx)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,R,n", [(16, 1, 4), (32, 4, 17)])
def test_page_scatter(P, R, n, dtype):
    pool = jnp.asarray(RNG.standard_normal((P, R, 128)), dtype)
    idx = jnp.asarray(RNG.choice(P, n, replace=False), jnp.int32)
    buf = jnp.asarray(RNG.standard_normal((n, R, 128)), dtype)
    expect = pc_ref.scatter_pages(pool, idx, buf)
    out = pc_ops.scatter_pages(pool, idx, buf)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_page_roundtrip_flat():
    pool = jnp.asarray(RNG.standard_normal((32, 512)), jnp.float32)
    expect = np.asarray(pool)                 # scatter donates the pool
    idx = jnp.asarray([3, 9, 27], jnp.int32)
    buf = pc_ops.gather_pages(pool, idx)
    out = pc_ops.scatter_pages(pool, idx, buf)       # scatter back = identity
    np.testing.assert_array_equal(np.asarray(out), expect)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,T,pps", [
    (2, 8, 2, 16, 4),      # GQA 4:1
    (1, 4, 4, 8, 3),       # MHA
    (3, 16, 2, 32, 2),     # GQA 8:1
    (2, 7, 1, 16, 5),      # odd head count (hymba-like 7:1)
])
def test_paged_attention_sweep(B, H, Hkv, T, pps, dtype):
    D, P = 128, 64
    q = jnp.asarray(RNG.standard_normal((B, H, D)), dtype)
    kp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), dtype)
    vp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), dtype)
    pt = jnp.asarray(RNG.integers(0, P, (B, pps)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, pps * T + 1, (B,)), jnp.int32)
    out = pa_ops.paged_decode_attention(q, kp, vp, pt, lengths)
    exp = pa_ref.paged_decode_attention(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [4, 12, 100])
def test_paged_attention_window(window):
    B, H, Hkv, D, T, pps, P = 2, 8, 2, 128, 8, 4, 32
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), jnp.float32)
    pt = jnp.asarray(RNG.integers(0, P, (B, pps)), jnp.int32)
    lengths = jnp.asarray([5, 30], jnp.int32)
    out = pa_ops.paged_decode_attention(q, kp, vp, pt, lengths,
                                        window=window)
    exp = pa_ref.paged_decode_attention(q, kp, vp, pt, lengths,
                                        window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_matches_dense_decode():
    """Against the engine's dense decode_attention on the same logical
    cache — the kernel and the engine must agree."""
    from repro.models.attention import decode_attention
    B, H, Hkv, D, T, pps = 2, 8, 4, 128, 16, 4
    S = pps * T
    P = 32
    kp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((Hkv, P, T, D)), jnp.float32)
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    pt = jnp.asarray(RNG.integers(0, P, (B, pps)), jnp.int32)
    lengths = jnp.asarray([S - 3, 20], jnp.int32)
    k_d = kp[:, pt].transpose(1, 2, 3, 0, 4).reshape(B, S, Hkv, D)
    v_d = vp[:, pt].transpose(1, 2, 3, 0, 4).reshape(B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = decode_attention(q, k_d, v_d, pos, lengths)
    paged = pa_ops.paged_decode_attention(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 64, 4, 32, 16, 16),
    (1, 37, 2, 64, 8, 16),      # ragged: S % Q != 0
    (2, 128, 3, 16, 32, 32),
    (1, 16, 1, 128, 128, 16),   # full mamba2 state size
])
def test_ssd_scan_sweep(B, S, H, P, N, Q, dtype):
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)) * 0.5, dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    D = jnp.asarray(RNG.standard_normal((H,)), jnp.float32)
    y, h = ssd_ops.ssd(x, dt, A, Bm, Cm, D, chunk_size=Q)
    ye, he = ssd_ref.ssd(x, dt, A, Bm, Cm, D, chunk_size=Q)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), **tol)


def test_ssd_scan_state_chaining():
    """Scanning two halves with carried state == scanning the whole."""
    B, S, H, P, N, Q = 1, 64, 2, 32, 16, 16
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, jnp.float32)
    D = jnp.zeros((H,), jnp.float32)
    y_full, h_full = ssd_ops.ssd(x, dt, A, Bm, Cm, D, chunk_size=Q)
    y1, h1 = ssd_ops.ssd(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                         D, chunk_size=Q)
    y2, h2 = ssd_ops.ssd(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                         D, chunk_size=Q, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_block():
    """The kernel path must agree with the model's ssm_forward math on the
    exact contraction it replaces."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N, Q = 2, 48, 4, 32, 16, 16
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.05, 0.3, (B, S, H)), jnp.float32)
    A = jnp.asarray([-1.0, -0.5, -2.0, -1.5], jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal((H,)), jnp.float32)
    y_k, h_k = ssd_ops.ssd(x, dt, A, Bm, Cm, D, chunk_size=Q)
    y_m, h_m = ssd_chunked(x, dt, A, Bm, Cm, D, chunk_size=Q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=1e-4, atol=1e-4)

"""Hypothesis property tests for the content-addressed SwapStore.

Split from test_swap_store.py because importorskip at module level skips
the whole module on minimal installs — the deterministic store tests must
always run.
"""
import numpy as np
import pytest

from repro.core.store import StorePolicy, SwapStore

hypothesis = pytest.importorskip("hypothesis")  # optional dep
from hypothesis import given, settings, strategies as st  # noqa: E402


def _rand(n, seed=0, dtype=np.float32):
    return np.random.default_rng(seed).standard_normal(n).astype(dtype)

_dtypes = st.sampled_from([np.float32, np.int32, np.uint8, np.float64])


@st.composite
def _unit(draw):
    n = draw(st.integers(0, 300))
    dtype = draw(_dtypes)
    kind = draw(st.sampled_from(["random", "constant", "structured"]))
    if kind == "constant":
        return np.full((n,), draw(st.integers(0, 100))).astype(dtype)
    if kind == "structured":
        return np.tile(np.arange(max(n // 8, 1)), 8)[:n].astype(dtype)
    return np.random.default_rng(draw(st.integers(0, 9))) \
        .integers(-1000, 1000, n).astype(dtype)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b"]),
                          st.integers(0, 10), _unit()),
                min_size=1, max_size=30))
def test_property_store_roundtrip(tmp_path_factory, ops):
    """Interleaved writes/overwrites across two tenants, with aggressive
    compression and tiny elision threshold: every key reads back exactly
    the last array written to it."""
    d = tmp_path_factory.mktemp("cas")
    s = SwapStore(str(d / "x.cas"), salt=b"prop",
                  policy=StorePolicy(tiers=((0, 9),), min_size=8))
    try:
        expect = {}
        for owner, key, arr in ops:
            s.client(owner).write_unit(key, arr)
            expect[(owner, key)] = arr
        for (owner, key), arr in expect.items():
            got = s.client(owner).read_unit(key)
            assert got.dtype == arr.dtype and got.shape == arr.shape
            np.testing.assert_array_equal(got, arr)
        # invariant: live accounting matches the metadata tables
        stats = s.stats()
        assert stats["unique_bytes"] <= stats["logical_bytes"]
        assert stats["stored_bytes"] <= stats["unique_bytes"]
    finally:
        s.close()


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=12),
       st.integers(0, 4))
def test_property_gc_keeps_survivors_intact(tmp_path_factory, payload_ids,
                                            n_evict):
    """Random sharing topology: evict a random subset of tenants; every
    surviving tenant still reads every unit bit-exact, and fully-orphaned
    segments are gone."""
    d = tmp_path_factory.mktemp("gc")
    s = SwapStore(str(d / "x.cas"), salt=b"gc")
    try:
        payloads = {i: _rand(200 + i, seed=i) for i in set(payload_ids)}
        tenants = [f"t{i}" for i in range(4)]
        written = {t: {} for t in tenants}
        for j, pid in enumerate(payload_ids):
            t = tenants[j % len(tenants)]
            s.client(t).write_unit(("u", j), payloads[pid])
            written[t][("u", j)] = payloads[pid]
        evicted = tenants[:n_evict]
        for t in evicted:
            s.release(s.client(t))
        for t in tenants[n_evict:]:
            for key, arr in written[t].items():
                np.testing.assert_array_equal(s.client(t).read_unit(key),
                                              arr)
        live_digests = {m.digest for t in tenants[n_evict:]
                        for m in s.client(t).extents.values()
                        if m.digest is not None}
        assert set(s._segments) == live_digests
    finally:
        s.close()

"""Network front door: HTTP/1.1 chunked streaming, WebSocket framing,
429 backpressure, SLO-classed admission and shedding."""
import http.client
import json
import socket
import threading

import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import ContainerState, Rung
from repro.serving import (AsyncPlatform, Backpressure, FrontDoor,
                           FrontDoorPolicy, Gateway, PlatformPolicy,
                           ServingEngine)
from repro.serving.engine import SLO_BATCH, SLO_INTERACTIVE, Request
from repro.serving.gateway import (ws_client_handshake, ws_client_recv,
                                   ws_client_send)

S = ContainerState
ARCH_OF = {"fn-a": "llama3.2-3b", "fn-b": "mamba2-130m"}


def _mk_stack(tiny_factory, spool_dir, *, workers=2, plat_policy=None,
              door_policy=None):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"), tiny_factory)
    eng = ServingEngine(mgr)
    plat = AsyncPlatform(eng, plat_policy or PlatformPolicy(keep_warm_s=1e9),
                         ARCH_OF, workers=workers)
    door = FrontDoor(plat, policy=door_policy)
    return mgr, eng, plat, door


def _post(addr, spec, timeout=60.0):
    """POST /v1/generate, reading the NDJSON stream line by line.
    Returns (status, headers, [parsed lines])."""
    conn = http.client.HTTPConnection(*addr, timeout=timeout)
    try:
        conn.request("POST", "/v1/generate", body=json.dumps(spec),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        lines = []
        while True:
            ln = resp.readline()
            if not ln:
                break
            lines.append(json.loads(ln))
        return resp.status, dict(resp.getheaders()), lines
    finally:
        conn.close()


# ----------------------------------------------------------------- http
def test_http_streams_tokens_then_done(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir)
    with plat, Gateway(door) as gw:
        status, headers, lines = _post(gw.address, {
            "tenant": "fn-a", "session": "s0", "prompt": [1, 2, 3],
            "max_new_tokens": 4, "arch": "llama3.2-3b"})
    assert status == 200
    assert headers.get("Content-Type") == "application/x-ndjson"
    toks = [ln["token"] for ln in lines if "token" in ln]
    done = lines[-1]
    assert done.get("done") is True and done.get("tokens") == len(toks)
    assert len(toks) == 4
    assert done["state_before"] in ("cold", "warm")
    assert done["ttft_ms"] is not None and done["ttft_ms"] >= 0
    st = door.stats()
    assert st["completed"] == 1 and st["active_sessions"] == 0


def test_http_streaming_ttft_tracks_first_token(tiny_factory, spool_dir):
    """The first NDJSON line must arrive before the request finishes —
    i.e. streaming is per-token, not buffered-until-done."""
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir)
    with plat, Gateway(door) as gw:
        conn = http.client.HTTPConnection(*gw.address, timeout=60.0)
        try:
            conn.request("POST", "/v1/generate", body=json.dumps({
                "tenant": "fn-a", "session": "s0", "prompt": [1, 2, 3],
                "max_new_tokens": 6, "arch": "llama3.2-3b"}))
            resp = conn.getresponse()
            first = json.loads(resp.readline())
            assert "token" in first          # a token precedes done
            rest = [json.loads(ln) for ln in resp.readlines() if ln.strip()]
        finally:
            conn.close()
    assert rest[-1].get("done") is True


def test_http_429_backpressure_with_retry_after(tiny_factory, spool_dir):
    """Overload is an HTTP status with an honest hint, not a queue."""
    mgr, eng, plat, door = _mk_stack(
        tiny_factory, spool_dir, workers=0,         # nothing drains
        plat_policy=PlatformPolicy(max_queue_depth=1, keep_warm_s=1e9))
    with Gateway(door) as gw:           # workers=0: no threads to stop
        # fill fn-a's (bounded) queue out-of-band
        door.submit("fn-a", [1, 2, 3], session_id="q0",
                    arch_key="llama3.2-3b")
        status, headers, lines = _post(gw.address, {
            "tenant": "fn-a", "session": "q1", "prompt": [1]})
        assert status == 429
        assert int(headers["Retry-After"]) >= 1
        assert lines[0]["retry_after_s"] >= 0.05
    st = door.stats()
    assert st["rejected"] == 1


def test_http_routes_and_errors(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir)
    with plat, Gateway(door) as gw:
        conn = http.client.HTTPConnection(*gw.address, timeout=30.0)
        conn.request("GET", "/healthz")
        assert json.loads(conn.getresponse().read()) == {"ok": True}
        conn.close()

        conn = http.client.HTTPConnection(*gw.address, timeout=30.0)
        conn.request("GET", "/v1/stats")
        st = json.loads(conn.getresponse().read())
        assert "active_sessions" in st
        conn.close()

        status, _, lines = _post(gw.address, {"tenant": "ghost",
                                              "session": "s0"})
        assert status == 400                 # no registered arch
        assert "ghost" in lines[0]["error"]

        conn = http.client.HTTPConnection(*gw.address, timeout=30.0)
        conn.request("GET", "/nope")
        assert conn.getresponse().status == 404
        conn.close()


# ------------------------------------------------------------- websocket
def test_websocket_streams_tokens(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir)
    with plat, Gateway(door) as gw:
        sock = socket.create_connection(gw.address, timeout=60.0)
        try:
            ws_client_handshake(sock, f"{gw.address[0]}:{gw.address[1]}")
            for sid in ("w0", "w1"):         # two requests, one socket
                ws_client_send(sock, json.dumps({
                    "tenant": "fn-b", "session": sid, "prompt": [1, 2],
                    "max_new_tokens": 3, "arch": "mamba2-130m"}))
                toks, done = [], None
                while done is None:
                    msg = json.loads(ws_client_recv(sock))
                    if "token" in msg:
                        toks.append(msg["token"])
                    else:
                        done = msg
                assert len(toks) == 3
                assert done.get("done") is True and "error" not in done
        finally:
            sock.close()
    assert door.stats()["completed"] == 2


def test_websocket_surfaces_backpressure(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(
        tiny_factory, spool_dir, workers=0,
        plat_policy=PlatformPolicy(max_queue_depth=1, keep_warm_s=1e9))
    with Gateway(door) as gw:           # workers=0: no threads to stop
        door.submit("fn-a", [1], session_id="q0", arch_key="llama3.2-3b")
        sock = socket.create_connection(gw.address, timeout=30.0)
        try:
            ws_client_handshake(sock, "x")
            ws_client_send(sock, json.dumps({
                "tenant": "fn-a", "session": "q1", "prompt": [1]}))
            msg = json.loads(ws_client_recv(sock))
            assert "error" in msg and msg["retry_after_s"] >= 0.05
        finally:
            sock.close()


# ----------------------------------------------------------- slo classes
def test_front_door_session_caps(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(
        tiny_factory, spool_dir, workers=0,
        door_policy=FrontDoorPolicy(max_sessions=2,
                                    max_sessions_per_tenant=1))
    door.submit("fn-a", [1], session_id="a0", arch_key="llama3.2-3b")
    with pytest.raises(Backpressure):        # per-tenant cap
        door.submit("fn-a", [1], session_id="a1")
    door.register("fn-b", "mamba2-130m")
    door.submit("fn-b", [1], session_id="b0")
    with pytest.raises(Backpressure):        # gateway-wide cap
        door.submit("fn-b", [1], session_id="b1")
    st = door.stats()
    assert st["accepted"] == 2 and st["rejected"] == 2


def test_batch_share_cap(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(
        tiny_factory, spool_dir, workers=0,
        door_policy=FrontDoorPolicy(max_sessions=4, batch_share=0.25))
    door.submit("fn-a", [1], session_id="b0", slo=SLO_BATCH,
                arch_key="llama3.2-3b")
    with pytest.raises(Backpressure):        # 1/4 batch slots used up
        door.submit("fn-a", [1], session_id="b1", slo=SLO_BATCH)
    # interactive is unaffected by the batch share
    door.submit("fn-a", [1], session_id="i0", slo=SLO_INTERACTIVE)


def test_pressure_sheds_batch_not_interactive(tiny_factory, spool_dir):
    """A deflated tenant under governor pressure: batch wakes are shed
    (the governor would immediately re-deflate), interactive admits."""
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir)
    with plat:
        plat.submit(Request("fn-a", "warmup",
                            np.array([1, 2], np.int32),
                            max_new_tokens=1,
                            close_session=True)).result(timeout=120)
        mgr.descend("fn-a", Rung.HIBERNATED)
        mgr.governor.budget_bytes = 1        # hopeless budget: pressure>0
        assert mgr.governor.pressure_bytes() > 0

        with pytest.raises(Backpressure):
            door.submit("fn-a", [1], session_id="b0", slo=SLO_BATCH)
        stream = door.submit("fn-a", [1], session_id="i0",
                             slo=SLO_INTERACTIVE)
        toks = list(stream)
        assert len(toks) >= 1 and stream.error is None

        # with pressure cleared the same batch request admits
        mgr.governor.budget_bytes = None
        mgr.descend("fn-a", Rung.HIBERNATED)
        stream = door.submit("fn-a", [1], session_id="b1", slo=SLO_BATCH)
        assert list(stream) and stream.error is None


def test_unknown_slo_rejected(tiny_factory, spool_dir):
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir, workers=0)
    with pytest.raises(ValueError):
        door.submit("fn-a", [1], session_id="s0", slo="bulk",
                    arch_key="llama3.2-3b")


def test_scheduler_claims_interactive_first(tiny_factory, spool_dir):
    """With a backlog, a queued interactive tenant is claimed before a
    tenant with only batch work — even when batch arrived first."""
    mgr, eng, plat, door = _mk_stack(tiny_factory, spool_dir, workers=0)
    sb = door.submit("fn-a", [1], session_id="b", slo=SLO_BATCH,
                     arch_key="llama3.2-3b")
    si = door.submit("fn-b", [1], session_id="i", slo=SLO_INTERACTIVE,
                     arch_key="mamba2-130m")
    order = []
    while True:
        with plat._cv:
            claim = plat._claim()
        if claim is None:
            break
        iid, reqs, futs = claim
        order.append(reqs[0].slo)
        try:
            plat._serve(iid, reqs, futs)
        finally:
            with plat._cv:
                plat._busy.discard(iid)
    assert order == [SLO_INTERACTIVE, SLO_BATCH]
    assert sb.done and si.done and sb.error is None and si.error is None

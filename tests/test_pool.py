"""Shared page pool: ownership, COW sharing, PSS accounting, madvise."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core.bitmap_alloc import PAGES_PER_BLOCK
from repro.core.pool import PagePool


def test_write_read_roundtrip():
    pool = PagePool(page_elems=64, capacity_pages=4 * PAGES_PER_BLOCK)
    pages = pool.alloc(3, "t0")
    data = np.arange(3 * 64, dtype=np.float32).reshape(3, 64)
    pool.write(pages, data)
    np.testing.assert_array_equal(pool.read(pages), data)


def test_cow_share_and_pss():
    pool = PagePool(page_elems=64)
    pages = pool.alloc(4, "a")
    pool.share(pages[:2], "b")               # b COW-shares 2 pages
    pb = pool.page_bytes
    assert pool.rss_bytes("a") == 4 * pb
    assert pool.rss_bytes("b") == 2 * pb
    assert pool.pss_bytes("a") == pytest.approx(2 * pb + 2 * pb / 2)
    assert pool.pss_bytes("b") == pytest.approx(2 * pb / 2)
    # freeing a's handle keeps shared pages alive for b
    assert pool.free(pages[:2], "a") == 0
    np.testing.assert_array_equal(pool.read(pages[:2]),
                                  np.zeros((2, 64), np.float32))
    assert pool.free(pages[:2], "b") == 2


def test_block_release_returns_memory():
    pool = PagePool(page_elems=8, capacity_pages=8 * PAGES_PER_BLOCK)
    pages = pool.alloc(PAGES_PER_BLOCK + 5, "t")
    committed = pool.committed_bytes
    pool.free_owner("t")
    assert pool.committed_bytes == 0          # blocks madvise'd back
    assert pool.committed_bytes < committed


def test_capacity_enforced():
    pool = PagePool(page_elems=8, capacity_pages=PAGES_PER_BLOCK)
    pool.alloc(PAGES_PER_BLOCK - 1, "t")      # minus control page
    with pytest.raises(MemoryError):
        pool.alloc(2, "t")


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["alloc", "free", "share"]), max_size=60))
def test_property_used_never_exceeds_committed(ops):
    pool = PagePool(page_elems=8, capacity_pages=4 * PAGES_PER_BLOCK)
    owners = {"a": [], "b": []}
    rng = np.random.default_rng(0)
    for op in ops:
        o = "a" if rng.random() < 0.5 else "b"
        if op == "alloc":
            try:
                owners[o] += pool.alloc(int(rng.integers(1, 9)), o)
            except MemoryError:
                pass
        elif op == "free" and owners[o]:
            n = int(rng.integers(1, len(owners[o]) + 1))
            pool.free(owners[o][:n], o)
            owners[o] = owners[o][n:]
        elif op == "share" and owners["a"]:
            pool.share(owners["a"][:1], "b")
            owners["b"] += owners["a"][:1]
    assert pool.used_bytes <= pool.committed_bytes
    pool.allocator.check_invariants()

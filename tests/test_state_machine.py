"""Container state machine (Fig. 3): exact transition graph."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core.state import (SERVABLE_STATES, TRANSITIONS, ContainerState,
                              Event, InvalidTransition, StateMachine)

S, E = ContainerState, Event


def test_paper_lifecycle():
    """The full numbered path of Fig. 3: ①②③④⑦⑧⑥⑧⑨⑤."""
    sm = StateMachine()
    assert sm.fire(E.COLD_START) == S.WARM          # ①
    assert sm.fire(E.REQUEST) == S.RUNNING          # ②
    assert sm.fire(E.FINISH) == S.WARM              # ③
    assert sm.fire(E.SIGSTOP) == S.HIBERNATE        # ④
    assert sm.fire(E.REQUEST) == S.HIBERNATE_RUNNING  # ⑦
    assert sm.fire(E.FINISH) == S.WOKEN             # ⑧
    assert sm.fire(E.REQUEST) == S.HIBERNATE_RUNNING  # ⑥
    assert sm.fire(E.FINISH) == S.WOKEN             # ⑧
    assert sm.fire(E.SIGSTOP) == S.HIBERNATE        # ⑨
    assert sm.fire(E.SIGCONT) == S.WOKEN            # ⑤
    tags = [h[4] for h in sm.history]
    assert tags == ["(1)", "(2)", "(3)", "(4)", "(7)", "(8)", "(6)",
                    "(8)", "(9)", "(5)"]


def test_invalid_transitions_raise():
    sm = StateMachine()
    with pytest.raises(InvalidTransition):
        sm.fire(E.REQUEST)                # no request before cold start
    sm.fire(E.COLD_START)
    with pytest.raises(InvalidTransition):
        sm.fire(E.SIGCONT)                # SIGCONT only from hibernate
    sm.fire(E.REQUEST)
    with pytest.raises(InvalidTransition):
        sm.fire(E.SIGSTOP)                # cannot deflate mid-request


def test_running_states_not_servable():
    assert S.RUNNING not in SERVABLE_STATES
    assert S.HIBERNATE_RUNNING not in SERVABLE_STATES
    assert {S.WARM, S.HIBERNATE, S.WOKEN} <= SERVABLE_STATES


def test_hooks_fire():
    sm = StateMachine()
    seen = []
    sm.on(E.SIGSTOP, lambda m: seen.append(m.state))
    sm.fire(E.COLD_START)
    sm.fire(E.SIGSTOP)
    assert seen == [S.HIBERNATE]


@settings(max_examples=300, deadline=None)
@given(st.lists(st.sampled_from(list(Event)), max_size=40))
def test_property_never_leaves_graph(events):
    """Arbitrary event streams: every accepted transition is in the paper's
    graph; every rejected one raises and leaves state unchanged."""
    sm = StateMachine()
    for ev in events:
        before = sm.state
        if (before, ev) in TRANSITIONS:
            after = sm.fire(ev)
            assert after == TRANSITIONS[(before, ev)][0]
        else:
            with pytest.raises(InvalidTransition):
                sm.fire(ev)
            assert sm.state == before

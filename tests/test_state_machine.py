"""Container state machine (Fig. 3 + the deflation ladder): exact graph."""
import pytest

try:        # optional dep: only the property test needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # minimal installs
    HAVE_HYPOTHESIS = False

from repro.core.state import (DEFLATE_EVENT_FOR, RUNG_OF, SERVABLE_STATES,
                              TRANSITIONS, ContainerState, Event,
                              InvalidTransition, Rung, StateMachine)

S, E = ContainerState, Event


def test_paper_lifecycle():
    """The full numbered path of Fig. 3: ①②③④⑦⑧⑥⑧⑨⑤."""
    sm = StateMachine()
    assert sm.fire(E.COLD_START) == S.WARM          # ①
    assert sm.fire(E.REQUEST) == S.RUNNING          # ②
    assert sm.fire(E.FINISH) == S.WARM              # ③
    assert sm.fire(E.SIGSTOP) == S.HIBERNATE        # ④
    assert sm.fire(E.REQUEST) == S.HIBERNATE_RUNNING  # ⑦
    assert sm.fire(E.FINISH) == S.WOKEN             # ⑧
    assert sm.fire(E.REQUEST) == S.HIBERNATE_RUNNING  # ⑥
    assert sm.fire(E.FINISH) == S.WOKEN             # ⑧
    assert sm.fire(E.SIGSTOP) == S.HIBERNATE        # ⑨
    assert sm.fire(E.SIGCONT) == S.WOKEN            # ⑤
    tags = [h[4] for h in sm.history]
    assert tags == ["(1)", "(2)", "(3)", "(4)", "(7)", "(8)", "(6)",
                    "(8)", "(9)", "(5)"]


def test_invalid_transitions_raise():
    sm = StateMachine()
    with pytest.raises(InvalidTransition):
        sm.fire(E.REQUEST)                # no request before cold start
    sm.fire(E.COLD_START)
    with pytest.raises(InvalidTransition):
        sm.fire(E.SIGCONT)                # SIGCONT only from hibernate
    sm.fire(E.REQUEST)
    with pytest.raises(InvalidTransition):
        sm.fire(E.SIGSTOP)                # cannot deflate mid-request


def test_running_states_not_servable():
    assert S.RUNNING not in SERVABLE_STATES
    assert S.HIBERNATE_RUNNING not in SERVABLE_STATES
    assert {S.WARM, S.HIBERNATE, S.WOKEN} <= SERVABLE_STATES


def test_hooks_fire():
    sm = StateMachine()
    seen = []
    sm.on(E.SIGSTOP, lambda m: seen.append(m.state))
    sm.fire(E.COLD_START)
    sm.fire(E.SIGSTOP)
    assert seen == [S.HIBERNATE]


def test_ladder_descent_path():
    """The full rung ladder, one rung at a time:
    WARM -> MMAP_CLEAN -> PARTIAL -> HIBERNATE -> DEAD."""
    sm = StateMachine()
    sm.fire(E.COLD_START)
    assert sm.fire(E.MMAP_DROP) == S.MMAP_CLEAN     # (4a)
    assert sm.fire(E.PARTIAL_STOP) == S.PARTIAL     # (4b)
    assert sm.fire(E.PARTIAL_STOP) == S.PARTIAL     # proportional re-bite
    assert sm.fire(E.SIGSTOP) == S.HIBERNATE        # (4)
    assert sm.fire(E.EVICT) == S.DEAD
    assert [RUNG_OF[h[3]] for h in sm.history] == [
        Rung.WARM, Rung.MMAP_CLEAN, Rung.PARTIAL, Rung.PARTIAL,
        Rung.HIBERNATED, Rung.TERMINATED]


def test_ladder_rungs_skippable_downward_only():
    """The governor may skip an empty rung going DOWN (WARM -> PARTIAL,
    WARM -> HIBERNATE); climbing happens only via SIGCONT/REQUEST."""
    for ev, dst in ((E.PARTIAL_STOP, S.PARTIAL), (E.SIGSTOP, S.HIBERNATE)):
        sm = StateMachine()
        sm.fire(E.COLD_START)
        assert sm.fire(ev) == dst
    # no event climbs one deflate rung to another: HIBERNATE cannot go
    # back to PARTIAL or MMAP_CLEAN except through a wake
    assert not any(src == S.HIBERNATE and dst in (S.PARTIAL, S.MMAP_CLEAN)
                   for (src, _), (dst, _) in TRANSITIONS.items())


def test_ladder_wakes():
    """MMAP_CLEAN re-maps to WARM; PARTIAL wakes to WOKEN; both serve
    requests directly."""
    sm = StateMachine()
    sm.fire(E.COLD_START)
    sm.fire(E.MMAP_DROP)
    assert sm.fire(E.SIGCONT) == S.WARM             # (5a) pure re-map
    sm.fire(E.PARTIAL_STOP)
    assert sm.fire(E.SIGCONT) == S.WOKEN            # (5b)
    sm.fire(E.SIGSTOP)
    sm.fire(E.SIGCONT)
    sm.fire(E.MMAP_DROP)                            # WOKEN -> PARTIAL (4a')
    assert sm.state == S.PARTIAL
    assert sm.fire(E.REQUEST) == S.HIBERNATE_RUNNING  # (7b)
    assert sm.fire(E.FINISH) == S.WOKEN


def test_ladder_illegal_transitions():
    """Enumerated illegal rung moves: deflate events on running/dead
    states, ladder events that would climb without a wake, and mmap-drop
    below its rung."""
    illegal = [
        (S.RUNNING, E.MMAP_DROP), (S.RUNNING, E.PARTIAL_STOP),
        (S.RUNNING, E.SIGSTOP), (S.RUNNING, E.EVICT),
        (S.HIBERNATE_RUNNING, E.MMAP_DROP),
        (S.HIBERNATE_RUNNING, E.PARTIAL_STOP),
        (S.HIBERNATE_RUNNING, E.SIGSTOP),
        (S.HIBERNATE, E.MMAP_DROP),       # already below MMAP_CLEAN
        (S.HIBERNATE, E.PARTIAL_STOP),    # cannot climb via a deflate event
        (S.PARTIAL, E.MMAP_DROP),         # mmap cleanup rides deflate_partial
        (S.MMAP_CLEAN, E.MMAP_DROP),      # idempotent rung: no self-loop
        (S.DEAD, E.MMAP_DROP), (S.DEAD, E.PARTIAL_STOP),
        (S.DEAD, E.SIGSTOP), (S.DEAD, E.SIGCONT), (S.DEAD, E.REQUEST),
        (S.COLD, E.MMAP_DROP), (S.COLD, E.PARTIAL_STOP),
        (S.COLD, E.SIGSTOP),
    ]
    for state, ev in illegal:
        assert (state, ev) not in TRANSITIONS, (state, ev)
        sm = StateMachine(state=state)
        with pytest.raises(InvalidTransition):
            sm.fire(ev)
        assert sm.state == state


def test_migration_transitions():
    """MIGRATE fences MMAP_CLEAN/PARTIAL/HIBERNATE; the fence resolves
    only via MIGRATE_DONE (-> DEAD on the source) or MIGRATE_ABORT
    (-> HIBERNATE: the snapshot never left)."""
    for src in (S.MMAP_CLEAN, S.PARTIAL, S.HIBERNATE):
        sm = StateMachine(state=src)
        assert sm.fire(E.MIGRATE) == S.MIGRATING
        assert sm.fire(E.MIGRATE_DONE) == S.DEAD
    sm = StateMachine(state=S.HIBERNATE)
    sm.fire(E.MIGRATE)
    assert sm.fire(E.MIGRATE_ABORT) == S.HIBERNATE
    assert RUNG_OF[S.MIGRATING] == Rung.HIBERNATED


def test_migrating_is_fenced_from_every_other_event():
    """A MIGRATING tenant accepts ONLY the two resolution events.  In
    particular governor TERMINATED (EVICT) is illegal — a stale descent
    must never free swap state an in-flight transfer is reading — and a
    serving/inflated state can never MIGRATE."""
    legal = {E.MIGRATE_DONE, E.MIGRATE_ABORT}
    for ev in Event:
        if ev in legal:
            assert (S.MIGRATING, ev) in TRANSITIONS
            continue
        assert (S.MIGRATING, ev) not in TRANSITIONS, ev
        sm = StateMachine(state=S.MIGRATING)
        with pytest.raises(InvalidTransition):
            sm.fire(ev)
        assert sm.state == S.MIGRATING
    # MIGRATE is only reachable from deflated-enough idle rungs
    for state in ContainerState:
        can = (state, E.MIGRATE) in TRANSITIONS
        assert can == (state in (S.MMAP_CLEAN, S.PARTIAL, S.HIBERNATE)), \
            state


def test_rung_ladder_is_total_and_ordered():
    """Every state has a rung; DEFLATE_EVENT_FOR covers every non-WARM
    rung and each mapped event lands on (at most) its rung from WARM."""
    assert set(RUNG_OF) == set(ContainerState)
    assert set(DEFLATE_EVENT_FOR) == {Rung.MMAP_CLEAN, Rung.PARTIAL,
                                      Rung.HIBERNATED, Rung.TERMINATED}
    for rung, ev in DEFLATE_EVENT_FOR.items():
        dst, _ = TRANSITIONS[(S.WARM, ev)]
        assert RUNG_OF[dst] == rung
    # servability: every rung above TERMINATED is servable via some path
    assert {S.MMAP_CLEAN, S.PARTIAL} <= SERVABLE_STATES


if HAVE_HYPOTHESIS:
    @settings(max_examples=300, deadline=None)
    @given(st.lists(st.sampled_from(list(Event)), max_size=40))
    def test_property_never_leaves_graph(events):
        """Arbitrary event streams (ladder events included): every accepted
        transition is in the graph; every rejected one raises and leaves
        state unchanged."""
        sm = StateMachine()
        for ev in events:
            before = sm.state
            if (before, ev) in TRANSITIONS:
                after = sm.fire(ev)
                assert after == TRANSITIONS[(before, ev)][0]
            else:
                with pytest.raises(InvalidTransition):
                    sm.fire(ev)
                assert sm.state == before
else:                                      # keep the skip VISIBLE
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_never_leaves_graph():
        pass

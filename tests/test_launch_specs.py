"""launch/specs unit behaviour that needs no devices: shape applicability,
decode windows, REAP recorder semantics, roofline model flops."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.core.reap import ReapRecorder
from repro.launch import analysis
from repro.launch.specs import applicable, decode_window


def test_whisper_skips_long_only():
    cfg = get_config("whisper-large-v3")
    assert not applicable(cfg, get_shape("long_500k"))
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert applicable(cfg, get_shape(s))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_context_policy(arch):
    """long_500k: SSM/hybrid run native; dense archs run the
    sliding-window variant (ring cache = window); whisper skips."""
    cfg = get_config(arch)
    shape = get_shape("long_500k")
    if cfg.long_context_mode == "skip":
        assert arch == "whisper-large-v3"
        return
    window, cache_len = decode_window(cfg, shape)
    if cfg.attention == "none":
        assert window is None and cache_len == shape.seq_len
    else:
        assert window == cfg.sliding_window
        assert cache_len == min(cfg.sliding_window, shape.seq_len)


def test_decode_32k_is_full_attention():
    cfg = get_config("yi-6b")
    window, cache_len = decode_window(cfg, get_shape("decode_32k"))
    assert window is None and cache_len == 32_768


def test_reap_recorder_union_semantics():
    r = ReapRecorder()
    r.start()
    r.record(("w", "a", -1))
    assert r.stop() == frozenset({("w", "a", -1)})
    r.start()
    r.record(("kv", "s", 0, 1))
    # union across invocations (REAP's stable-working-set observation)
    assert r.stop() == frozenset({("w", "a", -1), ("kv", "s", 0, 1)})
    r.record(("x",))                       # not recording -> ignored
    assert ("x",) not in r.working_set
    r.forget()
    assert not r.working_set


@pytest.mark.parametrize("arch,shape,expect_active", [
    ("deepseek-v2-236b", "train_4k", True),     # MoE: active << total
    ("llama3.2-3b", "train_4k", False),
])
def test_model_flops_moe_uses_active(arch, shape, expect_active):
    cfg = get_config(arch)
    f = analysis.model_flops(cfg, get_shape(shape))
    tokens = get_shape(shape).global_batch * get_shape(shape).seq_len
    assert f == 6.0 * cfg.active_param_count() * tokens
    if expect_active:
        assert cfg.active_param_count() < 0.25 * cfg.param_count()


def test_roofline_bottleneck_classification():
    r = analysis.Roofline("a", "s", "single", 256,
                          device_flops=197e12,          # 1 s compute
                          device_bytes=819e9 * 2,       # 2 s memory
                          coll_bytes={"all-reduce": int(50e9 * 3)})  # 3 s
    assert r.bottleneck == "collective"
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(3.0)

"""End-to-end launch-layer guard: the dry-run CLI must lower+compile a
real case in its own process (where it owns XLA_FLAGS and 512 placeholder
devices) and emit a well-formed roofline record."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cli_single_case(tmp_path):
    out = tmp_path / "case.jsonl"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-130m", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["bytes_per_device"] > 0
    assert rec["memory_s"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_render_roofline_compare(tmp_path, capsys):
    from benchmarks import render_roofline
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    row = {"arch": "x", "shape": "s", "status": "ok", "compute_s": 1.0,
           "memory_s": 4.0, "collective_s": 2.0, "bottleneck": "memory",
           "useful_flops_frac": 0.5, "bytes_per_device": 2**30}
    a.write_text(json.dumps(row) + "\n")
    row2 = dict(row, memory_s=1.0, collective_s=0.5, bottleneck="memory")
    b.write_text(json.dumps(row2) + "\n")
    render_roofline.main([str(a), str(b), "--compare"])
    out = capsys.readouterr().out
    assert "4.00x" in out          # dominant 4.0 -> 1.0

"""Binary wire codec + socket transport: canonical round-trips, salt
auth, socket-vs-loopback migration identity, and the crash matrix
(kill-mid-transfer, vanished peer, commit-callback failure)."""
import socket
import time

import numpy as np
import pytest

from repro.cluster import Node
from repro.cluster.migrate import (MigrationError, StorePeer,
                                   TransferStats, _export_bundle,
                                   migrate_instance)
from repro.cluster.transport import (AuthError, LoopbackTransport,
                                     SocketTransport, TransportError)
from repro.cluster import wire
from repro.core.state import ContainerState, Rung
from repro.core.store import UnitMeta

from test_cluster import (ARCH, SALT, _assert_identical, _cluster,
                          _full_wake, _snapshot, _tenant)

S = ContainerState


# ---------------------------------------------------------------- codec
def test_codec_roundtrips_representative_values():
    values = [
        None, True, False, 0, -1, 1, 127, 128, -(1 << 63), (1 << 63) - 1,
        0.0, -2.5, 1e300, "", "unit/key", "émoji ✓", b"", b"\x00\xff" * 9,
        (), ("weights", "embed", 0), [], [1, "two", b"\x03", None],
        {}, {"a": 1, "b": [True, ()]}, {("kv", "s", 0, 1): b"digest"},
        frozenset(), frozenset({"x", "y", ("t", 1)}),
        UnitMeta(digest=b"d" * 16, fill=-3, nbytes=4096,
                 dtype="float32", shape=(32, 4)),
        UnitMeta(digest=None, fill=0, nbytes=0, dtype="", shape=()),
        {"nested": {"deep": [(frozenset({1, 2}), {"k": b"v"})]}},
    ]
    for v in values:
        enc = wire.encode_value(v)
        dec = wire.decode_value(enc)
        assert dec == v, v
        # canonical: decode is a left inverse AND a right inverse
        assert wire.encode_value(dec) == enc, v


def test_codec_canonicalises_numpy_scalars():
    """Token ids / fills arrive as numpy scalars; the wire form is the
    plain Python value (one canonical encoding per value)."""
    assert wire.encode_value(np.int64(7)) == wire.encode_value(7)
    assert wire.encode_value(np.int32(-2)) == wire.encode_value(-2)
    assert wire.encode_value(np.float64(0.5)) == wire.encode_value(0.5)
    assert wire.decode_value(wire.encode_value(np.int64(7))) == 7


def test_codec_rejects_malformed_input():
    with pytest.raises(wire.WireError):
        wire.decode_value(b"")                       # empty
    with pytest.raises(wire.WireError):
        wire.decode_value(b"\xee")                   # unknown tag
    with pytest.raises(wire.WireError):
        wire.decode_value(wire.encode_value(1) + b"\x00")  # trailing
    with pytest.raises(wire.WireError):
        wire.decode_value(b"\x03\x80\x00")           # padded varint
    with pytest.raises(wire.WireError):
        wire.decode_value(b"\x05\x05ab")             # truncated str
    # duplicate dict keys never decode (canonical form is unique)
    dup = bytearray(wire.encode_value({"a": 1}))
    dup[1] = 2                                       # claim two pairs
    dup += wire.encode_value("a")[0:]               # same key again
    dup += wire.encode_value(2)
    with pytest.raises(wire.WireError):
        wire.decode_value(bytes(dup))
    # frozenset elements must arrive in sorted-encoding order
    fs = wire.encode_value(frozenset({1, 2}))
    a, b = wire.encode_value(1), wire.encode_value(2)
    swapped = fs[:2] + (b + a if fs[2:] == a + b else a + b)
    with pytest.raises(wire.WireError):
        wire.decode_value(swapped)
    with pytest.raises(wire.WireError):
        wire.encode_value(object())                  # not wire-safe


def test_codec_rejects_oversized_nesting():
    v = [1]
    for _ in range(wire.MAX_DEPTH + 2):
        v = [v]
    with pytest.raises(wire.WireError):
        wire.encode_value(v)


def test_frame_roundtrip():
    payload = wire.encode_value({"x": 1})
    frame = wire.pack_frame(wire.MSG_MISSING, payload)
    buf = bytearray(frame)

    def recv_exact(n):
        out = bytes(buf[:n])
        del buf[:n]
        return out

    mt, got = wire.read_frame(recv_exact)
    assert mt == wire.MSG_MISSING and got == payload


def test_segments_roundtrip():
    items = [(b"d" * 16, 1, 4096, b"payload"), (b"e" * 16, 0, 0, b"")]
    dec = wire.decode_segments(wire.encode_segments(items))
    assert dec == items


def test_bundle_roundtrip_drops_compiled(tiny_factory, spool_dir):
    """A real exported bundle survives encode→decode with every wire
    field intact; host-local executables stay behind."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=3)
    inst.compiled["prefill"] = object()              # host-local stand-in
    n0.manager.descend("t0", Rung.HIBERNATED)
    bundle = _export_bundle(n0, inst, ARCH)
    dec = wire.decode_bundle(wire.encode_bundle(bundle))
    for f in wire._BUNDLE_FIELDS:
        got, want = getattr(dec, f), getattr(bundle, f)
        if f == "kv_sessions":
            # numpy token ids canonicalise to plain ints on the wire
            want = [dict(sd, token_ids=[int(t) for t in sd["token_ids"]])
                    for sd in want]
        assert got == want, f
    assert dec.compiled == {}
    router.close()


# ------------------------------------------------------------ orphan sweep
def _mk_stores(tmp_path, salt=SALT):
    from repro.core.store import SwapStore
    a = SwapStore(str(tmp_path / "a"), salt=salt)
    b = SwapStore(str(tmp_path / "b"), salt=salt)
    return a, b


def test_sweep_orphans_only_touches_unadopted(tmp_path):
    src, dst = _mk_stores(tmp_path)
    c = src.client("t")
    c.write_units([("k1", np.arange(64, dtype=np.float32)),
                   ("k2", np.ones(64, dtype=np.float32))])
    meta = src.export_meta(c)
    items = list(src.export_segments(
        [m.digest for m in meta.values()]))
    new = dst.import_segments(items)
    assert sorted(new) == sorted(m.digest for m in meta.values())
    assert sorted(dst.orphan_digests()) == sorted(new)

    # adopt one key: its segment stops being an orphan, the other stays
    (k1_meta,) = [m for k, m in meta.items() if k == "k1"]
    dst.adopt_extents("mover", {"k1": k1_meta})
    orphans = dst.orphan_digests()
    assert k1_meta.digest not in orphans
    freed = dst.sweep_orphans()
    assert freed > 0
    assert dst.orphan_digests() == []
    # the adopted segment survived the sweep
    assert dst.missing_digests([k1_meta.digest]) == []
    src.close()
    dst.close()


def test_sweep_orphans_respects_age_gate(tmp_path):
    src, dst = _mk_stores(tmp_path)
    c = src.client("t")
    c.write_units([("k", np.arange(32, dtype=np.float32))])
    meta = src.export_meta(c)
    dst.import_segments(list(src.export_segments(
        [m.digest for m in meta.values()])))
    assert dst.orphan_digests(max_age_s=3600.0) == []      # too young
    assert dst.sweep_orphans(max_age_s=3600.0) == 0
    assert dst.sweep_orphans(max_age_s=0.0) > 0            # now eligible
    src.close()
    dst.close()


# ------------------------------------------------------------- socket path
def test_socket_auth_rejects_wrong_salt(tiny_factory, spool_dir):
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    addr = n1.start_peer_server()
    with pytest.raises(AuthError):
        SocketTransport.connect(addr, b"some-other-deployment")
    assert n1.peer_server.auth_failures == 1
    # the real salt still works after a failed attempt
    t = SocketTransport.connect(addr, SALT, node_id="n0")
    assert t.target_node_id == "n1"
    t.close()
    router.close()


def test_peer_refuses_unauthenticated_channel(tiny_factory, spool_dir):
    """StorePeer re-checks the channel's deployment at construction:
    a transport authenticated for salt A never ships salt-B digests."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    addr = n1.start_peer_server()
    t = SocketTransport.connect(addr, SALT)
    t._salt_fp = b"\x00" * 16          # channel from another deployment
    with pytest.raises(MigrationError):
        StorePeer(n0.manager.store, transport=t)
    t.close()
    router.close()


def test_socket_migration_matches_loopback(tiny_factory, spool_dir):
    """The tentpole acceptance: a migration over the real socket
    protocol restores byte-identical tenant state — the twin tenant
    (same seed, migrated over loopback) is the reference."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "sock", seed=11)
    twin = _tenant(router, n0, "loop", seed=11)
    snap = _snapshot(inst)
    _assert_identical(twin, snap)
    n0.manager.descend("sock", Rung.HIBERNATED)
    n0.manager.descend("loop", Rung.HIBERNATED)

    addr = n1.start_peer_server()
    t = SocketTransport.connect(addr, SALT, node_id="n0", window=2)
    try:
        h = migrate_instance(n0, None, "sock", ARCH, transport=t)
    finally:
        t.close()
    assert h.ok, h.error
    assert h.target_node_id == "n1"
    h2 = migrate_instance(n0, n1, "loop", ARCH)
    assert h2.ok, h2.error

    assert "sock" not in n0.manager.instances
    assert n1.manager.instances["sock"].state == S.HIBERNATE
    moved = _full_wake(n1, "sock")
    _assert_identical(moved, snap)
    ref = _full_wake(n1, "loop")
    _assert_identical(ref, snap)
    # dedup held across the wire: the twin's transfer shipped ~nothing
    # beyond what the first move already parked in n1's store
    assert h2.stats.bytes_shipped < h.stats.bytes_shipped
    assert n1.peer_server.transfers == 1
    router.close()


def test_socket_transport_multiple_sequential_migrations(tiny_factory,
                                                         spool_dir):
    """One connection serves several migrations; the server's import
    ledger resets at each bundle."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    for iid, seed in (("a", 1), ("b", 2)):
        _tenant(router, n0, iid, seed=seed)
        n0.manager.descend(iid, Rung.HIBERNATED)
    addr = n1.start_peer_server()
    t = SocketTransport.connect(addr, SALT)
    try:
        for iid in ("a", "b"):
            assert migrate_instance(n0, None, iid, ARCH, transport=t).ok
    finally:
        t.close()
    assert set(n1.manager.instances) == {"a", "b"}
    assert n1.store.orphan_digests() == []
    assert n1.peer_server.transfers == 2
    router.close()


# --------------------------------------------------------- fault injection
class _FaultyTransport(LoopbackTransport):
    """Dies after importing the first segment chunk — the window between
    ``import_segments`` and ``adopt_extents`` the orphan sweep exists
    for."""

    def __init__(self, *a, fail_after: int = 1, **kw):
        super().__init__(*a, **kw)
        self.sent = 0
        self.fail_after = fail_after
        self.sweeps = 0

    def send_segments(self, items):
        n = super().send_segments(items)
        self.sent += 1
        if self.sent >= self.fail_after:
            raise TransportError("injected: link died mid-transfer")
        return n

    def sweep_orphans(self, digests):
        self.sweeps += 1
        return super().sweep_orphans(digests)


def _store_totals(store):
    return store.live_bytes, len(store.orphan_digests())


def test_kill_mid_transfer_leaves_both_stores_clean(tiny_factory,
                                                    spool_dir):
    """Satellite acceptance: a transfer killed between import and adopt
    leaves zero orphans on the target, the source still owns every
    byte, and the tenant remains servable at the source."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=5)
    snap = _snapshot(inst)
    n0.manager.descend("t0", Rung.HIBERNATED)
    src_before = _store_totals(n0.store)
    dst_before = _store_totals(n1.store)

    t = _FaultyTransport(dst_node=n1)
    with pytest.raises(MigrationError) as ei:
        migrate_instance(n0, n1, "t0", ARCH, transport=t)
    assert ei.value.handle is not None          # transfer, not fence
    assert not ei.value.handle.committed
    assert t.sweeps >= 1                        # abort swept the target

    # both stores GC-clean: target took nothing, source kept everything
    assert _store_totals(n1.store) == dst_before
    assert _store_totals(n0.store) == src_before
    assert "t0" not in n1.manager.instances
    # the source fell back to a plain hibernated tenant and still serves
    inst = n0.manager.instances["t0"]
    assert inst.state == S.HIBERNATE
    assert inst.migration is None
    _assert_identical(_full_wake(n0, "t0"), snap)
    router.close()


class _DyingSocketTransport(SocketTransport):
    """Ships one chunk, then hard-closes the socket — the client
    process crashing mid-transfer, no abort protocol runs."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.sent = 0

    def send_segments(self, items):
        n = super().send_segments(items)
        self.sent += 1
        if self.sent >= 1:
            self.barrier()      # ack received: the import is on disk
            self.sock.shutdown(socket.SHUT_RDWR)
            self.sock.close()
            raise TransportError("injected: peer crashed")
        return n


def test_socket_peer_crash_server_sweeps_orphans(tiny_factory, spool_dir):
    """A peer that vanishes without aborting cannot leak refcount-zero
    segments: the server's connection teardown sweeps them."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=9)
    snap = _snapshot(inst)
    n0.manager.descend("t0", Rung.HIBERNATED)
    dst_before = _store_totals(n1.store)

    addr = n1.start_peer_server()
    t = _DyingSocketTransport.connect(addr, SALT)
    with pytest.raises(MigrationError) as ei:
        migrate_instance(n0, n1, "t0", ARCH, transport=t)
    assert ei.value.handle is not None

    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline \
            and _store_totals(n1.store) != dst_before:
        time.sleep(0.02)
    assert _store_totals(n1.store) == dst_before
    assert n1.peer_server.orphans_swept >= 1
    assert n0.manager.instances["t0"].state == S.HIBERNATE
    _assert_identical(_full_wake(n0, "t0"), snap)
    router.close()


def test_abandoned_import_swept_on_disconnect(tiny_factory, spool_dir):
    """Raw-protocol variant: import segments, never send a bundle, drop
    the connection — the server reclaims every byte."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=4)
    n0.manager.descend("t0", Rung.HIBERNATED)
    digests = [m.digest for m in
               n0.store.export_meta(inst.swap_file).values()
               if m.digest is not None]
    dst_before = _store_totals(n1.store)

    addr = n1.start_peer_server()
    t = SocketTransport.connect(addr, SALT)
    peer = StorePeer(n0.store, transport=t)
    peer.ship(digests, TransferStats())
    assert n1.store.orphan_digests() != []      # imported, not adopted
    t.sock.close()                              # vanish without BYE

    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline \
            and _store_totals(n1.store) != dst_before:
        time.sleep(0.02)
    assert _store_totals(n1.store) == dst_before
    router.close()


def test_commit_callback_failure_does_not_strand_tenant(tiny_factory,
                                                        spool_dir):
    """Crash consistency past MIGRATE_DONE: the commit is irrevocable,
    so a failing on_commit still leaves exactly one owner (the target)
    and a GC-clean source."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=6)
    snap = _snapshot(inst)
    n0.manager.descend("t0", Rung.HIBERNATED)

    def bad_commit():
        raise RuntimeError("injected: placement map update crashed")

    with pytest.raises(MigrationError) as ei:
        migrate_instance(n0, n1, "t0", ARCH, on_commit=bad_commit)
    h = ei.value.handle
    assert h is not None and h.committed        # past the point of no return
    # exactly one owner: the target
    assert "t0" not in n0.manager.instances
    assert n1.manager.instances["t0"].state == S.HIBERNATE
    # source finalization ran to completion despite the callback error
    assert n0.manager.migrated.get("t0") == "n1"
    assert n0.store.orphan_digests() == []
    router.placement["t0"] = "n1"               # what bad_commit skipped
    _assert_identical(_full_wake(n1, "t0"), snap)
    router.close()

"""Per-sandbox swap files (§3.4): roundtrips, io accounting, deletion."""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core.swap import ReapFile, SwapFile


def test_swapfile_roundtrip(spool_dir):
    f = SwapFile(f"{spool_dir}/a.swap")
    arrs = {("w", "x", -1): np.arange(12, dtype=np.float32).reshape(3, 4),
            ("kv", "s", 0, 1): np.ones((7,), np.int64)}
    for k, a in arrs.items():
        f.write_unit(k, a)
    for k, a in arrs.items():
        np.testing.assert_array_equal(f.read_unit(k), a)
    assert f.reads == 2          # one random read per unit
    f.delete()
    assert not os.path.exists(f"{spool_dir}/a.swap")


def test_swapfile_overwrite_reuses_extent(spool_dir):
    f = SwapFile(f"{spool_dir}/b.swap")
    f.write_unit("k", np.zeros(64, np.float32))
    size = f.file_bytes
    f.write_unit("k", np.ones(32, np.float32))   # smaller: reuse extent
    assert f.file_bytes == size
    np.testing.assert_array_equal(f.read_unit("k"), np.ones(32, np.float32))
    f.delete()


def test_reapfile_batch_is_one_read(spool_dir):
    f = ReapFile(f"{spool_dir}/c.reap")
    items = [((i,), np.full((16,), i, np.float32)) for i in range(10)]
    f.write_batch(items)
    assert f.writes == 1                       # pwritev: one batch write
    out = f.read_batch()
    assert f.reads == 1                        # preadv: one batch read
    for k, a in items:
        np.testing.assert_array_equal(out[k], a)
    # a REAP file still serves random reads (pagefault-mode wake)
    np.testing.assert_array_equal(f.read_unit((3,)), items[3][1])
    f.delete()


def test_reap_rewrite_replaces_working_set(spool_dir):
    f = ReapFile(f"{spool_dir}/d.reap")
    f.write_batch([("a", np.zeros(8, np.float32))])
    f.write_batch([("b", np.ones(8, np.float32))])
    assert "a" not in f.extents
    assert set(f.read_batch()) == {"b"}
    f.delete()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30),
                          st.integers(1, 64)), min_size=1, max_size=20,
                unique_by=lambda t: t[0]))
def test_property_reap_offsets_sequential(tmp_path_factory, items):
    """REAP extents are contiguous ascending — the batched sequential
    layout that makes the swap-in one disk pass."""
    d = tmp_path_factory.mktemp("reap")
    f = ReapFile(str(d / "x.reap"))
    arrs = [((k,), np.random.default_rng(k).standard_normal(n)
             .astype(np.float32)) for k, n in items]
    f.write_batch(arrs)
    offs = [f.extents[k].offset for k, _ in arrs]
    sizes = [f.extents[k].nbytes for k, _ in arrs]
    assert offs[0] == 0
    for i in range(1, len(offs)):
        assert offs[i] == offs[i - 1] + sizes[i - 1]
    out = f.read_batch()
    for k, a in arrs:
        np.testing.assert_array_equal(out[k], a)
    f.delete()

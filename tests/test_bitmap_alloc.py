"""Bitmap Page Allocator (§3.3, Fig. 4): unit + hypothesis property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core.bitmap_alloc import (PAGES_PER_BLOCK, USABLE_PER_BLOCK,
                                     BitmapPageAllocator)


def test_control_page_reserved():
    a = BitmapPageAllocator()
    pages = a.alloc_many(USABLE_PER_BLOCK)
    # page 0 of block 0 (the control page) must never be handed out
    assert 0 not in pages
    assert len(set(pages)) == USABLE_PER_BLOCK
    a.check_invariants()


def test_o2_lookup_order():
    """Allocation fills the lowest free offset first (ffs on L1 then L2)."""
    a = BitmapPageAllocator()
    assert a.alloc() == 1
    assert a.alloc() == 2
    a.free(1)
    assert a.alloc() == 1          # lowest free bit again


def test_block_growth_and_reclaim():
    a = BitmapPageAllocator()
    pages = a.alloc_many(USABLE_PER_BLOCK + 1)   # spills into a 2nd block
    assert a.committed_blocks == 2
    for p in pages:
        a.free(p)
    # both fully-free blocks returned to the global heap ("madvise")
    assert a.committed_blocks == 0
    assert a.stats["blocks_released"] == 2
    a.check_invariants()


def test_refcount_cow():
    a = BitmapPageAllocator()
    p = a.alloc()
    assert a.refcount(p) == 1
    a.incref(p)
    assert a.refcount(p) == 2
    assert a.decref(p) is False      # still shared
    assert a.decref(p) is True       # now freed
    with pytest.raises(ValueError):
        a.refcount(p)


def test_memory_limit():
    a = BitmapPageAllocator(max_blocks=1)
    a.alloc_many(USABLE_PER_BLOCK)
    with pytest.raises(MemoryError):
        a.alloc()


def test_free_list_no_metadata_in_pages():
    """The reclamation insight: freeing any subset leaves a valid structure
    (no free-list pointers live inside data pages)."""
    a = BitmapPageAllocator()
    pages = a.alloc_many(2000)
    for p in pages[::2]:
        a.free(p)
    a.check_invariants()
    # reallocation reuses freed pages before growing
    grown = a.stats["blocks_grown"]
    a.alloc_many(500)
    assert a.stats["blocks_grown"] == grown


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "incref",
                                           "decref"]),
                          st.integers(0, 50)), max_size=200))
def test_property_invariants(ops):
    """Random op sequences never break the L1/L2/refcount invariants."""
    a = BitmapPageAllocator(max_blocks=4)
    live = []
    for kind, i in ops:
        if kind == "alloc":
            try:
                live.append(a.alloc())
            except MemoryError:
                pass
        elif live:
            p = live[i % len(live)]
            if kind == "free":
                a.free(p)
                live.remove(p)
            elif kind == "incref":
                a.incref(p)
                live.append(p)
            elif kind == "decref":
                if a.decref(p):
                    # freed entirely: drop every alias
                    live = [q for q in live if q != p]
                else:
                    live.remove(p)
    a.check_invariants()
    assert a.allocated_pages == len(set(live))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 3 * USABLE_PER_BLOCK))
def test_property_alloc_n_unique(n):
    a = BitmapPageAllocator()
    pages = a.alloc_many(n)
    assert len(set(pages)) == n
    assert a.allocated_pages == n
    assert a.committed_blocks == -(-n // USABLE_PER_BLOCK)
    a.check_invariants()

"""Property tests for the prefix registry under adversarial interleaving.

A random schedule of {adopt, decode, close, deflate, migrate} ops runs
against a 2-node cluster with prefix sharing ON and an identical cluster
with sharing OFF.  Three invariants, for ANY schedule:

  * adopted decode is bit-exact: every token the sharing cluster emits
    equals the sharing-off twin's (adoption is indistinguishable from a
    private prefill);
  * survivors stay intact: deflating, migrating, or closing one sharer
    never perturbs another sharer's continuation;
  * refcounts balance: after evicting every tenant, no pool bytes remain
    charged to any tenant or to the registry owner (last-sharer-down
    spilled each entry to the CAS store instead of leaking pages).

The checks are plain functions; a parametrized smoke version always
runs, and hypothesis (optional dep) drives randomized schedules over
the same body.
"""
import os

import numpy as np
import pytest

from repro.cluster import ClusterRouter, Node
from repro.core.manager import ManagerConfig
from repro.core.prefix import PREFIX_OWNER
from repro.core.state import Rung
from repro.serving.engine import Request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # minimal installs
    HAVE_HYPOTHESIS = False

ARCH = "llama3.2-3b"
SALT = b"prefix-props-salt"
PROMPT = list(range(300, 396))        # 1.5 pages: decode COW-breaks p1
N_TENANTS = 3


def _pcluster(tiny_factory, spool: str, shared: bool):
    nodes = []
    for i in range(2):
        cfg = ManagerConfig(spool_dir=os.path.join(spool, f"n{i}"),
                            store_salt=SALT, wake_mode="reap",
                            prefix_sharing=shared)
        nodes.append(Node(f"n{i}", tiny_factory, spool_dir=spool,
                          salt=SALT, manager_cfg=cfg))
    return ClusterRouter(nodes), nodes


def _schedule(seed: int, n_ops: int):
    """Draw a pure-data op schedule; liveness/placement are simulated
    here so the same schedule replays on both clusters."""
    rng = np.random.default_rng(seed)
    live = {f"t{i}": [] for i in range(N_TENANTS)}
    loc = {f"t{i}": 0 for i in range(N_TENANTS)}
    ops, counter = [], 0
    for _ in range(n_ops):
        roll = float(rng.random())
        iid = f"t{int(rng.integers(N_TENANTS))}"
        sessions = live[iid]
        if roll < 0.35 or not any(live.values()):
            sid = f"s{counter}"
            counter += 1
            sessions.append(sid)
            ops.append(("adopt", iid, sid))
        elif roll < 0.60 and sessions:
            sid = sessions[int(rng.integers(len(sessions)))]
            ops.append(("decode", iid, sid, int(rng.integers(1, 64))))
        elif roll < 0.72 and sessions:
            sid = sessions.pop(int(rng.integers(len(sessions))))
            ops.append(("close", iid, sid, int(rng.integers(1, 64))))
        elif roll < 0.86:
            ops.append(("deflate", iid))
        else:
            tgt = 1 - loc[iid]
            loc[iid] = tgt
            ops.append(("migrate", iid, f"n{tgt}"))
    survivors = [(iid, sid) for iid in sorted(live) for sid in live[iid]]
    return ops, survivors


def _run(router, nodes, ops, survivors):
    """Replay the schedule; return every emitted token list (adds a
    final decode probe per surviving session)."""
    byname = {n.node_id: n for n in nodes}
    cur = {f"t{i}": "n0" for i in range(N_TENANTS)}
    for iid in cur:
        router.placement[iid] = "n0"
        router.arch_of[iid] = ARCH
        byname["n0"].engine.start_instance(iid, ARCH)
    out, tag = [], 0

    def deflate(node, iid):
        nonlocal tag
        tag += 1
        node.manager.ensure_awake(iid)
        node.engine.record_sample(iid, Request(iid, f"p{tag}", [9],
                                               max_new_tokens=1,
                                               close_session=True))
        node.manager.descend(iid, Rung.HIBERNATED)

    for op in ops:
        kind, iid = op[0], op[1]
        node = byname[cur[iid]]
        if kind == "adopt":
            out.append(node.engine.handle(
                Request(iid, op[2], np.asarray(PROMPT, np.int32),
                        max_new_tokens=3)).tokens)
        elif kind == "decode":
            out.append(node.engine.handle(
                Request(iid, op[2], [op[3]], max_new_tokens=3)).tokens)
        elif kind == "close":
            out.append(node.engine.handle(
                Request(iid, op[2], [op[3]], max_new_tokens=1,
                        close_session=True)).tokens)
        elif kind == "deflate":
            deflate(node, iid)
        else:                                          # migrate
            deflate(node, iid)
            h = router.migrate(iid, op[2])
            assert h.ok, h.error
            cur[iid] = op[2]
    for iid, sid in survivors:
        out.append(byname[cur[iid]].engine.handle(
            Request(iid, sid, [7], max_new_tokens=3)).tokens)
    return out


def _check_interleaving(tiny_factory, spool: str, seed: int,
                        n_ops: int) -> None:
    ops, survivors = _schedule(seed, n_ops)
    router_on, nodes_on = _pcluster(tiny_factory, spool + "_on", True)
    router_off, nodes_off = _pcluster(tiny_factory, spool + "_off", False)
    try:
        out_on = _run(router_on, nodes_on, ops, survivors)
        out_off = _run(router_off, nodes_off, ops, survivors)
        # adopted decode bit-exact + survivors intact, op for op
        assert out_on == out_off
        # refcounts balance: evict everything, nothing may stay charged
        for node in nodes_on:
            for iid in list(node.manager.instances):
                node.manager.evict(iid)
            pool = node.manager.pool
            assert pool.pss_bytes(PREFIX_OWNER) == 0
            for i in range(N_TENANTS):
                assert pool.pss_bytes(f"t{i}") == 0
    finally:
        router_on.close()
        router_off.close()


# ------------------------------------------------------- always-on smoke
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_interleaving_smoke(tiny_factory, spool_dir, seed):
    _check_interleaving(tiny_factory, spool_dir, seed, n_ops=10)


# ------------------------------------------------------- hypothesis
if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16), n_ops=st.integers(6, 12))
    def test_property_prefix_interleaving(tmp_path_factory, tiny_factory,
                                          seed, n_ops):
        spool = tmp_path_factory.mktemp("pfx_prop")
        _check_interleaving(tiny_factory, str(spool), seed, n_ops)
else:                                          # keep the skips VISIBLE
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_prefix_interleaving():
        pass

"""Multi-turn session semantics across hibernation cycles."""
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.serving import Request, ServingEngine
from repro.core.state import Rung


@pytest.fixture()
def eng(tiny_factory, spool_dir):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"), tiny_factory)
    return ServingEngine(mgr), mgr


def test_multi_turn_grows_session(eng):
    eng, mgr = eng
    inst = eng.start_instance("i", "llama3.2-3b")
    n0 = 0
    for turn in range(3):
        eng.handle(Request("i", "chat", np.asarray([turn + 1, turn + 2]),
                           max_new_tokens=2))
        sess = inst.kv.sessions["chat"]
        assert sess.num_tokens > n0          # prompt + generated appended
        n0 = sess.num_tokens


def test_session_tokens_match_across_hibernate_cycles(eng):
    """Three hibernate/wake cycles with a growing session: every
    continuation must equal the never-hibernated trajectory."""
    eng1, mgr = eng

    def run(mgr2, eng2, hibernate):
        inst = eng2.start_instance("i", "hymba-1.5b")
        out = []
        for turn in range(3):
            if hibernate and turn:
                eng2.record_sample("i", Request(
                    "i", f"p{turn}", np.asarray([9]), max_new_tokens=1,
                    close_session=True))
                mgr2.descend("i", Rung.HIBERNATED)
            r = eng2.handle(Request("i", "chat", np.asarray([turn + 3]),
                                    max_new_tokens=2))
            out += r.tokens
        return out

    base = run(mgr, eng1, hibernate=False)
    # fresh manager for the hibernating run
    import shutil
    shutil.rmtree(mgr.cfg.spool_dir, ignore_errors=True)
    mgr2 = InstanceManager(
        ManagerConfig(spool_dir=mgr.cfg.spool_dir + "_h", wake_mode="reap"),
        mgr.factory)
    hib = run(mgr2, ServingEngine(mgr2), hibernate=True)
    assert base == hib


def test_sessions_isolated(eng):
    """Two sessions on one instance never cross-contaminate state."""
    eng, mgr = eng
    inst = eng.start_instance("i", "mamba2-130m")
    ra1 = eng.handle(Request("i", "a", np.asarray([1, 2, 3]),
                             max_new_tokens=2))
    rb = eng.handle(Request("i", "b", np.asarray([9, 8]),
                            max_new_tokens=2))
    ra2 = eng.handle(Request("i", "a", np.asarray([4]), max_new_tokens=2))
    # replay session a alone on a fresh instance: same trajectory
    eng2, _ = (ServingEngine(mgr), mgr)
    inst2 = eng.start_instance("j", "mamba2-130m")
    sa1 = eng.handle(Request("j", "a", np.asarray([1, 2, 3]),
                             max_new_tokens=2))
    sa2 = eng.handle(Request("j", "a", np.asarray([4]), max_new_tokens=2))
    assert (ra1.tokens, ra2.tokens) == (sa1.tokens, sa2.tokens)


def test_close_session_frees_on_next_deflate(eng):
    eng, mgr = eng
    inst = eng.start_instance("i", "yi-6b")
    eng.handle(Request("i", "tmp", np.asarray([1, 2, 3, 4]),
                       max_new_tokens=2, close_session=True))
    assert mgr.pool.rss_bytes("i") > 0       # closed but not yet reclaimed
    st = mgr.descend("i", Rung.HIBERNATED)
    assert st.kv_pages_reclaimed > 0         # trim() returned them
    assert st.kv_pages_swapped == 0          # nothing live to swap
"""Hypothesis property tests for the binary wire codec.

Split from test_transport.py because importorskip at module level skips
the whole module on minimal installs — the deterministic codec tests
must always run.

The properties under test are the codec's two design rules:

* round-trip — ``decode(encode(v)) == v`` for every wire-safe value;
* canonicality — one encoding per value: ``encode(decode(b)) == b``,
  so digest tables hash identically regardless of which host built
  them.
"""
import pytest

from repro.cluster import wire
from repro.core.store import UnitMeta

hypothesis = pytest.importorskip("hypothesis")  # optional dep
from hypothesis import given, settings, strategies as st  # noqa: E402

# scalars whose equality survives a round-trip (NaN floats don't compare
# equal to themselves; the codec carries them fine but == can't test it)
_scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(min_value=-(1 << 63), max_value=(1 << 63) - 1),
    st.floats(allow_nan=False),
    st.text(max_size=40),
    st.binary(max_size=40),
)

# frozenset elements must be hashable wire values
_hashables = st.one_of(
    _scalars,
    st.tuples(_scalars, _scalars),
)

_metas = st.builds(
    UnitMeta,
    digest=st.one_of(st.none(), st.binary(min_size=16, max_size=16)),
    fill=st.integers(min_value=-(1 << 31), max_value=(1 << 31) - 1),
    nbytes=st.integers(min_value=0, max_value=1 << 40),
    dtype=st.sampled_from(["float32", "int32", "uint8", "bfloat16", ""]),
    shape=st.lists(st.integers(0, 1 << 20), max_size=5).map(tuple),
)

_values = st.recursive(
    st.one_of(_scalars, _metas),
    lambda children: st.one_of(
        st.lists(children, max_size=6),
        st.lists(children, max_size=6).map(tuple),
        st.dictionaries(_hashables, children, max_size=6),
        st.frozensets(_hashables, max_size=6),
    ),
    max_leaves=25,
)


@given(v=_values)
@settings(max_examples=300, deadline=None)
def test_value_roundtrip(v):
    enc = wire.encode_value(v)
    dec = wire.decode_value(enc)
    assert dec == v
    assert wire.encode_value(dec) == enc         # canonical


@given(v=_values)
@settings(max_examples=150, deadline=None)
def test_trailing_bytes_always_rejected(v):
    enc = wire.encode_value(v)
    with pytest.raises(wire.WireError):
        wire.decode_value(enc + b"\x00")


@given(items=st.lists(
    st.tuples(st.binary(min_size=16, max_size=16),
              st.integers(0, 3),
              st.integers(0, 1 << 30),
              st.binary(max_size=200)),
    max_size=8))
@settings(max_examples=150, deadline=None)
def test_segment_chunk_roundtrip(items):
    enc = wire.encode_segments(items)
    assert wire.decode_segments(enc) == items


@given(keys=st.lists(
    st.one_of(
        # real unit-key shapes: ("weights", path, block), ("kv", sid,
        # layer, page) — plus arbitrary tuples for forward-compat
        st.tuples(st.sampled_from(["weights", "embed", "kv"]),
                  st.text(max_size=12), st.integers(0, 64)),
        st.tuples(st.just("kv"), st.text(max_size=8),
                  st.integers(0, 32), st.integers(0, 128)),
    ),
    max_size=12, unique=True))
@settings(max_examples=150, deadline=None)
def test_reap_key_order_preserved(keys):
    """First-touch order is load-bearing for the streamed wake pipeline:
    list encoding must never reorder."""
    dec = wire.decode_value(wire.encode_value(list(keys)))
    assert dec == list(keys)

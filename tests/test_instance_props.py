"""Property tests on the instance weight-unit catalog: arbitrary
swap/fault interleavings are lossless and accounting stays consistent."""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip on minimal installs
from hypothesis import given, settings, strategies as st

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import Rung

_counter = itertools.count()


@pytest.fixture(scope="module")
def make_instance(tmp_path_factory):
    import jax
    from repro.configs import get_config, tiny_config
    from repro.models import model

    cfg = tiny_config(get_config("deepseek-v2-236b"))
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    def factory(arch):
        return cfg, jax.tree.map(lambda x: x.copy(), params)

    spool = str(tmp_path_factory.mktemp("spool"))

    def make():
        mgr = InstanceManager(ManagerConfig(spool_dir=spool), factory)
        inst = mgr.cold_start(f"p{next(_counter)}", "deepseek-v2-236b")
        golden = {k: v.copy() for k, v in inst.weights.items()}
        return mgr, inst, golden

    return make


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_swap_fault_interleavings_lossless(make_instance, data):
    """Any (working set, wake mode, fault order, #cycles) sequence
    restores the exact golden weights with consistent accounting."""
    mgr, inst, golden = make_instance()
    keys = sorted(inst.units, key=repr)
    cycles = data.draw(st.integers(1, 3))
    for _ in range(cycles):
        ws_idx = data.draw(st.sets(st.integers(0, len(keys) - 1),
                                   max_size=12))
        inst.recorder.forget()
        inst.recorder.start()
        inst.recorder.record_many(keys[i] for i in sorted(ws_idx))
        ws = inst.recorder.stop()

        mgr.descend(inst.instance_id, Rung.HIBERNATED)       # ④ from WARM / ⑨ from WOKEN
        assert inst.weight_bytes() == 0
        mode = data.draw(st.sampled_from(["reap", "pagefault"]))
        wk = mgr.hib.wake(inst, mode=mode, trigger="sigcont")
        if mode == "reap":
            assert set(inst.resident) == set(ws)
        else:
            assert wk.prefetched_bytes == 0

        order = data.draw(st.permutations(range(0, len(keys), 3)))
        mgr.hib.fault(inst, [keys[i] for i in order])
        inst.ensure_all_resident()
        for path, want in golden.items():
            np.testing.assert_array_equal(inst.weights[path], want,
                                          err_msg=path)
        total = sum(u.nbytes for u in inst.units.values())
        assert inst.weight_bytes() == total
        assert inst.state.value == "woken"
    mgr.evict(inst.instance_id)

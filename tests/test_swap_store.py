"""Content-addressed SwapStore: dedup, elision, compression, refcount GC.

The acceptance bar: dedup + compression + zero-page elision must be
byte-invisible to readers (inflate returns exactly what deflate wrote),
and terminating one tenant must never corrupt another tenant's shared
units.
"""
import os

import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.store import StorePolicy, SwapStore
from repro.core.state import Rung


@pytest.fixture()
def store(spool_dir):
    s = SwapStore(f"{spool_dir}/store.cas", salt=b"test-salt")
    yield s
    s.close()


def _rand(n, seed=0, dtype=np.float32):
    return np.random.default_rng(seed).standard_normal(n).astype(dtype)


def test_roundtrip_mixed_units(store):
    c = store.client("t0")
    units = {
        ("w", "a", -1): _rand(300, 1),
        ("w", "b", 0): np.zeros((64, 8), np.float32),          # elided
        ("w", "c", 2): np.full((33,), 7, np.int8),             # elided
        ("kv", "s", 0, 0): _rand(128, 2).reshape(16, 8),
        ("w", "empty", -1): np.zeros((0,), np.float32),
    }
    c.write_units(list(units.items()))
    out = c.read_units(list(units))
    for k, a in units.items():
        np.testing.assert_array_equal(out[k], a)
        assert out[k].dtype == a.dtype and out[k].shape == a.shape
    # constant units cost no disk bytes at all
    st = store.stats()
    assert st["elisions"] >= 3
    assert st["stored_bytes"] < sum(a.nbytes for a in units.values())


def test_cross_tenant_dedup_stores_once(store):
    payload = _rand(4096, 7)
    for t in range(8):
        store.client(f"t{t}").write_unit(("w", "shared", -1), payload)
    st = store.stats()
    assert st["segments"] == 1
    assert st["logical_bytes"] == 8 * payload.nbytes
    assert st["stored_bytes"] == payload.nbytes        # stored exactly once
    assert st["dedup_hits"] == 7
    for t in range(8):
        np.testing.assert_array_equal(
            store.client(f"t{t}").read_unit(("w", "shared", -1)), payload)


def test_rewrite_identical_is_free(store):
    """Re-deflating unchanged weights must not grow the file or refs."""
    c = store.client("t0")
    payload = _rand(1024, 3)
    c.write_unit("k", payload)
    size0 = store.file_bytes
    for _ in range(5):
        r = c.write_units([("k", payload)])
        assert r.stored_bytes == 0 and r.dedup_bytes == payload.nbytes
    assert store.file_bytes == size0
    np.testing.assert_array_equal(c.read_unit("k"), payload)


def test_refcount_gc_never_corrupts_other_tenant(store):
    """Terminating one tenant frees only unshared segments; the survivor
    reads back bit-exact data afterwards."""
    shared = _rand(2048, 11)
    only_a = _rand(512, 12)
    only_b = _rand(777, 13)
    a, b = store.client("a"), store.client("b")
    a.write_units([("s", shared), ("pa", only_a)])
    b.write_units([("s", shared), ("pb", only_b)])
    assert store.stats()["segments"] == 3
    live0 = store.live_bytes
    reclaimed = store.release(a)
    # only A's private segment is freed; the shared one survives
    assert reclaimed == only_a.nbytes
    assert store.live_bytes == live0 - only_a.nbytes
    np.testing.assert_array_equal(b.read_unit("s"), shared)
    np.testing.assert_array_equal(b.read_unit("pb"), only_b)
    store.release(b)
    assert store.stats()["segments"] == 0 and store.live_bytes == 0


def test_gc_extents_are_reused(store):
    """Freed extents go back to the allocator: tenant churn must not grow
    the segment file unboundedly."""
    for cycle in range(6):
        c = store.client(f"gen{cycle}")
        c.write_units([(i, _rand(256, seed=1000 + cycle * 8 + i))
                       for i in range(8)])
        size = store.file_bytes
        store.release(c)
        if cycle == 0:
            first_size = size
        assert size <= first_size        # reuse, not append-forever
    assert store.file_bytes == 0         # trailing free space truncated


def test_cold_units_sink_to_compression(spool_dir):
    """A unit that keeps missing the working set is recompressed at a
    higher tier — and still inflates byte-exact."""
    s = SwapStore(f"{spool_dir}/c.cas", salt=b"x",
                  policy=StorePolicy(tiers=((0, 0), (2, 6)), min_size=64))
    c = s.client("t")
    # compressible payload (structured, not noise)
    payload = np.tile(np.arange(64, dtype=np.float32), 64)
    miss = {"k": 0}
    c.hotness = lambda key: miss["k"]
    c.write_unit("k", payload)
    raw_stored = s.stats()["stored_bytes"]
    assert raw_stored == payload.nbytes          # miss 0 -> raw tier
    miss["k"] = 5
    c.write_unit("k", payload)                   # identical rewrite, cold now
    st = s.stats()
    assert st["sink_events"] == 1
    assert st["stored_bytes"] < raw_stored       # sunk to zlib tier
    np.testing.assert_array_equal(c.read_unit("k"), payload)
    s.close()


def test_incompressible_stays_raw_without_thrash(spool_dir):
    s = SwapStore(f"{spool_dir}/i.cas", salt=b"x",
                  policy=StorePolicy(tiers=((0, 9),), min_size=64))
    c = s.client("t")
    noise = np.frombuffer(os.urandom(4096), np.uint8)
    c.write_unit("k", noise)
    assert s.stats()["stored_bytes"] == noise.nbytes   # zlib didn't shrink it
    writes0 = s.writes
    c.write_unit("k", noise)                           # tried_level remembers
    assert s.writes == writes0 and s.sink_events == 0
    np.testing.assert_array_equal(c.read_unit("k"), noise)
    s.close()


def test_vectored_read_coalesces_segments(store):
    c = store.client("t")
    items = [((i,), _rand(64, seed=i)) for i in range(64)]
    c.write_units(items)
    reads0 = c.reads
    out = c.read_units([k for k, _ in items])
    assert (c.reads - reads0) * 4 <= len(items)   # merged preadv runs
    for k, a in items:
        np.testing.assert_array_equal(out[k], a)


def test_manager_evict_isolated_between_tenants(tiny_factory, spool_dir):
    """End-to-end: two tenants of one arch share segments; evicting one
    leaves the other's hibernated state fully restorable, bit-exact."""
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="pagefault",
                      store_salt=b"fixed"), tiny_factory)
    a = mgr.cold_start("a", "llama3.2-3b")
    b = mgr.cold_start("b", "llama3.2-3b")
    before = {k: v.copy() for k, v in b.weights.items()}
    mgr.descend("a", Rung.HIBERNATED)
    mgr.descend("b", Rung.HIBERNATED)
    # identical params -> the swap tier is stored once
    st = mgr.store.stats()
    assert st["stored_bytes"] < st["logical_bytes"]
    mgr.hib.wake(mgr.instances["a"], mode="pagefault", trigger="sigcont")
    mgr.evict("a")
    mgr.hib.fault(b, b.nonresident_keys())
    for k, v in before.items():
        np.testing.assert_array_equal(b.weights[k], v)

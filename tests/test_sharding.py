"""Sharding-spec validity without 512 devices: every spec must evenly
divide its leaf on the refined production-mesh *shape* (pure math — the
dry-run proves end-to-end lowering, this catches regressions fast)."""
import jax
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import sharding as shd
from repro.models import model


class FakeMesh:
    """Shape-only stand-in for the refined production mesh."""

    def __init__(self, cfg, multi_pod=False):
        self.shape = {"data": 16, "tp": cfg.tp, "sp": cfg.sp}
        if multi_pod:
            self.shape = {"pod": 2, **self.shape}
        self.axis_names = tuple(self.shape)

    @property
    def devices(self):
        raise AssertionError("spec test must not touch devices")


def _check_specs(tree, specs, mesh):
    flat_v = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: x is None or
                             hasattr(x, "index"))
    assert len(flat_v) == len(flat_s)
    for v, spec in zip(flat_v, flat_s):
        if spec is None:
            continue
        for dim, entry in zip(v.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= mesh.shape.get(a, 1)
            assert dim % n == 0, (spec, v.shape, dim, n)


@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divide(arch, mode, multi_pod):
    cfg = get_config(arch)
    assert cfg.tp * cfg.sp == 16, f"{arch}: tp*sp must equal model axis"
    mesh = FakeMesh(cfg, multi_pod)
    tree = jax.eval_shape(lambda k: model.init_params(k, cfg),
                          jax.random.PRNGKey(0))
    specs = shd.params_specs(tree, cfg, mode, mesh)
    _check_specs(tree, specs, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_expert_banks_are_expert_parallel(arch):
    """MoE expert banks must shard E over data — they cannot replicate."""
    cfg = get_config(arch)
    if cfg.moe is None:
        pytest.skip("dense")
    mesh = FakeMesh(cfg)
    tree = jax.eval_shape(lambda k: model.init_params(k, cfg),
                          jax.random.PRNGKey(0))
    import jax.tree_util as jtu
    from repro.core.instance import _path_str
    flat = jtu.tree_flatten_with_path(tree)[0]
    found = 0
    for p, v in flat:
        path = _path_str(p)
        if "/moe/w_" in path and "/shared/" not in path \
                and "/dense/" not in path:
            spec = shd.sanitize_spec(
                shd.param_spec(path, v.ndim, cfg, "decode", mesh),
                v.shape, mesh)
            assert spec[1] == "data", (path, spec)
            found += 1
    assert found == 3


@pytest.mark.parametrize("batch,expected", [
    (256, ("data", "sp")), (32, ("data", "sp")), (128, ("data", "sp")),
    (1, None), (8, ("data",)),
])
def test_batch_axes_prefix(batch, expected):
    cfg = get_config("llama3.2-3b")          # tp=8, sp=2
    mesh = FakeMesh(cfg)
    got = shd.batch_axes(mesh, batch)
    if batch == 8:
        assert got is None or got == ("data",)
    else:
        assert got == expected, (batch, got)


def test_batch_axes_never_overdivide():
    cfg = get_config("hymba-1.5b")           # sp=16
    mesh = FakeMesh(cfg, multi_pod=True)     # pod2 x data16 x sp16
    assert shd.batch_axes(mesh, 256) == ("pod", "data")   # 512 ∤ 256


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_activation_rules_no_duplicate_axes(arch):
    cfg = get_config(arch)
    mesh = FakeMesh(cfg)
    for shape in SHAPES.values():
        mode = {"train": "train", "prefill": "prefill",
                "decode": "decode"}[shape.kind]
        rules = shd.activation_rules(cfg, mode, mesh, shape.global_batch)
        for name, spec in rules.items():
            seen = []
            for entry in tuple(spec):
                if entry is None:
                    continue
                for a in (entry if isinstance(entry, tuple) else (entry,)):
                    assert a not in seen, (arch, shape.name, name, spec)
                    seen.append(a)

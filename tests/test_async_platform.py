"""AsyncPlatform concurrency layer: wake-storm dedup, background policy
daemon, admission control, worker-pool serving."""
import threading
import time

import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import ContainerState
from repro.core.state import Rung
from repro.serving import (AdmissionError, AsyncPlatform, Platform,
                           PlatformPolicy, Request, ServingEngine)

S = ContainerState
ARCH_OF = {"fn-a": "llama3.2-3b", "fn-b": "mamba2-130m"}


def _mk_engine(tiny_factory, spool_dir):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"), tiny_factory)
    return ServingEngine(mgr), mgr


def _req(iid, sid, n=3, new=1, **kw):
    return Request(iid, sid, np.arange(1, n + 1, dtype=np.int32),
                   max_new_tokens=new, **kw)


def _hibernate(eng, mgr, iid="fn-a"):
    """Cold-start, record a working set, deflate."""
    eng.start_instance(iid, ARCH_OF[iid])
    eng.record_sample(iid, _req(iid, "probe", new=1, close_session=True))
    mgr.descend(iid, Rung.HIBERNATED)
    assert mgr.instances[iid].state == S.HIBERNATE


def test_wake_storm_shares_single_inflate(tiny_factory, spool_dir):
    """N threads hit one HIBERNATE instance -> exactly one batched inflate
    (one streamed pipeline, a bounded handful of chunked REAP reads),
    every request served."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    _hibernate(eng, mgr)
    inst = mgr.instances["fn-a"]
    reads0, wakes0 = inst.reap_file.reads, mgr.wakes_performed

    n = 8
    plat = AsyncPlatform(eng, PlatformPolicy(keep_warm_s=1e9), ARCH_OF,
                         workers=n)
    barrier = threading.Barrier(n)
    futs = [None] * n

    def hit(i):
        barrier.wait()
        futs[i] = plat.submit(_req("fn-a", f"storm{i}"))

    with plat:
        threads = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        resps = [f.result(timeout=120) for f in futs]

    assert mgr.wakes_performed - wakes0 == 1      # one inflate for the storm
    # pipelined wake: one vectored read per chunk, never one per caller.
    # The bound is the chunk count of the stream, not the storm size.
    if inst.wake_pipeline is not None:
        inst.wake_pipeline.wait(30)
        max_reads = max(1, len(inst.wake_pipeline.chunks))
    else:
        max_reads = 1
    assert 1 <= inst.reap_file.reads - reads0 <= max_reads
    assert all(len(r.tokens) >= 1 for r in resps)
    assert inst.state == S.WOKEN


def test_ensure_awake_thread_dedup(tiny_factory, spool_dir):
    """Direct manager-level storm: one WakeStats, the rest deduped."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    _hibernate(eng, mgr)

    n = 8
    barrier = threading.Barrier(n)
    results = [None] * n

    def race(i):
        barrier.wait()
        results[i] = mgr.ensure_awake("fn-a")

    threads = [threading.Thread(target=race, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    performed = [r for r in results if r is not None]
    assert len(performed) == 1
    assert mgr.wakes_deduped == n - 1


def test_daemon_deflates_idle_tenant(tiny_factory, spool_dir):
    """Keep-alive expiry is enforced by the background daemon — no manual
    tick() calls anywhere."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    pol = PlatformPolicy(keep_warm_s=0.0, tick_interval_s=0.02)
    with AsyncPlatform(eng, pol, ARCH_OF, workers=2) as plat:
        plat.submit(_req("fn-a", "s0")).result(timeout=120)
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline and \
                mgr.instances["fn-a"].state != S.HIBERNATE:
            time.sleep(0.02)
        assert mgr.instances["fn-a"].state == S.HIBERNATE
    assert any(e[1] == "deflate" for e in plat.log)


def test_daemon_handles_memory_pressure(tiny_factory, spool_dir):
    """The daemon deflates (never evicts) under a memory target."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    pol = PlatformPolicy(keep_warm_s=1e9, tick_interval_s=0.02,
                         memory_target_bytes=0)
    with AsyncPlatform(eng, pol, ARCH_OF, workers=2) as plat:
        plat.submit(_req("fn-a", "s0")).result(timeout=120)
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline and \
                mgr.instances["fn-a"].state != S.HIBERNATE:
            time.sleep(0.02)
    assert mgr.instances["fn-a"].state == S.HIBERNATE
    assert "fn-a" in mgr.instances                # deflated, NOT evicted


def test_admission_control_rejects_when_full(tiny_factory, spool_dir):
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    pol = PlatformPolicy(max_queue_depth=2)

    # async platform: rejection is parked on the returned future
    aplat = AsyncPlatform(eng, pol, ARCH_OF, workers=0)  # nothing drains
    for i in range(2):
        assert not aplat.submit(_req("fn-a", f"s{i}")).done()
    rej = aplat.submit(_req("fn-a", "s2"))        # over depth -> rejected
    with pytest.raises(AdmissionError):
        rej.result(timeout=1)
    assert aplat.rejected == 1

    # sync shim: legacy callers ignore the future, so submit raises
    plat = Platform(eng, pol, ARCH_OF)
    f1 = plat.submit(_req("fn-a", "s0"))
    f2 = plat.submit(_req("fn-a", "s1"))
    with pytest.raises(AdmissionError):
        plat.submit(_req("fn-a", "s2"))
    assert plat.rejected == 1
    # other tenants are unaffected by fn-a's full queue
    assert not plat.submit(_req("fn-b", "s0")).done()
    [r1, r2, r4] = plat.step()
    assert r1.request.session_id == "s0"
    assert f1.done() and f2.done()


def test_worker_pool_serves_tenants_concurrently(tiny_factory, spool_dir):
    """Two tenants served by two workers; both futures resolve and each
    tenant's state machine lands where a sequential serve would."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    with AsyncPlatform(eng, PlatformPolicy(keep_warm_s=1e9), ARCH_OF,
                       workers=2) as plat:
        futs = [plat.submit(_req("fn-a", "a0", new=2)),
                plat.submit(_req("fn-b", "b0", new=2))]
        resps = [f.result(timeout=120) for f in futs]
    assert {r.request.instance_id for r in resps} == {"fn-a", "fn-b"}
    assert all(r.state_after == "warm" for r in resps)
    assert mgr.states() == {"fn-a": "warm", "fn-b": "warm"}


def test_submit_error_propagates_to_future(tiny_factory, spool_dir):
    """An unknown tenant (no arch mapping) fails the future, not a worker."""
    eng, mgr = _mk_engine(tiny_factory, spool_dir)
    with AsyncPlatform(eng, PlatformPolicy(keep_warm_s=1e9), ARCH_OF,
                       workers=1) as plat:
        fut = plat.submit(_req("fn-unknown", "s0"))
        with pytest.raises(KeyError):
            fut.result(timeout=30)

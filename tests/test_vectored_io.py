"""Vectored swap IO: preadv batch reads vs per-unit random reads, pwritev
batch writes, and the ftruncate fix for shrinking REAP rewrites."""
import os

import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.swap import ReapFile, SwapFile
from repro.core.state import Rung


def _units(n, sz=256, seed=0):
    rng = np.random.default_rng(seed)
    return [((i,), rng.standard_normal(sz).astype(np.float32))
            for i in range(n)]


def test_swapfile_vectored_equals_per_unit(spool_dir):
    """read_units must return bit-identical data to read_unit, key by key."""
    f = SwapFile(f"{spool_dir}/v.swap")
    items = _units(64)
    f.write_units(items)
    per_unit = {k: f.read_unit(k) for k, _ in items}
    reads0 = f.reads
    batched = f.read_units([k for k, _ in items])
    assert set(batched) == set(per_unit)
    for k in per_unit:
        np.testing.assert_array_equal(batched[k], per_unit[k])
    # 64 contiguous extents merge into far fewer syscalls than 64 preads
    assert (f.reads - reads0) * 4 <= len(items)
    f.delete()


def test_reapfile_vectored_equals_per_unit(spool_dir):
    f = ReapFile(f"{spool_dir}/v.reap")
    items = _units(32, seed=1)
    f.write_batch(items)
    keys = [k for k, _ in items]
    batched = f.read_units(keys)
    for k, a in items:
        np.testing.assert_array_equal(batched[k], a)
        np.testing.assert_array_equal(f.read_unit(k), a)
    f.delete()


def test_vectored_read_of_gapped_subset(spool_dir):
    """Non-adjacent extents split into runs but stay correct."""
    f = SwapFile(f"{spool_dir}/g.swap")
    items = _units(30, seed=2)
    f.write_units(items)
    subset = [items[i][0] for i in range(0, 30, 3)]
    out = f.read_units(subset)
    assert set(out) == set(subset)
    for i in range(0, 30, 3):
        np.testing.assert_array_equal(out[items[i][0]], items[i][1])
    f.delete()


def test_vectored_read_unsorted_keys(spool_dir):
    """Keys arrive in arbitrary order; extents are sorted before merging."""
    f = SwapFile(f"{spool_dir}/u.swap")
    items = _units(16, seed=3)
    f.write_units(items)
    keys = [k for k, _ in items][::-1]
    reads0 = f.reads
    out = f.read_units(keys)
    assert f.reads - reads0 == 1          # still one merged run
    for k, a in items:
        np.testing.assert_array_equal(out[k], a)
    f.delete()


def test_reap_shrinking_rewrite_truncates(spool_dir):
    """A smaller rewrite must not leave stale trailing bytes on disk:
    file_bytes tracks the real footprint the memory benchmarks report."""
    f = ReapFile(f"{spool_dir}/t.reap")
    f.write_batch(_units(32, seed=4))
    big = os.path.getsize(f.path)
    assert f.file_bytes == big
    f.write_batch(_units(4, seed=5))
    assert f.file_bytes == os.path.getsize(f.path) < big
    # and an empty working set clears the file entirely
    f.write_batch([])
    assert f.file_bytes == os.path.getsize(f.path) == 0
    assert not f.extents
    f.delete()


def test_instance_fault_path_is_vectored(tiny_factory, spool_dir):
    """HibernationManager.fault coalesces the whole fault set: restoring
    every unit of a deflated instance takes >=4x fewer syscalls than one
    pread per unit (the acceptance bar for the inflate path)."""
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="pagefault"),
        tiny_factory)
    inst = mgr.cold_start("i0", "llama3.2-3b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    mgr.descend("i0", Rung.HIBERNATED)
    reads0 = inst.swap_file.reads + inst.reap_file.reads
    st = mgr.hib.fault(inst, inst.nonresident_keys())
    syscalls = inst.swap_file.reads + inst.reap_file.reads - reads0
    assert st.faults == len(inst.units)
    assert syscalls * 4 <= st.faults, \
        f"{syscalls} syscalls for {st.faults} faulted units"
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)

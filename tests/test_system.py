"""Whole-system scenario: the paper's deployment story on one node.

Multiple tenants, a platform with keep-alive policy, memory pressure that
deflates instead of evicting, predictive wake, density accounting.
"""
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import memory_report
from repro.core.state import ContainerState
from repro.serving import Platform, PlatformPolicy, Request, ServingEngine
from repro.core.state import Rung

S = ContainerState


@pytest.fixture()
def platform(tiny_factory, spool_dir):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"), tiny_factory)
    eng = ServingEngine(mgr)
    pol = PlatformPolicy(keep_warm_s=0.0)     # tick() deflates immediately
    arch_of = {"fn-a": "llama3.2-3b", "fn-b": "mamba2-130m",
               "fn-c": "phi4-mini-3.8b"}
    return Platform(eng, pol, arch_of), mgr


def test_platform_cold_then_hibernate_then_wake(platform):
    plat, mgr = platform
    plat.submit(Request("fn-a", "s0", np.asarray([1, 2, 3]),
                        max_new_tokens=2))
    [r1] = plat.step()
    assert r1.state_before == "warm"          # fresh cold start
    assert mgr.instances["fn-a"].state == S.WARM
    plat.tick()                               # keep-alive expired -> deflate
    assert mgr.instances["fn-a"].state == S.HIBERNATE
    plat.submit(Request("fn-a", "s1", np.asarray([4]), max_new_tokens=2))
    [r2] = plat.step()
    assert r2.state_before == "hibernate" and r2.state_after == "woken"


def test_density_hibernate_packs_more_tenants(platform):
    """The paper's headline: deflated tenants co-reside where warm ones
    would not fit."""
    plat, mgr = platform
    for fn in ("fn-a", "fn-b", "fn-c"):
        plat.submit(Request(fn, "s0", np.asarray([1, 2]), max_new_tokens=2))
    plat.step()
    warm_total = mgr.resident_bytes()
    budget = int(warm_total * 0.4)            # < the 3 warm tenants
    deflated = mgr.handle_memory_pressure(budget)
    assert deflated                           # some tenants deflated...
    assert len(mgr.instances) == 3            # ...but NONE evicted
    assert mgr.resident_bytes() <= budget
    # all three still servable without a cold start
    for fn in ("fn-a", "fn-b", "fn-c"):
        assert mgr.instances[fn].state in (S.WARM, S.HIBERNATE, S.WOKEN)


def test_predictive_wake(platform):
    plat, mgr = platform
    plat.policy.predictive_wake = True
    plat.submit(Request("fn-b", "s0", np.asarray([5]), max_new_tokens=1))
    plat.step()
    plat.tick()
    assert mgr.instances["fn-b"].state == S.HIBERNATE
    # ⑤: queueing a request wakes the instance before processing
    plat.submit(Request("fn-b", "s1", np.asarray([6]), max_new_tokens=1))
    assert mgr.instances["fn-b"].state == S.WOKEN
    [r] = plat.step()
    assert r.state_before == "woken"


def test_classic_mode_evicts(tiny_factory, spool_dir):
    """deflate_instead_of_evict=False reproduces the baseline platform the
    paper compares against (eviction -> cold start)."""
    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir), tiny_factory)
    eng = ServingEngine(mgr)
    plat = Platform(eng, PlatformPolicy(keep_warm_s=0.0,
                                        deflate_instead_of_evict=False),
                    {"fn-a": "llama3.2-3b"})
    plat.submit(Request("fn-a", "s0", np.asarray([1]), max_new_tokens=1))
    plat.step()
    plat.tick()
    assert "fn-a" not in mgr.instances        # evicted
    plat.submit(Request("fn-a", "s1", np.asarray([2]), max_new_tokens=1))
    [r] = plat.step()
    assert ("cold_start", ) in {(e[1],) for e in plat.log}


def test_pss_accounting_states(platform):
    plat, mgr = platform
    plat.submit(Request("fn-a", "s0", np.asarray([1, 2]), max_new_tokens=2))
    plat.step()
    inst = mgr.instances["fn-a"]
    warm = memory_report(inst, mgr.shared)
    mgr.descend("fn-a", Rung.HIBERNATED)
    hib = memory_report(inst, mgr.shared)
    assert hib.pss_total < warm.pss_total
    assert hib.state == "hibernate"
    assert hib.weight_private == 0
    assert hib.metadata > 0                   # kept-alive host objects


def test_anticipatory_wake(platform):
    """⑤ control-plane prediction: a periodic tenant is woken before its
    next request arrives (EWMA inter-arrival model)."""
    plat, mgr = platform
    plat.policy.anticipate_margin_s = 0.5
    # establish a ~1s cadence with virtual clocks
    for i, t in enumerate((100.0, 101.0, 102.0)):
        plat.submit(Request("fn-b", f"s{i}", np.asarray([1 + i]),
                            max_new_tokens=1, close_session=True), now=t)
        plat.step()
    mgr.instances["fn-b"].last_used = 102.0     # align to the virtual clock
    plat.tick(now=102.1)                 # keep-alive 0 -> deflate
    assert mgr.instances["fn-b"].state == S.HIBERNATE
    plat.tick(now=102.2)                 # next due ~103.0: not yet
    assert mgr.instances["fn-b"].state == S.HIBERNATE
    plat.tick(now=102.6)                 # within 0.5s margin -> wake
    assert mgr.instances["fn-b"].state == S.WOKEN
    assert any(e[1] == "anticipated_wake" for e in plat.log)

"""Hibernation core: 4-step deflation, both inflate paths, bit-exactness.

The paper's central claims at unit level:
  * deflation reclaims (almost) all anonymous memory;
  * REAP wake = one batched read restoring exactly the working set;
  * pagefault wake restores nothing upfront, faults restore on access;
  * a hibernate/wake cycle is lossless (weights bit-exact).
"""
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import memory_report
from repro.core.state import ContainerState, Event
from repro.core.state import Rung


@pytest.fixture()
def mgr(tiny_factory, spool_dir):
    return InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap"),
        tiny_factory)


def _start(mgr, arch="llama3.2-3b", iid="i0"):
    inst = mgr.cold_start(iid, arch)
    return inst


def test_deflate_reclaims_weights(mgr):
    inst = _start(mgr)
    warm = inst.weight_bytes()
    assert warm > 0
    st = mgr.descend("i0", Rung.HIBERNATED)
    assert inst.state == ContainerState.HIBERNATE
    assert inst.weight_bytes() == 0
    assert st.swap_bytes + st.reap_bytes == warm
    assert st.reap_bytes == 0            # nothing recorded yet -> all swap


def test_wake_is_bit_exact(mgr):
    inst = _start(mgr)
    before = {k: v.copy() for k, v in inst.weights.items()}
    mgr.descend("i0", Rung.HIBERNATED)
    # pagefault everything back
    st = mgr.hib.fault(inst, inst.nonresident_keys())
    assert st.faults == len(inst.units)
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


def test_reap_wake_restores_working_set_only(mgr):
    inst = _start(mgr)
    # record a synthetic working set: embed block 0 + half the units
    units = list(inst.units)
    ws = frozenset(units[: len(units) // 2])
    inst.recorder.start()
    inst.recorder.record_many(ws)
    inst.recorder.stop()
    st = mgr.descend("i0", Rung.HIBERNATED)
    assert st.reap_bytes > 0 and st.swap_bytes > 0
    wk = mgr.hib.wake(inst, mode="reap", trigger="sigcont")
    assert inst.state == ContainerState.WOKEN
    assert wk.prefetched_bytes == st.reap_bytes
    assert set(inst.resident) == set(ws)
    # woken memory < warm memory (the paper's Fig. 7 claim, unit level)
    assert inst.weight_bytes() < sum(u.nbytes for u in inst.units.values())


def test_pagefault_wake_restores_nothing(mgr):
    inst = _start(mgr)
    mgr.descend("i0", Rung.HIBERNATED)
    wk = mgr.hib.wake(inst, mode="pagefault", trigger="sigcont")
    assert wk.prefetched_bytes == 0
    assert inst.weight_bytes() == 0
    # first access faults
    key = next(iter(inst.units))
    st = mgr.hib.fault(inst, [key])
    assert st.faults == 1 and st.faulted_bytes == inst.units[key].nbytes


def test_expert_units_are_separate(tiny_factory, spool_dir):
    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir), tiny_factory)
    inst = mgr.cold_start("m0", "deepseek-v2-236b")
    cfg = inst.cfg
    expert_units = [k for k in inst.units if k[2] >= 0 and "/moe/" in k[1]]
    # 3 expert mats x num_experts units
    assert len(expert_units) == 3 * cfg.moe.num_experts
    # faulting one expert loads only that expert's bytes
    mgr.descend("m0", Rung.HIBERNATED)
    one = expert_units[0]
    st = mgr.hib.fault(inst, [one])
    assert st.faulted_bytes == inst.units[one].nbytes
    total = sum(inst.units[k].nbytes for k in expert_units)
    assert st.faulted_bytes < total / cfg.moe.num_experts + 1


def test_swap_files_deleted_on_evict(tiny_factory, spool_dir):
    """§3.4: private per-sandbox files are unlinked at termination."""
    import os
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap",
                      dedup_store=False), tiny_factory)
    inst = _start(mgr)
    mgr.descend("i0", Rung.HIBERNATED)
    paths = [inst.swap_file.path, inst.reap_file.path]
    assert all(os.path.exists(p) for p in paths)
    mgr.hib.wake(inst, mode="reap", trigger="sigcont")
    mgr.evict("i0")
    assert not any(os.path.exists(p) for p in paths)
    assert inst.state == ContainerState.DEAD


def test_store_released_on_evict(mgr):
    """Dedup mode: evicting a tenant decrefs its store units (the shared
    segment file survives for other tenants) and deletes its REAP file."""
    import os
    inst = _start(mgr)
    mgr.descend("i0", Rung.HIBERNATED)
    assert inst.swap_file.extents and mgr.store.stats()["stored_bytes"] > 0
    mgr.hib.wake(inst, mode="reap", trigger="sigcont")
    mgr.evict("i0")
    assert inst.state == ContainerState.DEAD
    assert not inst.swap_file.extents
    assert mgr.store.stats()["stored_bytes"] == 0      # sole tenant: all GC'd
    assert not os.path.exists(inst.reap_file.path)
    assert os.path.exists(mgr.store.path)              # deployment-lifetime


def test_memory_pressure_deflates_lru(mgr):
    a = _start(mgr, iid="a")
    b = _start(mgr, iid="b")
    a.last_used, b.last_used = 1.0, 2.0
    deflated = mgr.handle_memory_pressure(target_bytes=a.weight_bytes() + 1)
    assert deflated[0] == "a"                 # LRU order
    assert mgr.instances["a"].state == ContainerState.HIBERNATE


def test_shared_weights_refcount(tiny_factory, spool_dir):
    loads = []

    def loader(base_id):
        cfg, params = tiny_factory(base_id)
        loads.append(base_id)
        import jax
        from repro.core.instance import _path_str
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return {_path_str(p): np.asarray(v) for p, v in flat
                if _path_str(p) == "embed"}

    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir),
                          tiny_factory, shared_loader=loader)
    a = mgr.cold_start("a", "llama3.2-3b", shared_paths={"embed"})
    b = mgr.cold_start("b", "llama3.2-3b", shared_paths={"embed"})
    assert mgr.shared.refcount("llama3.2-3b") == 2
    assert len(loads) == 1                     # loaded once, shared
    # shared leaves are not swapped on deflation (clean file-backed pages)
    st = mgr.descend("a", Rung.HIBERNATED)
    assert st.shared_bytes_released == 0       # b still holds a ref
    assert "embed" not in {k[1] for k in a.swap_file.extents}
    st2 = mgr.descend("b", Rung.HIBERNATED)
    assert st2.shared_bytes_released > 0       # last ref -> dropped
    # PSS splits shared bytes across sharers
    rep = memory_report(b, mgr.shared)
    assert rep.weight_shared_pss == 0          # dropped at refcount 0


def test_descend_rejects_non_deflation_rungs(mgr):
    _start(mgr)
    with pytest.raises(ValueError):
        mgr.descend("i0", Rung.WARM)



"""Paged KV cache: sessions, COW forks, trim, swap/fault cycle."""
import numpy as np
import pytest

from repro.configs import get_config, tiny_config
from repro.core.pool import PagePool
from repro.core.swap import ReapFile, SwapFile
from repro.serving.paged_kv import PagedKVCache


@pytest.fixture()
def cache():
    cfg = tiny_config(get_config("llama3.2-3b"))
    pool = PagePool(page_elems=256, capacity_pages=1 << 14)
    return PagedKVCache("i0", cfg, pool), cfg, pool


def _rand_kv(cache, n_tok):
    rng = np.random.default_rng(0)
    return rng.standard_normal((n_tok, cache.token_elems)).astype(np.float32)


def test_write_read_roundtrip(cache):
    kv, cfg, pool = cache
    kv.new_session("s")
    data = _rand_kv(kv, 37)
    for l in range(cfg.num_layers):
        kv.write_tokens("s", l, data, 0)
    for l in range(cfg.num_layers):
        np.testing.assert_allclose(kv.read_tokens("s", l, 37), data)
    # appending more tokens extends pages
    more = _rand_kv(kv, 5)
    kv.write_tokens("s", 0, more, 37)
    np.testing.assert_allclose(kv.read_tokens("s", 0, 42),
                               np.concatenate([data, more]))


def test_fork_cow_shares_pages(cache):
    kv, cfg, pool = cache
    kv.new_session("s")
    data = _rand_kv(kv, 20)
    for l in range(cfg.num_layers):
        kv.write_tokens("s", l, data, 0)
    kv.sessions["s"].num_tokens = 20
    before = pool.used_bytes
    kv.fork_session("s", "t")
    assert pool.used_bytes == before          # no new pages: COW
    np.testing.assert_allclose(kv.read_tokens("t", 0, 20), data)
    # refcounts: freeing the original keeps the fork readable
    kv.close_session("s")
    kv.trim()
    np.testing.assert_allclose(kv.read_tokens("t", 0, 20), data)


def test_trim_reclaims_closed_sessions(cache):
    kv, cfg, pool = cache
    kv.new_session("s")
    for l in range(cfg.num_layers):
        kv.write_tokens("s", l, _rand_kv(kv, 16), 0)
    used = pool.used_bytes
    assert used > 0
    kv.close_session("s")
    assert pool.used_bytes == used            # guest-freed, not yet returned
    assert kv.trim() > 0                      # deflation step 2
    assert pool.used_bytes == 0


def test_swap_cycle_restores_exact_bytes(cache, spool_dir):
    kv, cfg, pool = cache
    swap = SwapFile(f"{spool_dir}/i0.swap")
    reap = ReapFile(f"{spool_dir}/i0.reap")
    kv.new_session("s")
    data = _rand_kv(kv, 40)
    for l in range(cfg.num_layers):
        kv.write_tokens("s", l, data, 0)
    kv.sessions["s"].num_tokens = 40
    kv.set_host_unit("s", "all", "state", np.ones((2, 3), np.float32))

    ws = frozenset([("kv", "s", 0, 0), ("kvh", "s", "all", "state")])
    reap_items, swap_items = kv.export_items(ws)
    assert {k for k, _ in reap_items} == set(ws)
    reap.write_batch(reap_items)
    swap.write_units(swap_items)
    kv.drop_pages()
    assert pool.used_bytes == 0

    # REAP prefetch restores the working set only
    kv.apply_prefetch(reap.read_batch())
    np.testing.assert_allclose(
        kv.read_tokens("s", 0, kv.page_tokens)[:kv.page_tokens],
        data[:kv.page_tokens])
    np.testing.assert_array_equal(kv.get_host_unit("s", "all", "state"),
                                  np.ones((2, 3), np.float32))
    # the rest page-faults in
    missing = kv.nonresident_keys(kv.keys_for("s"))
    assert missing
    kv.fault_in(missing, swap, reap)
    for l in range(cfg.num_layers):
        np.testing.assert_allclose(kv.read_tokens("s", l, 40), data)
    swap.delete()
    reap.delete()

"""Kernel-over-pool equivalence: the Pallas paged_attention kernel, fed
directly from bitmap-allocator pages, matches the engine's dense-gather
decode attention on live session state — including after a hibernate/wake
cycle (pages re-allocated at different physical ids)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.manager import InstanceManager, ManagerConfig
from repro.models.attention import decode_attention
from repro.serving import Request, ServingEngine
from repro.serving.paged_backend import paged_decode
from repro.core.state import Rung


@pytest.fixture()
def served_instance(tiny_factory, spool_dir):
    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir), tiny_factory)
    eng = ServingEngine(mgr)
    inst = eng.start_instance("i0", "llama3.2-3b")
    for j, n in enumerate((5, 9, 17)):
        eng.handle(Request("i0", f"s{j}", np.arange(n) % inst.cfg.vocab_size,
                           max_new_tokens=3))
    return eng, mgr, inst


def _dense_reference(inst, sids, layer, q):
    kv = inst.kv
    cfg = inst.cfg
    B = len(sids)
    S = max(kv.sessions[s].num_tokens for s in sids)
    Hkv, D = cfg.num_kv_heads, cfg.head_dim
    k = np.zeros((B, S, Hkv, D), np.float32)
    v = np.zeros((B, S, Hkv, D), np.float32)
    pos = np.full((B, S), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    for b, sid in enumerate(sids):
        n = kv.sessions[sid].num_tokens
        data = kv.read_tokens(sid, layer, n).reshape(n, 2, Hkv, D)
        k[b, :n], v[b, :n] = data[:, 0], data[:, 1]
        pos[b, :n] = np.arange(n)
        lengths[b] = n
    return decode_attention(q, jnp.asarray(k), jnp.asarray(v),
                            jnp.asarray(pos), jnp.asarray(lengths))


def test_kernel_matches_dense_on_pool(served_instance):
    eng, mgr, inst = served_instance
    sids = ["s0", "s1", "s2"]
    cfg = inst.cfg
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(
        (3, cfg.num_heads, cfg.head_dim)), jnp.float32)
    for layer in (0, cfg.num_layers - 1):
        out = paged_decode(inst.kv, sids, layer, q)
        ref = _dense_reference(inst, sids, layer, q)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_kernel_survives_hibernation(served_instance):
    """After deflate + fault-in, physical page ids change but the kernel's
    page-table view must produce identical attention."""
    eng, mgr, inst = served_instance
    sids = ["s0", "s1", "s2"]
    cfg = inst.cfg
    q = jnp.asarray(np.random.default_rng(1).standard_normal(
        (3, cfg.num_heads, cfg.head_dim)), jnp.float32)
    before = paged_decode(inst.kv, sids, 0, q)
    mgr.descend("i0", Rung.HIBERNATED)
    keys = [k for s in sids for k in inst.kv.keys_for(s)]
    mgr.hib.fault(inst, inst.kv.nonresident_keys(keys))
    after = paged_decode(inst.kv, sids, 0, q)
    np.testing.assert_allclose(np.asarray(after), np.asarray(before),
                               rtol=1e-6, atol=1e-6)

"""Streamed wake pipeline: critical-prefix contract, concurrent
wake/fault/deflate races, lookahead-prefetch correctness, and the
chunk-granular streaming readers it is built on.

The invariant under every interleaving: restored state is byte-identical
to the synchronous wake path.
"""
import threading

import numpy as np
import pytest

from repro.core.inflate import (InflatorPool, critical_wake_keys,
                                is_critical_key)
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.pool import PagePool
from repro.core.reap import ReapRecorder
from repro.core.state import ContainerState
from repro.core.swap import SwapFile
from repro.serving.engine import Request, ServingEngine
from repro.core.state import Rung

S = ContainerState


def _mk(tiny_factory, spool_dir, *, pipelined=True, chunk=16 << 10,
        dedup=True, lookahead=True):
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap",
                      pipelined_wake=pipelined, wake_chunk_bytes=chunk,
                      dedup_store=dedup, lookahead=lookahead), tiny_factory)
    return ServingEngine(mgr), mgr


def _req(iid, sid, toks, n=1, **kw):
    return Request(iid, sid, np.asarray(toks, np.int32),
                   max_new_tokens=n, **kw)


def _record_everything(eng, inst):
    """Fatten the REAP file: working set = every unit + all live KV."""
    inst.recorder.start()
    inst.recorder.record_many(inst.units)
    if inst.kv is not None:
        for sid in inst.kv.sessions:
            inst.recorder.record_many(inst.kv.keys_for(sid))
    inst.recorder.stop()


# ---------------------------------------------------------------- recorder

def test_recorder_preserves_first_touch_order():
    r = ReapRecorder()
    r.start()
    for k in ("c", "a", "b", "a"):
        r.record(k)
    r.stop()
    assert r.ordered_working_set == ("c", "a", "b")
    assert isinstance(r.working_set, frozenset)
    # a later session appends new keys but never reorders old ones
    r.start()
    r.record_many(["x", "a"])
    r.stop()
    assert r.ordered_working_set == ("c", "a", "b", "x")


def test_reap_file_written_in_touch_order(tiny_factory, spool_dir):
    eng, mgr = _mk(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "llama3.2-3b")
    eng.record_sample("i0", _req("i0", "probe", [1, 2, 3],
                                 close_session=True))
    mgr.descend("i0", Rung.HIBERNATED)
    order = {k: i for i, k in
             enumerate(inst.recorder.ordered_working_set)}
    file_keys = [k for k in inst.reap_file.extents if k in order]
    assert file_keys == sorted(file_keys, key=order.__getitem__)


# ---------------------------------------------------------------- contract

def test_critical_prefix_resident_at_wake_return(tiny_factory, spool_dir):
    """``wake()`` (pipelined) returns with every prefill-critical unit
    resident; the tail drains to exactly the synchronous restore."""
    eng, mgr = _mk(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "arctic-480b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    eng.record_sample("i0", _req("i0", "probe", [1, 2, 3, 4],
                                 close_session=True))
    _record_everything(eng, inst)
    mgr.descend("i0", Rung.HIBERNATED)

    st = mgr.ensure_awake("i0", trigger="sigcont", priority="high")
    assert st is not None and st.pipelined
    crit = critical_wake_keys(inst)
    assert crit and all(k in inst.resident for k in crit)
    assert st.critical_path_seconds > 0
    # expert units are tail, not critical
    assert any(not is_critical_key(k) for k in inst.reap_file.extents)

    pipe = inst.wake_pipeline
    assert pipe is not None and pipe.wait(60)
    # after the tail drains, every weight unit in the REAP file is resident
    assert all(k in inst.resident
               for k in inst.reap_file.extents if k[0] == "w")
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)
    stats = pipe.stats
    assert stats.io_seconds > 0 and stats.inflate_seconds > 0


def test_wake_storm_mid_stream(tiny_factory, spool_dir):
    """A storm against one tenant mid-stream: one pipeline, every request
    served correctly, restored weights bit-exact."""
    eng, mgr = _mk(tiny_factory, spool_dir, chunk=4 << 10)
    inst = eng.start_instance("i0", "arctic-480b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    eng.record_sample("i0", _req("i0", "probe", [1, 2, 3],
                                 close_session=True))
    _record_everything(eng, inst)

    # baseline tokens from the synchronous path
    eng_s, mgr_s = _mk(tiny_factory, spool_dir + "/sync", pipelined=False)
    eng_s.start_instance("i0", "arctic-480b")
    want = eng_s.handle(_req("i0", "s0", [7, 8, 9])).tokens

    mgr.descend("i0", Rung.HIBERNATED)
    n = 6
    barrier = threading.Barrier(n)
    resps = [None] * n

    def hit(i):
        barrier.wait()
        resps[i] = eng.handle(_req("i0", f"s{i}", [7, 8, 9],
                                   close_session=True))

    threads = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert mgr.wakes_performed == 1
    assert all(r.tokens == want for r in resps)
    if inst.wake_pipeline is not None:
        assert inst.wake_pipeline.wait(60)
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


def test_deflate_mid_stream_drains_safely(tiny_factory, spool_dir):
    """Deflate while the tail is still inflating: the stream cancels,
    drains, and NO working-set byte is lost across the re-deflate."""
    eng, mgr = _mk(tiny_factory, spool_dir, chunk=2 << 10)
    inst = eng.start_instance("i0", "arctic-480b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    eng.record_sample("i0", _req("i0", "probe", [1, 2],
                                 close_session=True))
    _record_everything(eng, inst)
    mgr.descend("i0", Rung.HIBERNATED)

    # low-priority anticipatory wake -> immediately deflate mid-stream
    mgr.predictive_wake("i0")
    pipe = inst.wake_pipeline
    assert pipe is not None
    mgr.descend("i0", Rung.HIBERNATED)                        # cancels + drains + restores
    assert not pipe.active
    assert inst.wake_pipeline is None
    assert inst.state == S.HIBERNATE

    # everything must still be restorable, bit-exact
    mgr.hib.wake(inst, mode="reap", trigger="sigcont")
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


def test_partial_residency_deflate_loses_nothing(tiny_factory, spool_dir):
    """White-box leftover restore: deflate an instance whose REAP file
    holds units that were never re-inflated (the deterministic analogue
    of a cancelled stream) — the rewrite must not drop them."""
    eng, mgr = _mk(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "llama3.2-3b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    _record_everything(eng, inst)
    mgr.descend("i0", Rung.HIBERNATED)
    assert inst.reap_file.extents

    # wake WITHOUT restoring (pagefault-style), fault in only a few units
    mgr.hib.wake(inst, mode="pagefault", trigger="sigcont")
    some = list(inst.reap_file.extents)[:2]
    inst.fault_in([k for k in some if k[0] == "w"])
    assert len(inst.resident) < len(inst.units)

    mgr.descend("i0", Rung.HIBERNATED)                        # must restore leftovers first
    mgr.hib.wake(inst, mode="reap", trigger="sigcont")
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


def test_lookahead_prefetch_matches_synchronous(tiny_factory, spool_dir):
    """Lookahead prefetch (mid-decode fault -> async next-layer pull) must
    leave faulted array contents identical to the synchronous path —
    tokens and final KV stream included."""
    outs = {}
    for name, pipelined in (("sync", False), ("pipe", True)):
        eng, mgr = _mk(tiny_factory, spool_dir + f"/{name}",
                       pipelined=pipelined, chunk=4 << 10,
                       lookahead=pipelined)
        inst = eng.start_instance("i0", "llama3.2-3b")
        # a session with history: its pages fault (and look ahead) on resume
        eng.handle(_req("i0", "chat", list(range(1, 24)), n=2))
        eng.record_sample("i0", _req("i0", "probe", [1, 2],
                                     close_session=True))
        _record_everything(eng, inst)
        mgr.descend("i0", Rung.HIBERNATED)
        r = eng.handle(_req("i0", "chat", [30, 31], n=3))
        if inst.wake_pipeline is not None:
            assert inst.wake_pipeline.wait(60)
        inst.quiesce_bg()
        kv = inst.kv
        sess = kv.sessions["chat"]
        mgr.hib.fault(inst, kv.keys_for("chat"))   # everything resident
        stream = np.concatenate(
            [kv.read_tokens("chat", lyr, sess.num_tokens)
             for lyr in range(inst.cfg.num_layers)])
        outs[name] = (r.tokens, stream)
    assert outs["sync"][0] == outs["pipe"][0]
    np.testing.assert_array_equal(outs["sync"][1], outs["pipe"][1])


def test_demand_pull_from_another_thread(tiny_factory, spool_dir):
    """A fault arriving mid-stream demand-pulls exactly its chunk and
    returns correct bytes while the streamer owns the rest."""
    eng, mgr = _mk(tiny_factory, spool_dir, chunk=2 << 10)
    inst = eng.start_instance("i0", "arctic-480b")
    before = {k: v.copy() for k, v in inst.weights.items()}
    _record_everything(eng, inst)
    mgr.descend("i0", Rung.HIBERNATED)
    mgr.predictive_wake("i0")                # low priority: slow stream
    pipe = inst.wake_pipeline
    tail = [k for k in inst.reap_file.extents if not is_critical_key(k)]
    assert tail
    st = mgr.hib.fault(inst, tail[:4])
    assert all(k in inst.resident for k in tail[:4])
    assert st.faulted_bytes >= 0
    assert pipe.wait(60)
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


# ---------------------------------------------------------------- plumbing

def test_swap_file_streaming_iter(tmp_path):
    f = SwapFile(str(tmp_path / "x.swap"))
    rng = np.random.default_rng(0)
    items = [((i,), rng.standard_normal(64).astype(np.float32))
             for i in range(16)]
    f.write_units(items)
    keys = [k for k, _ in items]
    whole = f.read_units(keys)
    seen = {}
    chunks = 0
    for batch in f.read_units_iter(keys, chunk_bytes=512):
        seen.update(batch)
        chunks += 1
    assert chunks > 1
    assert set(seen) == set(whole)
    for k in keys:
        np.testing.assert_array_equal(seen[k], whole[k])
    f.delete()


def test_store_client_streaming_iter(tiny_factory, spool_dir):
    eng, mgr = _mk(tiny_factory, spool_dir)
    inst = eng.start_instance("i0", "llama3.2-3b")
    mgr.descend("i0", Rung.HIBERNATED)                         # no working set -> all store
    keys = list(inst.swap_file.extents)
    whole = inst.swap_file.read_units(keys)
    seen = {}
    for batch in inst.swap_file.read_units_iter(keys, chunk_bytes=8 << 10):
        seen.update(batch)
    assert set(seen) == set(whole)
    for k in keys:
        np.testing.assert_array_equal(seen[k], whole[k])


def test_pool_scatter_kernel_matches_numpy():
    pool = PagePool(256, np.float32, capacity_pages=64)
    pages = pool.alloc(8, "t0")
    rng = np.random.default_rng(1)
    rows = rng.standard_normal((8, 256)).astype(np.float32)
    pool.scatter(pages, rows)                       # numpy path
    np_data = pool.data.copy()
    pool.data[:] = 0
    pool.scatter(pages, rows, use_kernel=True)      # Pallas kernel path
    np.testing.assert_array_equal(pool.data, np_data)
    assert pool.scatter_calls == 2


def test_inflator_pool_runs_and_sheds_idle_workers():
    pool = InflatorPool(max_workers=2, idle_s=0.1)
    futs = [pool.submit(lambda x: x * x, i) for i in range(8)]
    assert [f.result(10) for f in futs] == [i * i for i in range(8)]
    import time
    deadline = time.monotonic() + 5.0
    while pool._workers and time.monotonic() < deadline:
        time.sleep(0.05)
    assert pool._workers == 0

    err = pool.submit(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        err.result(10)

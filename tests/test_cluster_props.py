"""Property tests for the migration protocol.

Two invariants the cluster tier must never lose:

  * migrate -> wake is byte-identical to an in-place wake, for ANY
    ladder rung and ANY partial-residency split (which cold units were
    bitten off, how many bites);
  * source-store GC after a migration never frees a digest a surviving
    local tenant still references, for ANY subset of tenants migrating.

The checks are plain functions; a parametrized smoke version always
runs, and hypothesis (optional dep) drives randomized rungs / splits /
migration subsets over the same bodies.
"""
import numpy as np
import pytest

from repro.core.state import Rung
from test_cluster import (_assert_identical, _cluster, _full_wake,
                          _snapshot, _tenant)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # minimal installs
    HAVE_HYPOTHESIS = False


def _apply_rung(node, inst, rung_idx: int, split_seed: int) -> None:
    """0 = full hibernate, 1 = partial (random victim split, possibly
    multiple proportional bites), 2 = mmap_clean."""
    from repro.core.state import Event
    if rung_idx == 0:
        node.manager.descend(inst.instance_id, Rung.HIBERNATED)
        return
    if rung_idx == 2:
        inst.sm.fire(Event.MMAP_DROP)
        inst.mmap_dropped = True
        return
    rng = np.random.default_rng(split_seed)
    cands = [t[2] for t in
             node.manager.governor._partial_candidates(inst)]
    if not cands:
        node.manager.descend(inst.instance_id, Rung.HIBERNATED)
        return
    take = rng.integers(1, len(cands) + 1)
    picked = [cands[i] for i in
              rng.permutation(len(cands))[:take]]
    # split the victims into 1-3 bites: PARTIAL_STOP self-loops must
    # compose to the same bytes as one big bite
    bites = max(1, min(int(rng.integers(1, 4)), len(picked)))
    for chunk in np.array_split(np.arange(len(picked)), bites):
        if len(chunk):
            node.manager.descend(inst.instance_id, Rung.PARTIAL, keys=[picked[i] for i in chunk])


def _check_roundtrip(tiny_factory, spool_dir, rung_idx: int,
                     split_seed: int, kv_tokens: int) -> None:
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    inst = _tenant(router, n0, "t0", seed=split_seed, kv_tokens=kv_tokens)
    twin = _tenant(router, n0, "twin", seed=split_seed,
                   kv_tokens=kv_tokens)
    snap = _snapshot(inst)
    _apply_rung(n0, inst, rung_idx, split_seed)
    _apply_rung(n0, twin, rung_idx, split_seed)

    h = router.migrate("t0", "n1")
    assert h.ok, h.error
    _assert_identical(_full_wake(n1, "t0"), snap)
    _assert_identical(_full_wake(n0, "twin"), snap)
    router.close()


def _check_gc_topology(tiny_factory, spool_dir, n_tenants: int,
                       migrate_mask: int, seed: int) -> None:
    """Migrate an arbitrary subset away; every survivor on the source
    must still wake bit-exact (no digest it references was freed)."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    snaps = {}
    for i in range(n_tenants):
        iid = f"t{i}"
        inst = _tenant(router, n0, iid, seed=seed + i, kv_tokens=24)
        snaps[iid] = _snapshot(inst)
        n0.manager.descend(iid, Rung.HIBERNATED)
    moved = [f"t{i}" for i in range(n_tenants) if migrate_mask & (1 << i)]
    if len(moved) == n_tenants:
        moved = moved[:-1]                    # keep one survivor
    for iid in moved:
        assert router.migrate(iid, "n1").ok
    for i in range(n_tenants):
        iid = f"t{i}"
        node = n1 if iid in moved else n0
        _assert_identical(_full_wake(node, iid), snaps[iid])
    router.close()


# ------------------------------------------------------- always-on smoke
@pytest.mark.parametrize("rung_idx,split_seed", [
    (0, 11), (1, 12), (1, 13), (2, 14)])
def test_roundtrip_smoke(tiny_factory, spool_dir, rung_idx, split_seed):
    _check_roundtrip(tiny_factory, spool_dir, rung_idx, split_seed,
                     kv_tokens=40)


@pytest.mark.parametrize("mask", [0b01, 0b10, 0b011, 0b111])
def test_gc_topology_smoke(tiny_factory, spool_dir, mask):
    _check_gc_topology(tiny_factory, spool_dir, 3, mask, seed=20)


# ------------------------------------------------------- hypothesis
if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(rung_idx=st.integers(0, 2), split_seed=st.integers(0, 2**16),
           kv_tokens=st.integers(8, 72))
    def test_property_migrate_wake_bit_exact(tmp_path_factory, tiny_factory,
                                             rung_idx, split_seed,
                                             kv_tokens):
        spool = tmp_path_factory.mktemp("prop_spool")
        _check_roundtrip(tiny_factory, str(spool), rung_idx, split_seed,
                         kv_tokens)

    @settings(max_examples=8, deadline=None)
    @given(n_tenants=st.integers(2, 4), mask=st.integers(0, 15),
           seed=st.integers(0, 2**16))
    def test_property_gc_never_frees_survivor_digest(tmp_path_factory,
                                                     tiny_factory,
                                                     n_tenants, mask, seed):
        spool = tmp_path_factory.mktemp("prop_spool")
        _check_gc_topology(tiny_factory, str(spool), n_tenants,
                           mask & ((1 << n_tenants) - 1), seed)
else:                                          # keep the skips VISIBLE
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_migrate_wake_bit_exact():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_gc_never_frees_survivor_digest():
        pass

"""Predictive control plane: seasonal forecaster correctness, graceful
fallback on sparse/adversarial traffic, flash-crowd detection, and the
pre-inflate daemon acting through the low-priority wake pipeline."""
import numpy as np

from repro.core.forecast import (ForecastConfig, ForecastDaemon,
                                 TrafficForecaster)
from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import ContainerState, Rung

S = ContainerState
ARCH = "llama3.2-3b"
PERIOD, BINS = 100.0, 10


def _fc(**kw):
    kw.setdefault("season_period_s", PERIOD)
    kw.setdefault("n_bins", BINS)
    kw.setdefault("min_periods", 2)
    kw.setdefault("confidence_arrivals", 12)
    return TrafficForecaster(ForecastConfig(**kw))


def _gov_cfg(**fc_kw):
    fc_kw.setdefault("season_period_s", PERIOD)
    fc_kw.setdefault("n_bins", BINS)
    fc_kw.setdefault("min_periods", 2)
    fc_kw.setdefault("confidence_arrivals", 8)
    fc_kw.setdefault("preinflate_margin_s", 10.0)
    fc_kw.setdefault("preinflate_min_confidence", 0.2)
    return GovernorConfig(forecast=ForecastConfig(**fc_kw))


def _mgr(tiny_factory, spool_dir, gov_cfg=None):
    return InstanceManager(
        ManagerConfig(spool_dir=spool_dir, wake_mode="reap",
                      governor_policy=gov_cfg), tiny_factory)


def _learn_window(observe, periods=3):
    """Arrivals in phase window [50, 60) of each learning period."""
    for p in range(periods):
        for ph in (50.0, 52.0, 54.0, 56.0, 58.0):
            observe("t0", p * PERIOD + ph)


# --------------------------------------------------------------- fallback
def test_empty_history_returns_fallback_unchanged():
    """A never-observed key is pure reactive: the caller's fallback
    comes back verbatim (including None), confidence is zero, and no
    burst is flagged."""
    fc = _fc()
    assert fc.predicted_gap("ghost", 5.0, 42.0) == 42.0
    assert fc.predicted_gap("ghost", 5.0, None) is None
    assert fc.confidence("ghost", 5.0) == 0.0
    assert fc.seasonal_gap("ghost", 5.0) is None
    assert not fc.in_burst("ghost", 5.0)
    assert fc.rate("ghost", 5.0) == 0.0


def test_single_arrival_degrades_to_fallback():
    """One arrival is not a season: no completed period means zero
    confidence, so the blend returns the memoryless estimate exactly."""
    fc = _fc()
    fc.observe("t0", 10.0)
    assert fc.predicted_gap("t0", 12.0, 30.0) == 30.0
    assert fc.confidence("t0", 12.0) == 0.0
    assert not fc.in_burst("t0", 12.0)
    fc.forget("t0")
    assert fc.predicted_gap("t0", 12.0, 30.0) == 30.0


# --------------------------------------------------------------- seasonal
def test_seasonal_learning_predicts_active_window():
    """Three learned periods of a [50, 60) active window: sitting at
    phase 45 the model predicts the next arrival when the hot bin
    starts, with high confidence — the pre-inflate signal."""
    fc = _fc()
    _learn_window(fc.observe)
    now = 3 * PERIOD + 45.0          # quiet bin, hot window 5s away
    gap = fc.seasonal_gap("t0", now)
    assert gap is not None and 4.0 <= gap <= 12.0
    # confidence is judged at the bin the predicted arrival lands in,
    # not the (deliberately quiet) current bin
    assert fc.confidence("t0", now) > 0.5
    blended = fc.predicted_gap("t0", now, 80.0)
    assert blended < 0.5 * 80.0
    # mid quiet half of the period the same model predicts "far away"
    far = fc.seasonal_gap("t0", 3 * PERIOD + 65.0)
    assert far is not None and far > 50.0


def test_antiseasonal_traffic_not_much_worse_than_ewma():
    """Adversarial anti-seasonal trace (the active window alternates
    phase every period): the blend's mean absolute gap error stays
    within 1.5x of the pure EWMA fallback — graceful degradation, never
    a cliff."""
    rng = np.random.default_rng(11)
    fc = _fc(confidence_arrivals=8)
    evs, t = [], 0.0
    for p in range(8):
        start = (0.0 if p % 2 == 0 else 50.0) + p * PERIOD
        t = start
        while t < start + 20.0:
            t += float(rng.exponential(3.0))
            evs.append(t)
    evs.sort()
    ewma, last = None, None
    err_fc, err_ewma = [], []
    for t in evs:
        if last is not None:
            actual = t - last
            if ewma is not None:
                pred = fc.predicted_gap("t0", last, ewma)
                err_fc.append(abs(pred - actual))
                err_ewma.append(abs(ewma - actual))
            ewma = actual if ewma is None else \
                0.3 * actual + 0.7 * ewma
        fc.observe("t0", t)
        last = t
    assert np.mean(err_fc) <= 1.5 * np.mean(err_ewma) + 1e-9


# ------------------------------------------------------------ flash crowd
def test_burst_detection_fires_and_subsides():
    fc = _fc(short_window_s=2.0, long_window_s=30.0, burst_ratio=3.0,
             burst_min_arrivals=4)
    for t in range(0, 200, 20):          # sparse background
        fc.observe("t0", float(t))
    assert not fc.in_burst("t0", 200.0)
    for i in range(8):                   # the crowd lands
        fc.observe("t0", 300.0 + i * 0.2)
    assert fc.in_burst("t0", 301.6)
    assert fc.burst_factor("t0", 301.6) >= 3.0
    # during the burst the predicted gap collapses to the observed rate
    assert fc.predicted_gap("t0", 301.6, 60.0) < 1.0
    # the short window drains: the flag drops, no sticky state
    assert not fc.in_burst("t0", 330.0)
    assert fc.stats()["bursts_flagged"] > 0


# ---------------------------------------------------------- governor wiring
def test_governor_blends_and_falls_back(tiny_factory, spool_dir):
    """With a forecaster configured the governor's predicted_gap blends
    seasonal predictions, but a tenant with no history gets exactly the
    reactive estimate."""
    mgr = _mgr(tiny_factory, spool_dir, _gov_cfg())
    mgr.cold_start("t0", ARCH)
    gov = mgr.governor
    assert gov.forecaster is not None
    # no history: reactive idle-time fallback, to the millisecond
    mgr.instances["t0"].last_used = 1.0
    assert gov.predicted_gap("t0", 5.0, last_used=1.0) == 4.0
    # arrivals flow into the forecaster via observe_arrival
    _learn_window(lambda iid, t: gov.observe_arrival(iid, now=t))
    assert gov.forecaster.observations == 15
    now = 3 * PERIOD + 45.0
    reactive_only = _mgr(tiny_factory, spool_dir + "/reactive").governor
    assert gov.predicted_gap("t0", now) < 20.0   # seasonal pull-in
    assert reactive_only.forecaster is None


def test_wake_footprint_tracks_descents_and_resets(tiny_factory, spool_dir):
    """Every descent accumulates the bytes a future wake must restore;
    the wake resets it — the elasticity demand model reads this."""
    mgr = _mgr(tiny_factory, spool_dir)
    mgr.cold_start("t0", ARCH)
    gov = mgr.governor
    assert gov.inflate_bytes_estimate("t0") == 0
    mgr.descend("t0", Rung.HIBERNATED)
    est = gov.inflate_bytes_estimate("t0")
    assert est > 0
    mgr.ensure_awake("t0")
    inst = mgr.instances["t0"]
    if inst.wake_pipeline is not None:
        inst.wake_pipeline.wait(60)
    inst.quiesce_bg()
    assert gov.inflate_bytes_estimate("t0") == 0


# ----------------------------------------------------------------- daemon
def test_daemon_preinflates_ahead_of_learned_window(tiny_factory,
                                                    spool_dir):
    """The daemon wakes a hibernated tenant when the learned window is
    within the margin — and leaves it alone in the quiet phase."""
    mgr = _mgr(tiny_factory, spool_dir, _gov_cfg())
    mgr.cold_start("t0", ARCH)
    gov = mgr.governor
    _learn_window(lambda iid, t: gov.observe_arrival(iid, now=t))
    mgr.descend("t0", Rung.HIBERNATED)
    daemon = ForecastDaemon(mgr)
    # deep in the quiet phase: the window is ~40s away, margin is 10
    assert daemon.step(3 * PERIOD + 10.0) == []
    assert mgr.instances["t0"].state == S.HIBERNATE
    # just ahead of the window: pre-inflate fires
    assert daemon.step(3 * PERIOD + 45.0) == ["t0"]
    inst = mgr.instances["t0"]
    assert inst.state != S.HIBERNATE
    if inst.wake_pipeline is not None:
        inst.wake_pipeline.wait(60)
    inst.quiesce_bg()
    assert daemon.prewarmed_tenants == 1
    # already awake: the next pass has nothing to do
    assert daemon.step(3 * PERIOD + 46.0) == []


def test_daemon_noop_without_forecaster(tiny_factory, spool_dir):
    """Reactive governor (forecast=None): the daemon is a strict no-op
    — pre-PR-9 behaviour is the benchmark baseline."""
    mgr = _mgr(tiny_factory, spool_dir)
    mgr.cold_start("t0", ARCH)
    mgr.descend("t0", Rung.HIBERNATED)
    daemon = ForecastDaemon(mgr)
    assert daemon.step(1.0) == []
    assert mgr.instances["t0"].state == S.HIBERNATE

import os
import shutil

import pytest

# Tests must see ONE device (the dry-run alone uses 512 placeholders).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"


@pytest.fixture()
def spool_dir(tmp_path):
    d = tmp_path / "spool"
    d.mkdir()
    yield str(d)
    shutil.rmtree(d, ignore_errors=True)


@pytest.fixture(scope="session")
def tiny_factory():
    """factory(arch) -> (cfg, params) with per-arch caching."""
    import jax
    from repro.configs import get_config, tiny_config
    from repro.models import model

    cache = {}

    def factory(arch_key: str):
        if arch_key not in cache:
            cfg = tiny_config(get_config(arch_key))
            params = model.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch_key] = (cfg, params)
        cfg, params = cache[arch_key]
        return cfg, jax.tree.map(lambda x: x.copy(), params)

    return factory

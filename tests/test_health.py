"""Failure detector: enumerated health machine + lease/hysteresis
behaviour, driven entirely in virtual time."""
import itertools

import pytest

from repro.cluster.health import (HEALTH_TRANSITIONS, FailureDetector,
                                  HealthEvent, HealthPolicy,
                                  InvalidHealthTransition, NodeHealth,
                                  NodeHealthMachine)

H, HE = NodeHealth, HealthEvent

POL = HealthPolicy(heartbeat_interval_s=1.0, suspect_after_s=3.0,
                   dead_after_s=10.0, revive_beats=2)


# ------------------------------------------------------------ enumeration
def test_every_state_event_pair_is_classified():
    """The full |states| x |events| grid: every pair is either a legal
    edge in HEALTH_TRANSITIONS (fires, lands on the declared state) or
    raises InvalidHealthTransition — no edge exists outside the table."""
    for state, event in itertools.product(NodeHealth, HealthEvent):
        m = NodeHealthMachine("n0", state=state)
        if (state, event) in HEALTH_TRANSITIONS:
            want, _tag = HEALTH_TRANSITIONS[(state, event)]
            assert m.can(event)
            assert m.fire(event, now=1.0) is want
            assert m.state is want
            assert m.history[-1][1:4] == (state, event, want)
        else:
            assert not m.can(event)
            with pytest.raises(InvalidHealthTransition):
                m.fire(event, now=1.0)
            assert m.state is state          # failed fire mutates nothing
            assert m.history == []


def test_no_alive_to_dead_shortcut():
    """There is deliberately no ALIVE->DEAD edge: even hard evidence
    must walk MISS then EXPIRE."""
    assert (H.ALIVE, HE.EXPIRE) not in HEALTH_TRANSITIONS
    det = FailureDetector(["n0"], POL)
    det.observe_failure("n0", now=5.0)
    assert det.is_dead("n0")
    hist = det.machines["n0"].history
    assert [(old, ev, new) for _, old, ev, new, _ in hist] == [
        (H.ALIVE, HE.MISS, H.SUSPECT),
        (H.SUSPECT, HE.EXPIRE, H.DEAD)]


# ------------------------------------------------------------ lease timers
def test_lease_lapse_walks_suspect_then_dead():
    det = FailureDetector(["n0", "n1"], POL)
    det.beat("n0", 0.0)
    det.beat("n1", 0.0)
    for t in (1.0, 2.0):
        det.beat("n1", t)
        assert det.step(t) == []
    # n0 silent past suspect_after_s
    trans = det.step(3.5)
    assert trans == [("n0", H.ALIVE, H.SUSPECT)]
    assert det.alive_ids() == ["n1"]         # SUSPECT is not a target
    # still silent past dead_after_s (from last beat)
    trans = det.step(10.5)
    assert ("n0", H.SUSPECT, H.DEAD) in trans
    assert det.is_dead("n0")
    assert not det.is_dead("n1")


def test_long_gap_fires_both_edges_in_one_step():
    """A single late step after a long silence still walks the
    enumerated path: MISS and EXPIRE both fire, in order."""
    det = FailureDetector(["n0"], POL)
    det.beat("n0", 0.0)
    trans = det.step(100.0)
    assert trans == [("n0", H.ALIVE, H.SUSPECT),
                     ("n0", H.SUSPECT, H.DEAD)]


def test_first_observation_seeds_lease():
    """A detector constructed at virtual t=0 but first stepped at
    t=1e6 must not declare everyone dead: the first step seeds the
    lease instead of comparing against a time nobody ever beat at."""
    det = FailureDetector(["n0"], POL)
    assert det.step(1e6) == []
    assert det.state("n0") is H.ALIVE
    assert det.step(1e6 + 1.0) == []          # fresh lease, not lapsed
    assert det.step(1e6 + 50.0) != []         # but it does lapse eventually


def test_revive_needs_consecutive_beats():
    det = FailureDetector(["n0"], POL)
    det.beat("n0", 0.0)
    det.step(4.0)
    assert det.state("n0") is H.SUSPECT
    det.beat("n0", 4.1)                       # one lucky packet
    assert det.state("n0") is H.SUSPECT
    # a lapse resets the streak: the beats must be consecutive
    det.step(8.0)
    det.beat("n0", 8.1)
    det.beat("n0", 8.2)                       # second consecutive beat
    assert det.state("n0") is H.ALIVE
    assert det.alive_ids() == ["n0"]


def test_no_implicit_resurrection():
    """Beats from a DEAD node are counted and ignored; only an explicit
    reinstate readmits it (with a fresh lease)."""
    det = FailureDetector(["n0"], POL)
    det.observe_failure("n0", 1.0)
    assert det.is_dead("n0")
    for t in (2.0, 3.0, 4.0):
        assert det.beat("n0", t) is H.DEAD
    assert det.ignored_beats == 3
    assert det.reinstate("n0", 5.0) is H.ALIVE
    assert det.step(5.5) == []                # lease restarted at reinstate


def test_observe_failure_without_fail_fast_stops_at_suspect():
    det = FailureDetector(["n0"], HealthPolicy(fail_fast=False))
    assert det.observe_failure("n0", 1.0) is H.SUSPECT
    assert not det.is_dead("n0")


def test_transition_subscribers_see_every_edge():
    seen = []
    det = FailureDetector(["n0"], POL)
    det.on_transition.append(lambda nid, old, new: seen.append((nid, old,
                                                                new)))
    det.beat("n0", 0.0)
    det.step(4.0)
    det.step(11.0)
    det.reinstate("n0", 12.0)
    assert seen == [("n0", H.ALIVE, H.SUSPECT),
                    ("n0", H.SUSPECT, H.DEAD),
                    ("n0", H.DEAD, H.ALIVE)]

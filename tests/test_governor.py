"""MemoryGovernor pressure paths: proportional reclaim order, EWMA
next-arrival prediction vs adversarial traffic, partial deflate + demand
fault, and the terminate rung's swap-store refcount release."""
import numpy as np
import pytest

from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import ContainerState, Rung

S = ContainerState


def _mgr(tiny_factory, spool_dir, **cfg_kw):
    cfg_kw.setdefault("wake_mode", "reap")
    return InstanceManager(ManagerConfig(spool_dir=spool_dir, **cfg_kw),
                          tiny_factory)


def _start(mgr, iid, arch="llama3.2-3b"):
    inst = mgr.cold_start(iid, arch)
    return inst


# --------------------------------------------------------------- pressure
def test_budget_breach_all_tenants_active_proportional_order(tiny_factory,
                                                             spool_dir):
    """All tenants WARM (active), budget breached: the governor deflates
    in predicted-idleness order and frees only the bytes needed to clear
    pressure — the hot tenant stays WARM."""
    mgr = _mgr(tiny_factory, spool_dir)
    insts = [_start(mgr, f"t{i}") for i in range(3)]
    gov = mgr.governor
    now = 100.0
    # t0 hot (short EWMA gap, just arrived), t1 medium, t2 coldest
    for t in (99.0, 99.5, 100.0):
        gov.observe_arrival("t0", now=t)
    for t in (80.0, 90.0):
        gov.observe_arrival("t1", now=t)
    gov.observe_arrival("t2", now=10.0)
    for inst in insts:
        inst.last_used = now
    one = insts[0].weight_bytes(resident_only=True) + \
        insts[0].metadata_bytes()
    # budget forces out ~one tenant's bytes; two must stay resident
    budget = 3 * one - one // 2
    acts = gov.step(now=now, budget_bytes=budget)
    assert acts, "governor must act on a breach"
    assert acts[0].instance_id == "t2"            # coldest-predicted first
    assert mgr.instances["t0"].state == S.WARM    # hot tenant untouched
    assert gov.governed_bytes() <= budget
    # proportional: pressure cleared without deflating everyone
    assert {a.instance_id for a in acts} <= {"t1", "t2"}


def test_no_action_without_breach(tiny_factory, spool_dir):
    mgr = _mgr(tiny_factory, spool_dir)
    _start(mgr, "t0")
    gov = mgr.governor
    assert gov.step(now=1.0, budget_bytes=gov.governed_bytes() + 1) == []
    assert gov.pressure_bytes(gov.governed_bytes() + 1) < 0


def test_ewma_prediction_vs_bursty_tenant(tiny_factory, spool_dir):
    """Adversarial bursty traffic: a tenant that just finished a rapid
    burst predicts an imminent next arrival, so the governor victimizes
    the steady long-gap tenant first — and only picks the bursty one once
    it is the only candidate left."""
    mgr = _mgr(tiny_factory, spool_dir)
    bursty = _start(mgr, "bursty")
    steady = _start(mgr, "steady")
    gov = mgr.governor
    for t in (0.0, 5.0, 10.0):
        gov.observe_arrival("steady", now=t)           # gap EWMA ~5s
    for k in range(11):
        gov.observe_arrival("bursty", now=10.0 + k * 0.1)  # gap EWMA ~0.1s
    now = 11.05                                        # just after the burst
    bursty.last_used = steady.last_used = now
    assert gov.predicted_gap("bursty", now) < gov.predicted_gap("steady", now)
    acts = gov.step(now=now, budget_bytes=0)
    order = [a.instance_id for a in acts]
    assert order.index("steady") < order.index("bursty")
    # both end deflated: the budget (0) can only be approached, and the
    # bursty tenant is deflated last, not never
    assert bursty.state == S.HIBERNATE and steady.state == S.HIBERNATE


def test_wake_cost_ewma_learned_per_rung(tiny_factory, spool_dir):
    """Measured wakes move the governor's per-rung cost model away from
    the priors."""
    mgr = _mgr(tiny_factory, spool_dir)
    inst = _start(mgr, "t0")
    inst.recorder.start()
    inst.recorder.record_many(list(inst.units)[:4])
    inst.recorder.stop()
    gov = mgr.governor
    prior = gov.wake_cost(Rung.HIBERNATED)
    mgr.descend("t0", Rung.HIBERNATED)
    mgr.ensure_awake("t0", trigger="sigcont")
    assert "hibernated" in gov.wake_cost_ewma
    assert gov.wake_cost(Rung.HIBERNATED) != prior
    assert gov.wake_cost(Rung.PARTIAL) == pytest.approx(
        dict(gov.cfg.cost_priors)[Rung.PARTIAL])       # still the prior


# --------------------------------------------------------------- partial
def test_partial_deflate_then_demand_fault(tiny_factory, spool_dir):
    """Partial deflate drops only cold non-critical units; a request that
    needs one demand-faults it back, bit-exact."""
    from repro.core.inflate import is_critical_key
    from repro.serving.engine import ServingEngine
    from benchmarks.common import request_for

    mgr = _mgr(tiny_factory, spool_dir)
    eng = ServingEngine(mgr)
    inst = eng.start_instance("moe", "arctic-480b")
    eng.handle(request_for(inst.cfg, "moe", "s0", 8, 2, seed=0,
                           close_session=True))
    before = {k: v.copy() for k, v in inst.weights.items()}

    victims = [k for _, _, k in mgr.governor._partial_candidates(inst)]
    assert victims and all(not is_critical_key(k) for k in victims)
    st = mgr.descend("moe", Rung.PARTIAL, keys=victims)
    assert inst.state == S.PARTIAL and inst.rung == Rung.PARTIAL
    assert st.rung == "partial" and st.swap_bytes > 0
    wvictims = [k for k in victims if k[0] == "w"]
    assert all(k not in inst.resident for k in wvictims)
    # the prefill-critical prefix never left
    crit = [u.key for u in inst.swappable_units()
            if is_critical_key(u.key) and u.key not in set(victims)]
    assert all(k in inst.resident for k in crit)

    # deterministic demand fault: pull one dropped expert directly (no
    # background restore is running yet — deflate_partial quiesced it)
    one = wvictims[0]
    fst = mgr.hib.fault(inst, [one])
    assert fst.faulted_bytes == inst.units[one].nbytes
    assert one in inst.resident
    np.testing.assert_array_equal(inst._get_unit(inst.units[one]),
                                  before[one[1]][..., one[2], :, :]
                                  if before[one[1]].ndim > 3
                                  else before[one[1]][one[2]])

    # an end-to-end request on the PARTIAL instance serves correctly
    # (remaining dropped units arrive via demand fault or the background
    # partial-wake restore — both race-free under the install lock)
    resp = eng.handle(request_for(inst.cfg, "moe", "s1", 8, 2, seed=1,
                                  close_session=True))
    assert len(resp.tokens) == 2
    assert inst.state == S.WOKEN
    inst.quiesce_bg()
    inst.ensure_all_resident()
    for k, v in before.items():
        np.testing.assert_array_equal(inst.weights[k], v)


def test_partial_bite_is_proportional(tiny_factory, spool_dir):
    """The governor swaps only enough cold bytes to clear the breach, not
    the whole cold set."""
    mgr = _mgr(tiny_factory, spool_dir,
               governor_policy=GovernorConfig(min_partial_bytes=1,
                                              headroom=0.0))
    inst = _start(mgr, "moe", arch="arctic-480b")
    gov = mgr.governor
    cold = gov._partial_candidates(inst)
    cold_bytes = sum(nb for _, nb, _ in cold)
    assert cold_bytes > 0
    inst.last_used = 5.0
    need = min(nb for _, nb, _ in cold) // 2 + 1      # a sub-unit breach
    budget = gov.governed_bytes() - need
    acts = gov.step(now=10.0, budget_bytes=budget)
    assert [a.rung_to for a in acts] == [Rung.PARTIAL]
    assert inst.state == S.PARTIAL
    remaining = sum(nb for _, nb, _ in gov._partial_candidates(inst))
    assert remaining > 0                              # cold set NOT emptied
    assert gov.governed_bytes() <= budget


def test_mmap_clean_rung_releases_last_sharer(tiny_factory, spool_dir):
    """MMAP_CLEAN on the last sharer frees the shared base weights and a
    request re-maps them."""
    import jax
    from repro.core.instance import _path_str

    def loader(base_id):
        cfg, params = tiny_factory(base_id)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return {_path_str(p): np.asarray(v) for p, v in flat
                if _path_str(p) == "embed"}

    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir),
                          tiny_factory, shared_loader=loader)
    inst = mgr.cold_start("a", "llama3.2-3b", shared_paths={"embed"})
    assert mgr.governor._mmap_benefit(inst) == inst.shared_weight_bytes() > 0
    st = mgr.descend("a", Rung.MMAP_CLEAN)
    assert inst.state == S.MMAP_CLEAN and inst.mmap_dropped
    assert st.shared_bytes_released > 0
    assert not mgr.shared.is_loaded("llama3.2-3b")
    # wake re-maps (refcount-balanced; reload_count grows by one)
    wk = mgr.ensure_awake("a", trigger="sigcont")
    assert wk is not None and wk.rung == "mmap_clean"
    assert inst.state == S.WARM and not inst.mmap_dropped
    assert mgr.shared.refcount("llama3.2-3b") == 1


def test_mmap_drop_on_woken_lands_partial_and_wakes(tiny_factory, spool_dir):
    """(4a'): deflate_mmap on a WOKEN instance lands in PARTIAL and the
    next wake is NOT deduped — the re-map must actually run."""
    import jax
    from repro.core.instance import _path_str

    def loader(base_id):
        cfg, params = tiny_factory(base_id)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return {_path_str(p): np.asarray(v) for p, v in flat
                if _path_str(p) == "embed"}

    mgr = InstanceManager(ManagerConfig(spool_dir=spool_dir),
                          tiny_factory, shared_loader=loader)
    inst = mgr.cold_start("a", "llama3.2-3b", shared_paths={"embed"})
    mgr.descend("a", Rung.HIBERNATED)
    wk = mgr.ensure_awake("a", trigger="sigcont")
    assert wk is not None and inst.state == S.WOKEN
    st = mgr.descend("a", Rung.MMAP_CLEAN)
    assert inst.state == S.PARTIAL and st.rung == "partial"
    assert inst.mmap_dropped and not mgr.shared.is_loaded("llama3.2-3b")
    wk2 = mgr.ensure_awake("a", trigger="sigcont")
    assert wk2 is not None and wk2.rung == "partial"   # not deduped
    assert not inst.mmap_dropped                        # re-mapped
    assert mgr.shared.refcount("llama3.2-3b") == 1


def test_stale_governor_action_is_revalidated(tiny_factory, spool_dir):
    """A descent scored against an old state must not fire once the
    instance moved: _apply revalidates under the lock and no-ops."""
    mgr = _mgr(tiny_factory, spool_dir,
               governor_policy=GovernorConfig(terminate_idle_s=1.0))
    inst = _start(mgr, "a")
    inst.last_used = 0.0
    # score says TERMINATED (hibernated + idle), but the tenant woke up
    # between scoring and apply: simulate by applying against WOKEN
    mgr.descend("a", Rung.HIBERNATED)
    mgr.ensure_awake("a", trigger="sigcont")
    assert inst.state == S.WOKEN
    act = mgr.governor._apply(inst, Rung.TERMINATED, need=1, now=100.0,
                              score=1.0, try_lock=None)
    assert act is None and "a" in mgr.instances        # NOT evicted
    # and a stale MMAP_CLEAN descent against a hibernated instance no-ops
    mgr.descend("a", Rung.HIBERNATED)
    act = mgr.governor._apply(inst, Rung.MMAP_CLEAN, need=1, now=100.0,
                              score=1.0, try_lock=None)
    assert act is None and inst.state == S.HIBERNATE


# --------------------------------------------------------------- terminate
def test_terminate_rung_releases_store_refcounts(tiny_factory, spool_dir):
    """TERMINATED releases the tenant's swap-store segment refs: shared
    segments survive while another tenant references them, and the store
    GCs to zero when the last sharer dies."""
    mgr = _mgr(tiny_factory, spool_dir,
               governor_policy=GovernorConfig(terminate_idle_s=1.0))
    forgotten = []
    mgr.on_evict = forgotten.append      # platform-layer cleanup hook
    for iid in ("a", "b"):
        _start(mgr, iid)                 # same arch: payloads dedup
        mgr.instances[iid].last_used = 0.0
        mgr.descend(iid, Rung.HIBERNATED)
    stats = mgr.store.stats()
    assert stats["stored_bytes"] > 0 and stats["dedup_hits"] > 0
    gov = mgr.governor
    # hibernated but not yet idle long enough -> terminate is gated
    assert gov.step(now=0.5, budget_bytes=0) == []
    acts = gov.step(now=100.0, budget_bytes=0)
    assert [a.rung_to for a in acts] == [Rung.TERMINATED, Rung.TERMINATED]
    assert mgr.instances == {}
    assert sorted(forgotten) == ["a", "b"]            # platform cleanup ran
    assert mgr.store.stats()["stored_bytes"] == 0     # full GC
    assert mgr.store.stats()["segments"] == 0


def test_terminate_spares_referenced_segments(tiny_factory, spool_dir):
    """Terminating ONE of two dedup'd tenants must not GC the survivor's
    bytes."""
    mgr = _mgr(tiny_factory, spool_dir)
    for iid in ("a", "b"):
        _start(mgr, iid)
        mgr.descend(iid, Rung.HIBERNATED)
    stored = mgr.store.stats()["stored_bytes"]
    mgr.evict("a")
    assert mgr.store.stats()["stored_bytes"] == stored
    inst_b = mgr.instances["b"]
    wk = mgr.ensure_awake("b", trigger="sigcont")
    assert wk is not None
    if inst_b.wake_pipeline is not None:
        inst_b.wake_pipeline.wait(60)
    inst_b.ensure_all_resident()
    assert inst_b.weight_bytes(resident_only=True) > 0


# --------------------------------------------------------------- platform
def test_platform_daemon_feeds_governor_and_enforces_budget(tiny_factory,
                                                            spool_dir):
    """AsyncPlatform: arrivals feed the governor's EWMA, and the pressure
    daemon enforces ManagerConfig.memory_budget_bytes via the ladder."""
    import time as _time
    from repro.serving import AsyncPlatform, PlatformPolicy, Request
    from repro.serving.engine import ServingEngine

    mgr = _mgr(tiny_factory, spool_dir, memory_budget_bytes=1)
    eng = ServingEngine(mgr)
    pol = PlatformPolicy(keep_warm_s=1e9, tick_interval_s=0.02)
    with AsyncPlatform(eng, pol, {"fn-a": "llama3.2-3b"}, workers=2) as plat:
        plat.submit(Request("fn-a", "s0",
                            np.arange(1, 4, dtype=np.int32),
                            max_new_tokens=1)).result(timeout=120)
        assert "fn-a" in mgr.governor.arrivals
        deadline = _time.monotonic() + 30.0
        while _time.monotonic() < deadline and \
                mgr.instances["fn-a"].state != S.HIBERNATE:
            _time.sleep(0.02)
    assert mgr.instances["fn-a"].state == S.HIBERNATE
    assert any(a.rung_to == Rung.HIBERNATED for a in mgr.governor.actions)

"""Trip-count-aware HLO cost model: validated against known-flop programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyse_text(txt)


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _cost(lambda x, y: x @ y, a, b)
    assert c.flops == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    """The exact failure mode of compiled.cost_analysis(): a scanned body
    must be scaled by its trip count."""
    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = _cost(scanned, x, w)
    assert c.flops == 12 * 2 * 32 * 64 * 64
    # raw XLA cost_analysis undercounts (documents the bug we fix):
    # it reports ~one body's flops (+ loop-control scalar ops), not 12x
    from repro.launch.analysis import xla_cost_dict
    raw = xla_cost_dict(jax.jit(scanned).lower(x, w).compile())
    assert raw["flops"] < 1.1 * 2 * 32 * 64 * 64


def test_nested_scan():
    def nested(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    c = _cost(nested, x, w)
    assert c.flops == 5 * 3 * 2 * 16 * 16 * 16


def test_dus_bytes_count_update_not_buffer():
    """A dynamic-update-slice writes its update region, not the aliased
    32k-slot cache — the traffic model must reflect that."""
    def f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 0))

    cache = jax.ShapeDtypeStruct((32768, 128), jnp.float32)
    new = jax.ShapeDtypeStruct((1, 128), jnp.float32)
    txt = jax.jit(f, donate_argnums=0).lower(cache, new).compile().as_text()
    c = hlo_cost.analyse_text(txt)
    # with donation the update is in-place: traffic = 2x update region
    assert c.bytes == 2 * 1 * 128 * 4


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((4, 64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 128, 32), jnp.float32)
    c = _cost(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    assert c.flops == 4 * 2 * 64 * 128 * 32


def test_shape_parser():
    e, b = hlo_cost.shape_elems_bytes("bf16[8,32768,576]{2,1,0}")
    assert e == 8 * 32768 * 576 and b == 2 * e
    e, b = hlo_cost.shape_elems_bytes(
        "(s32[], f32[128,256]{1,0}, /*index=5*/bf16[2,4]{1,0})")
    assert b == 4 + 128 * 256 * 4 + 2 * 4 * 2


def test_collectives_module():
    from repro.launch.analysis import collective_bytes
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p), to_apply=%add
  ROOT %ag = f32[64]{0} all-gather(%ar), dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 64
    assert out["all-gather"] == 256

"""Cluster elasticity: scale-out with CAS warm-ship, forecast-demand
autoscaling, and scale-in drains that lose nothing — including the
drain-vs-node-death race, fenced by the failure detector."""
import pytest

from repro.cluster import ClusterPolicy, MigrationError, Node
from repro.cluster.faults import FaultInjector
from repro.cluster.health import HealthPolicy, NodeHealth
from repro.core.state import Rung

from test_cluster import (ARCH, SALT, _assert_identical, _cluster,
                          _full_wake, _snapshot, _tenant)
from test_chaos import POLICY, _hibernate_and_replicate


def _factory(tiny_factory, spool_dir):
    """node_factory wired onto the router for scale-out tests."""
    return lambda nid: Node(nid, tiny_factory, spool_dir=spool_dir,
                            salt=SALT)


# ------------------------------------------------------------- scale-out
def test_scale_out_admits_and_cas_warms_node(tiny_factory, spool_dir):
    """A scaled-out node joins detector-ALIVE with the fleet's
    deployment digests already in its store (pinned, so GC cannot undo
    the warm-up), making the first migration to it mostly dedup."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    snap = _snapshot(_tenant(router, n0, "t0", seed=3))
    _tenant(router, n0, "t1", seed=4)
    for iid in ("t0", "t1"):
        n0.manager.descend(iid, Rung.HIBERNATED)
    router.node_factory = _factory(tiny_factory, spool_dir)

    node = router.scale_out(now=0.0)
    assert node is not None and node.node_id in router.nodes
    assert router.detector.state(node.node_id) is NodeHealth.ALIVE
    digests = router.deployment_digests(ARCH)
    assert digests and not node.store.missing_digests(digests)
    assert node.store.stats()["pinned_segments"] > 0
    stats = router.migration_stats()
    assert stats["scale_outs"] == 1 and stats["warm_bytes_shipped"] > 0

    # the warm-up pays off: the first migration there is mostly dedup,
    # and the tenant wakes byte-identical on the new node
    h = router.migrate("t0", node.node_id)
    assert h.ok and h.stats.bytes_dedup > 0
    assert h.stats.bytes_shipped < h.stats.full_snapshot_bytes
    _assert_identical(_full_wake(node, "t0"), snap)
    router.close()


def test_scale_out_respects_ceiling_and_missing_factory(tiny_factory,
                                                        spool_dir):
    router, _ = _cluster(tiny_factory, spool_dir)
    assert router.scale_out(now=0.0) is None        # no factory wired
    router.node_factory = _factory(tiny_factory, spool_dir)
    router.policy.max_nodes = 2
    assert router.scale_out(now=0.0) is None        # already at ceiling
    assert router.migration_stats()["scale_outs"] == 0
    router.close()


def test_autoscale_scales_out_on_forecast_demand(tiny_factory, spool_dir):
    """Deflated tenants predicted to wake within the horizon add up to
    more than the budgeted headroom: the elastic round spawns a node."""
    policy = ClusterPolicy(elastic=True, scale_horizon_s=30.0,
                           max_nodes=4)
    router, (n0, n1) = _cluster(tiny_factory, spool_dir, budget=1 << 20,
                                policy=policy)
    router.node_factory = _factory(tiny_factory, spool_dir)
    for i in range(4):
        _tenant(router, n0, f"t{i}", seed=i)
        n0.manager.descend(f"t{i}", Rung.HIBERNATED)
        # two tight arrivals: the reactive EWMA predicts "due in ~1s"
        n0.governor.observe_arrival(f"t{i}", now=0.0)
        n0.governor.observe_arrival(f"t{i}", now=1.0)

    demand = router.forecast_demand_bytes(now=2.0)
    assert demand > router.cluster_headroom_bytes()
    acts = router.autoscale(now=2.0)
    assert [a[0] for a in acts] == ["scale_out"]
    assert len(router.nodes) == 3
    # one action per round: the same round never also drains
    assert not router._draining
    router.close()


def test_autoscale_idle_without_demand(tiny_factory, spool_dir):
    """No deflated tenant due within the horizon: the elastic round
    does nothing — elasticity must not thrash on an idle cluster."""
    policy = ClusterPolicy(elastic=True, scale_in_sustained_rounds=1000)
    router, _ = _cluster(tiny_factory, spool_dir, budget=256 << 20,
                         policy=policy)
    router.node_factory = _factory(tiny_factory, spool_dir)
    assert router.forecast_demand_bytes(now=100.0) == 0
    for r in range(3):
        assert router.autoscale(now=100.0 + r) == []
    assert len(router.nodes) == 2
    router.close()


# -------------------------------------------------------------- scale-in
def test_drain_rehomes_everything_and_decommissions(tiny_factory,
                                                    spool_dir):
    """The scale-in acceptance property: draining a node mass-migrates
    every tenant (including a WARM one walked down to a migratable
    rung), loses nothing, leaves survivors GC-clean, and removes the
    node from the fabric."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    iids = [f"t{i}" for i in range(4)]
    snaps = {iid: _snapshot(_tenant(router, n0, iid, seed=i))
             for i, iid in enumerate(iids)}
    for iid in iids[1:]:
        n0.manager.descend(iid, Rung.HIBERNATED)
    # t0 stays WARM: drain must walk it down itself

    acts = router.drain_node("n0", now=0.0)
    assert ("scale_in", "n0") in acts
    assert len([a for a in acts if a[0] == "drain_migrate"]) == 4
    assert router.tenants_lost == 0
    assert "n0" not in router.nodes and not router._draining
    for iid in iids:
        assert router.placement[iid] == "n1"
        _assert_identical(_full_wake(n1, iid), snaps[iid])
    assert n1.store.orphan_digests(0.0) == []
    stats = router.migration_stats()
    assert stats["scale_ins"] == 1 and stats["nodes"] == 1
    router.close()


def test_drain_refusals(tiny_factory, spool_dir):
    """No absorbing peer or a non-ALIVE source: drain refuses up front
    rather than stranding tenants halfway."""
    router, (n0,) = _cluster(tiny_factory, spool_dir, n=1)
    _tenant(router, n0, "t0")
    with pytest.raises(MigrationError, match="no other node"):
        router.drain_node("n0", now=0.0)
    router.close()

    router2, (m0, m1) = _cluster(tiny_factory, spool_dir + "/b")
    router2.check_health(0.0)
    m0.kill()
    with pytest.raises(MigrationError, match="not ALIVE"):
        router2.drain_node("n0", now=1.0)
    router2.close()


def test_drain_excluded_as_target_but_still_counted_alive(tiny_factory,
                                                          spool_dir):
    """A draining node leaves the placement/replication target set but
    stays in the recovery/repair set — the fencing primitive."""
    router, (n0, n1) = _cluster(tiny_factory, spool_dir)
    router._draining.add("n0")
    assert [n.node_id for n in router.target_nodes()] == ["n1"]
    assert {n.node_id for n in router.alive_nodes()} == {"n0", "n1"}
    assert router.place("fresh", ARCH).node_id == "n1"
    router._draining.discard("n0")
    router.close()


def test_drain_aborts_cleanly_when_node_dies_mid_drain(tiny_factory,
                                                       spool_dir):
    """The race the detector fences: the node dies between two drain
    migrations.  The drain stops, hands the remainder to replicated
    recovery, and not one tenant is lost or double-homed."""
    router, (n0, n1, n2) = _cluster(tiny_factory, spool_dir, n=3,
                                    policy=POLICY)
    iids = [f"t{i}" for i in range(4)]
    snaps = {iid: _snapshot(_tenant(router, n0, iid, seed=10 + i))
             for i, iid in enumerate(iids)}
    _hibernate_and_replicate(router, n0, iids)
    router.check_health(0.0)

    # kill the source at the *second* migration's post-ship checkpoint
    inj = FaultInjector(seed=7).arm("migrate.shipped",
                                    FaultInjector.kill_node(n0), hit=2)
    with inj:
        acts = router.drain_node("n0", now=0.0)
    assert ("drain_aborted", "n0") in acts
    assert ("scale_in", "n0") not in acts
    assert router.tenants_lost == 0
    assert router.detector.is_dead("n0")
    assert "n0" in router.nodes          # aborted, not decommissioned
    assert not router._draining
    homes = {iid: router.placement[iid] for iid in iids}
    assert set(homes.values()) <= {"n1", "n2"}
    for iid in iids:
        home = router.nodes[homes[iid]]
        _assert_identical(_full_wake(home, iid), snaps[iid])
    router.close()

"""Wake latency: synchronous vs pipelined (streamed) REAP wake.

The paper's claim is that a Woken container answers with near-Warm
latency because only *part* of the deflated memory must be inflated
before the request runs.  This suite measures exactly that:
**time-to-first-token** for a request that wakes a hibernated tenant
whose working set is dominated by tail bytes the first token does not
need — other sessions' deep-layer KV context, the shape of a real
multi-turn chat deployment.

  synchronous  — ``wake()`` restores the WHOLE working set, then serves.
  pipelined    — ``wake()`` returns at the prefill-critical prefix
                 (weights + embedding blocks + layer-0 KV); the deeper
                 layers' KV streams in the background while the first
                 request computes.

The tenant: a tiny dense llama stretched to 6 layers, with SESSIONS
long-context sessions resident in the working set.  Session KV is
synthesized directly into pool pages (the wake path neither knows nor
cares how the pages got their bytes); the probe request is a real
prefill on a fresh session.
"""
from __future__ import annotations

import dataclasses
import hashlib
import shutil
import time

import numpy as np

from benchmarks.common import Table, fmt_mb, request_for
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import percentile
from repro.serving.engine import ServingEngine
from repro.core.state import Rung

ARCH = "llama3.2-3b"
NUM_LAYERS = 6
SESSIONS = 16
SESSION_TOKENS = 1024        # 16 pool pages per layer per session
PROBE_LEN = 4


def _factory():
    import jax
    from repro.configs import get_config, tiny_config
    from repro.models import model
    cache = {}

    def factory(arch_key):
        if arch_key not in cache:
            cfg = dataclasses.replace(tiny_config(get_config(arch_key)),
                                      num_layers=NUM_LAYERS)
            params = model.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch_key] = (cfg, params)
        cfg, params = cache[arch_key]
        return cfg, jax.tree.map(lambda x: x.copy(), params)

    return factory


def _synthesize_sessions(inst, sessions: int, tokens: int) -> int:
    """Fill the cache with long-context sessions (multi-turn history).

    Pages are written directly — the swap/wake machinery is agnostic to
    how KV bytes were produced, and this keeps the benchmark's setup cost
    off model compute."""
    kv = inst.kv
    rng = np.random.default_rng(0)
    n = 0
    for s in range(sessions):
        sid = f"chat{s}"
        kv.new_session(sid)
        data = rng.standard_normal(
            (tokens, kv.token_elems)).astype(np.float32)
        for layer in range(inst.cfg.num_layers):
            kv.write_tokens(sid, layer, data, 0)
            n += data.nbytes
        kv.sessions[sid].num_tokens = tokens
    return n


def _weight_digests(inst):
    return {k: hashlib.blake2b(np.ascontiguousarray(v).tobytes(),
                               digest_size=16).digest()
            for k, v in inst.weights.items()}


def _setup(spool: str, pipelined: bool, sessions: int):
    shutil.rmtree(spool, ignore_errors=True)
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool, wake_mode="reap",
                      pipelined_wake=pipelined,
                      pool_capacity_pages=1 << 16), _factory())
    eng = ServingEngine(mgr)
    inst = eng.start_instance("tenant", ARCH)
    cfg = inst.cfg
    _synthesize_sessions(inst, sessions, SESSION_TOKENS)
    # compile-cache warmup for the probe shape (survives hibernation)
    eng.handle(request_for(cfg, "tenant", "warm", PROBE_LEN, 0, seed=99,
                           close_session=True))
    # working set := everything resident (hibernate-all with full WS)
    inst.recorder.start()
    inst.recorder.record_many(inst.units)
    for sid in inst.kv.sessions:
        inst.recorder.record_many(inst.kv.keys_for(sid))
    inst.recorder.stop()
    return eng, mgr, inst


def _cycles(eng, mgr, inst, n: int):
    """n deflate -> wake-by-request cycles: (ttfts, wake stats)."""
    cfg = inst.cfg
    ttfts, stats = [], []
    for c in range(n):
        mgr.descend("tenant", Rung.HIBERNATED)
        t0 = time.monotonic()
        eng.handle(request_for(cfg, "tenant", f"probe{c}", PROBE_LEN, 0,
                               seed=100 + c, close_session=True))
        ttfts.append(time.monotonic() - t0)
        if inst.wake_pipeline is not None:
            inst.wake_pipeline.wait(120)
        inst.quiesce_bg()
        wakes = [s for op, _, s in mgr.hib.log if op == "wake"]
        stats.append(wakes[-1])
    return ttfts, stats


def main(quick: bool = False):
    # quick mode trims cycles, NOT the working set: the tail/critical
    # ratio is what the 2x claim rides on
    n = 5 if quick else 9
    sessions = SESSIONS
    tab = Table("Wake latency: time-to-first-token, synchronous vs "
                f"pipelined wake ({ARCH}, {NUM_LAYERS} layers, "
                f"{sessions}x{SESSION_TOKENS}-token sessions)",
                ["mode", "ttft p50 ms", "ttft p99 ms", "wakes/s",
                 "crit ms", "io ms", "inflate ms", "restore MB"])
    results = {}
    for mode, pipelined in (("synchronous", False), ("pipelined", True)):
        eng, mgr, inst = _setup(f"/tmp/bench_wake_latency/{mode}",
                                pipelined, sessions)
        digests = _weight_digests(inst)
        ttfts, stats = _cycles(eng, mgr, inst, n)
        inst.ensure_all_resident()
        exact = _weight_digests(inst) == digests
        p50 = percentile(ttfts, 50)
        p99 = percentile(ttfts, 99)
        tab.add(mode, f"{p50 * 1e3:.1f}", f"{p99 * 1e3:.1f}",
                f"{1.0 / p50:.2f}",
                f"{np.mean([s.critical_path_seconds for s in stats]) * 1e3:.1f}",
                f"{np.mean([s.io_seconds for s in stats]) * 1e3:.1f}",
                f"{np.mean([s.inflate_seconds for s in stats]) * 1e3:.1f}",
                fmt_mb(stats[-1].prefetched_bytes))
        results[mode] = (p50, p99, exact)
        del eng, mgr, inst
    print(tab.render())
    sync_p50, _, sync_exact = results["synchronous"]
    pipe_p50, _, pipe_exact = results["pipelined"]
    checks = [
        ("pipelined ttft >= 2x better than synchronous",
         sync_p50 >= 2.0 * pipe_p50),
        ("restored state byte-identical in both modes",
         sync_exact and pipe_exact),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

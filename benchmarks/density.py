"""Deployment density: how many tenants fit a fixed memory budget.

Co-deployment of Hibernate + Woken containers vs Warm-only (the paper's
overall-system conclusion).  We pack instances until the budget is hit
under four policies:
  warm-only        — every tenant stays inflated (the baseline platform)
  hibernate-all    — deflate after each request (working set recorded, so
                     most bytes land in the private per-sandbox REAP file)
  hibernate-cold   — deflate with NO recorded working set: every unit
                     rides the content-addressed SwapStore, so the disk
                     column shows the cross-tenant dedup win
  woken-mix        — REAP-wake with woken residency (working set only)
"""
from __future__ import annotations

from benchmarks.common import Table, fmt_mb, make_engine, request_for
from repro.core.metrics import memory_report
from repro.core.state import Rung

ARCH = "llama3.2-3b"
BUDGET = 256 << 20          # 256 MB of "device" memory


def packed_instances(policy: str, spool: str):
    eng, mgr = make_engine(f"{spool}/{policy}", "tiny", "reap", share=True)
    count = 0
    wake_stats = []
    while count < 200:
        iid = f"i{count}"
        inst = eng.start_instance(iid, ARCH, shared_paths={"embed"})
        eng.handle(request_for(inst.cfg, iid, "s", 8, 4,
                               close_session=True))
        if policy != "warm-only":
            if policy != "hibernate-cold":
                eng.record_sample(iid, request_for(inst.cfg, iid, "p", 8, 4,
                                                   close_session=True))
            mgr.descend(iid, Rung.HIBERNATED)
            if policy == "woken-mix":
                # woken residency: wake with the working set resident.
                # The anticipatory wake streams (low priority); density
                # counts settled residency, so drain the tail first.
                st = mgr.predictive_wake(iid)
                if inst.wake_pipeline is not None:
                    inst.wake_pipeline.wait(60)
                if st is not None:
                    wake_stats.append(st)
        total = sum(memory_report(i, mgr.shared).pss_total
                    for i in mgr.instances.values())
        if total > BUDGET:
            mgr.evict(iid)
            break
        count += 1
    # the disk side of density: what verbatim per-sandbox files would hold
    # vs the content-addressed store's actual footprint
    reps = [memory_report(i, mgr.shared) for i in mgr.instances.values()]
    disk_logical = sum(r.disk_logical for r in reps)
    disk_stored = sum(r.disk_stored_pss for r in reps)
    return count, disk_logical, disk_stored, wake_stats


def _wake_ms(stats, attr):
    if not stats:
        return "-"
    return f"{sum(getattr(s, attr) for s in stats) / len(stats) * 1e3:.2f}"


def main(quick: bool = False):
    tab = Table(f"Density: tenants within {BUDGET >> 20} MB ({ARCH})",
                ["policy", "instances", "x vs warm-only",
                 "disk logical MB", "disk stored MB",
                 "wake io ms", "wake inflate ms", "wake crit ms"])
    rows = [("warm-only", *packed_instances("warm-only",
                                            "/tmp/bench_density"))]
    base = rows[0][1]
    pols = (["hibernate-all", "hibernate-cold"] if quick
            else ["hibernate-all", "hibernate-cold", "woken-mix"])
    for pol in pols:
        rows.append((pol, *packed_instances(pol, "/tmp/bench_density")))
    for pol, n, dl, ds, ws in rows:
        tab.add(pol, n, f"{n / max(base, 1):.1f}x", fmt_mb(dl), fmt_mb(ds),
                _wake_ms(ws, "io_seconds"), _wake_ms(ws, "inflate_seconds"),
                _wake_ms(ws, "critical_path_seconds"))
    print(tab.render())
    cold = rows[2]
    checks = [("density", rows[1][1] > rows[0][1]),
              # all-swap-tier hibernation: the store dedups N identical
              # tenants down to ~one stored copy
              ("dedup shrinks hibernated disk", cold[3] < cold[2] / 2)]
    return tab, checks


if __name__ == "__main__":
    main()

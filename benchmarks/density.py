"""Deployment density: how many tenants fit a fixed memory budget.

Co-deployment of Hibernate + Woken containers vs Warm-only (the paper's
overall-system conclusion).  We pack instances until the budget is hit
under three policies:
  warm-only        — every tenant stays inflated (the baseline platform)
  hibernate-all    — deflate after each request
  woken-mix        — REAP-wake with woken residency (working set only)
"""
from __future__ import annotations

from benchmarks.common import Table, fmt_mb, make_engine, request_for
from repro.core.metrics import memory_report

ARCH = "llama3.2-3b"
BUDGET = 256 << 20          # 256 MB of "device" memory


def packed_instances(policy: str, spool: str) -> int:
    eng, mgr = make_engine(f"{spool}/{policy}", "tiny", "reap", share=True)
    count = 0
    while count < 200:
        iid = f"i{count}"
        inst = eng.start_instance(iid, ARCH, shared_paths={"embed"})
        eng.handle(request_for(inst.cfg, iid, "s", 8, 4,
                               close_session=True))
        if policy != "warm-only":
            eng.record_sample(iid, request_for(inst.cfg, iid, "p", 8, 4,
                                               close_session=True))
            mgr.deflate(iid)
            if policy == "woken-mix":
                # woken residency: wake with the working set resident
                mgr.predictive_wake(iid)
        total = sum(memory_report(i, mgr.shared).pss_total
                    for i in mgr.instances.values())
        if total > BUDGET:
            mgr.evict(iid)
            break
        count += 1
    return count


def main(quick: bool = False):
    tab = Table(f"Density: tenants within {BUDGET >> 20} MB ({ARCH})",
                ["policy", "instances", "x vs warm-only"])
    base = packed_instances("warm-only", "/tmp/bench_density")
    rows = [("warm-only", base)]
    for pol in (["hibernate-all"] if quick
                else ["hibernate-all", "woken-mix"]):
        rows.append((pol, packed_instances(pol, "/tmp/bench_density")))
    for pol, n in rows:
        tab.add(pol, n, f"{n / max(base, 1):.1f}x")
    print(tab.render())
    return tab, [("density", rows[1][1] > rows[0][1])]


if __name__ == "__main__":
    main()

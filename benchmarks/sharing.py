"""§3.5 reproduction: runtime-binary sharing effect on wake latency and
memory (the paper's Node.js case: 25 ms -> 11 ms with sharing on).

Shared base weights (the embedding table — the 'language runtime binary'
of an LLM instance) are file-backed: never swapped, refcount-dropped on
deflate, re-acquired on wake.  Sharing saves both swap IO and PSS.
"""
from __future__ import annotations

from benchmarks.common import Table, fmt_mb, fmt_ms, make_engine, request_for
from repro.core.metrics import memory_report
from repro.core.state import Rung

ARCH = "phi4-mini-3.8b"      # 200k vocab: big shared embedding
N = 4


def run(share: bool, spool="/tmp/bench_share"):
    eng, mgr = make_engine(f"{spool}/{share}", "tiny", "reap", share=share)
    for i in range(N):
        inst = eng.start_instance(
            f"i{i}", ARCH, shared_paths={"embed"} if share else None)
        eng.handle(request_for(inst.cfg, f"i{i}", "s", 8, 4,
                               close_session=True))
        eng.record_sample(f"i{i}", request_for(inst.cfg, f"i{i}", "p", 8, 4,
                                               close_session=True))
    pss_warm = sum(memory_report(i, mgr.shared).pss_total
                   for i in mgr.instances.values())
    for i in range(N):
        mgr.descend(f"i{i}", Rung.HIBERNATED)
    # wake latency of one instance
    r = eng.handle(request_for(mgr.instances["i0"].cfg, "i0", "s2", 8, 4,
                               close_session=True))
    return {"pss_warm": pss_warm, "wake_ms": r.spans["e2e"],
            "swap_bytes": mgr.instances["i0"].swap_file.file_bytes
            + mgr.instances["i0"].reap_file.file_bytes}


def main(quick: bool = False):
    off = run(False)
    on = run(True)
    tab = Table(f"§3.5 base-weight sharing ({ARCH}, {N} instances)",
                ["metric", "sharing off", "sharing on", "delta"])
    tab.add("warm PSS (MB)", fmt_mb(off["pss_warm"]), fmt_mb(on["pss_warm"]),
            f"{on['pss_warm'] / off['pss_warm']:.0%}")
    tab.add("hibernate wake+req (ms)", fmt_ms(off["wake_ms"]),
            fmt_ms(on["wake_ms"]),
            f"{on['wake_ms'] / off['wake_ms']:.0%}")
    tab.add("swap file bytes (MB)", fmt_mb(off["swap_bytes"]),
            fmt_mb(on["swap_bytes"]),
            f"{on['swap_bytes'] / off['swap_bytes']:.0%}")
    print(tab.render())
    return tab, [("sharing saves pss", on["pss_warm"] < off["pss_warm"]),
                 ("sharing saves swap io",
                  on["swap_bytes"] < off["swap_bytes"])]


if __name__ == "__main__":
    main()

"""Fig. 7 reproduction: memory (PSS) per container state, 10 instances.

The paper collects pmap PSS for 10 co-running instances per benchmark in
Warm / Hibernate / Woken states, with the Quark runtime binary shared
(here: the shared base-weight registry).  Claims: Hibernate ~ 7-25% of
Warm; Woken 28-90% of Warm.
"""
from __future__ import annotations

from benchmarks.common import (WORKLOADS, Table, fmt_mb, make_engine,
                               request_for)
from repro.core.metrics import memory_report
from repro.core.state import Rung

N_INSTANCES = 10


def run_workload(name, arch, plen, ntok, scale, spool="/tmp/bench_mem"):
    eng, mgr = make_engine(f"{spool}/{name}", scale, "reap", share=True)
    insts = []
    for i in range(N_INSTANCES):
        iid = f"i{i}"
        inst = eng.start_instance(iid, arch,
                                  shared_paths={"embed"})
        eng.handle(request_for(inst.cfg, iid, "s", plen, ntok,
                               close_session=True))
        # record working set so deflation splits reap/swap like production
        eng.record_sample(iid, request_for(inst.cfg, iid, "probe", plen,
                                           ntok, close_session=True))
        insts.append(inst)

    def pss_total():
        return sum(memory_report(i, mgr.shared).pss_total for i in insts)

    warm = pss_total()
    for i in range(N_INSTANCES):
        mgr.descend(f"i{i}", Rung.HIBERNATED)
    hib = pss_total()
    for i in range(N_INSTANCES):
        inst = insts[i]
        eng.handle(request_for(inst.cfg, f"i{i}", "s2", plen, ntok,
                               close_session=True))
    woken = pss_total()
    return {"warm": warm, "hib": hib, "woken": woken}


def main(quick: bool = False):
    tab = Table(f"Fig.7: PSS memory per state ({N_INSTANCES} instances, MB)",
                ["workload", "arch", "warm", "hibernate", "woken",
                 "hib/warm", "woken/warm"])
    checks = []
    wls = WORKLOADS[:4] if quick else WORKLOADS
    for name, arch, plen, ntok, scale in wls:
        r = run_workload(name, arch, plen, ntok, scale)
        hw, ww = r["hib"] / r["warm"], r["woken"] / r["warm"]
        tab.add(name, arch, fmt_mb(r["warm"]), fmt_mb(r["hib"]),
                fmt_mb(r["woken"]), f"{hw:.0%}", f"{ww:.0%}")
        checks.append((name, hw < 0.5, ww <= 1.0))
    print(tab.render())
    print("\nclaims: hib<<warm woken<=warm")
    for c in checks:
        print(f"  {c[0]:14s} {c[1]} {c[2]}")
    return tab, checks


if __name__ == "__main__":
    main()

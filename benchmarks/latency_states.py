"""Fig. 6 reproduction: request response latency per container state.

Per workload, measure end-to-end request latency for:
  cold        — cold start + first request (init + compile + process)
  warm        — request on a Warm Container
  hib-pf      — first request on a Hibernate Container, page-fault swap-in
  hib-reap    — first request on a Hibernate Container, REAP batch swap-in
  woken       — request on a Woken-up Container

Expected orderings (the paper's claims): warm ~ woken < hib-reap <=
hib-pf << cold (REAP may lose to page-fault only for tiny working sets —
the paper's image-processing-2.6MB exception).
"""
from __future__ import annotations

import time

from benchmarks.common import (WORKLOADS, Table, fmt_ms, make_engine,
                               request_for)
from repro.core.state import Rung


def run_workload(name, arch, plen, ntok, scale, spool="/tmp/bench_lat"):
    res = {}

    # --- cold: fresh manager; includes init + first-compile + process
    eng, mgr = make_engine(f"{spool}/{name}/cold", scale, "reap")
    t0 = time.monotonic()
    inst = eng.start_instance("i", arch)
    r = eng.handle(request_for(inst.cfg, "i", "cold", plen, ntok,
                               close_session=True))
    res["cold"] = time.monotonic() - t0

    # --- warm
    r = eng.handle(request_for(inst.cfg, "i", "warm", plen, ntok,
                               close_session=True))
    res["warm"] = r.spans["e2e"]

    # --- record the REAP working set with a sample request (§3.4.2)
    eng.record_sample("i", request_for(inst.cfg, "i", "probe", plen, ntok,
                                       close_session=True))

    # --- hibernate + page-fault wake
    mgr.cfg.wake_mode = "pagefault"
    mgr.descend("i", Rung.HIBERNATED)
    r = eng.handle(request_for(inst.cfg, "i", "pf", plen, ntok,
                               close_session=True))
    res["hib-pf"] = r.spans["e2e"]
    res["pf-faults"] = r.faults
    res["pf-bytes"] = r.faulted_bytes

    # --- hibernate + REAP wake
    mgr.cfg.wake_mode = "reap"
    mgr.descend("i", Rung.HIBERNATED)
    r = eng.handle(request_for(inst.cfg, "i", "reap", plen, ntok,
                               close_session=True))
    res["hib-reap"] = r.spans["e2e"]
    res["reap-bytes"] = r.prefetched_bytes
    res["reap-faults"] = r.faults

    # --- woken
    r = eng.handle(request_for(inst.cfg, "i", "wk", plen, ntok,
                               close_session=True))
    res["woken"] = r.spans["e2e"]
    return res


def main(quick: bool = False):
    tab = Table("Fig.6: request latency per state (ms)",
                ["workload", "arch", "cold", "warm", "hib-pf", "hib-reap",
                 "woken", "reap/cold", "pf faults"])
    checks = []
    wls = WORKLOADS[:4] if quick else WORKLOADS
    for name, arch, plen, ntok, scale in wls:
        r = run_workload(name, arch, plen, ntok, scale)
        tab.add(name, arch, fmt_ms(r["cold"]), fmt_ms(r["warm"]),
                fmt_ms(r["hib-pf"]), fmt_ms(r["hib-reap"]),
                fmt_ms(r["woken"]), f"{r['hib-reap'] / r['cold']:.0%}",
                r["pf-faults"])
        checks.append((name,
                       r["hib-reap"] < r["cold"],
                       r["hib-pf"] < r["cold"],
                       r["woken"] < 2.5 * r["warm"]))
    print(tab.render())
    print("\nclaims: hib<cold(reap) hib<cold(pf) woken~warm")
    for c in checks:
        print(f"  {c[0]:14s} {c[1]} {c[2]} {c[3]}")
    return tab, checks


if __name__ == "__main__":
    main()

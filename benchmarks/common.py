"""Shared benchmark scaffolding: workloads, builders, result tables.

The paper's Figs. 6/7 use FunctionBench micro-benchmarks spanning process
type / memory footprint / latency.  The LLM-serving analogues here span the
same axes: small-vs-large working set, short-vs-long requests, and the
program-language-runtime variety maps to architecture families.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_config, scaled_config, tiny_config
from repro.core.instance import _path_str
from repro.core.manager import InstanceManager, ManagerConfig
from repro.models import model
from repro.serving import Request, ServingEngine

#: the benchmark suite (Fig. 6/7 analogue).  (name, arch, prompt_len,
#: new_tokens, scale) — float-operation ~ tiny/short; video-processing ~
#: scaled/long; image-processing two sizes; hello-world per runtime family.
WORKLOADS = [
    ("hello-dense",   "llama3.2-3b",     8,  4, "tiny"),
    ("hello-moe",     "arctic-480b",     8,  4, "tiny"),
    ("hello-ssm",     "mamba2-130m",     8,  4, "tiny"),
    ("hello-hybrid",  "hymba-1.5b",      8,  4, "tiny"),
    ("float-op",      "phi4-mini-3.8b",  4,  2, "tiny"),
    ("image-small",   "yi-6b",          32,  8, "scaled"),
    ("image-large",   "yi-6b",         128,  8, "scaled"),
    ("video-proc",    "chatglm3-6b",   256, 16, "scaled"),
]


def build_factory(scale: str = "tiny") -> Callable:
    cache: Dict[str, tuple] = {}

    def factory(arch_key: str):
        if arch_key not in cache:
            cfg = get_config(arch_key)
            cfg = tiny_config(cfg) if scale == "tiny" else \
                scaled_config(cfg)
            params = model.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch_key] = (cfg, params)
        cfg, params = cache[arch_key]
        return cfg, jax.tree.map(lambda x: x.copy(), params)

    return factory


def shared_loader_for(factory):
    def loader(base_id):
        cfg, params = factory(base_id)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        return {_path_str(p): np.asarray(v) for p, v in flat
                if _path_str(p) in SHARED_PATHS}
    return loader


#: §3.5: the "runtime binary" analogue — the embedding table is the
#: shared read-only base across instances of one model
SHARED_PATHS = {"embed"}


def make_engine(spool: str, scale: str = "tiny", wake_mode: str = "reap",
                share: bool = False, dedup: bool = True):
    shutil.rmtree(spool, ignore_errors=True)
    os.makedirs(spool, exist_ok=True)
    factory = build_factory(scale)
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool, wake_mode=wake_mode,
                      share_base_weights=share, dedup_store=dedup),
        factory, shared_loader=shared_loader_for(factory) if share else None)
    return ServingEngine(mgr), mgr


def request_for(cfg, iid, sid, prompt_len, new_tokens, seed=0, **kw):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
    if cfg.frontend.kind == "vision":
        kw.setdefault("embeds", np.ones(
            (cfg.frontend.num_embeddings, cfg.frontend.embed_dim),
            np.float32))
    if cfg.is_encoder_decoder:
        kw.setdefault("frames", np.ones((8, cfg.frontend.embed_dim),
                                        np.float32))
    return Request(iid, sid, prompt, max_new_tokens=new_tokens, **kw)


@dataclass
class Table:
    title: str
    columns: List[str]
    rows: List[List] = field(default_factory=list)

    def add(self, *row):
        self.rows.append(list(row))

    def render(self) -> str:
        w = [max(len(str(c)), *(len(str(r[i])) for r in self.rows))
             if self.rows else len(str(c))
             for i, c in enumerate(self.columns)]
        out = [f"## {self.title}"]
        out.append(" | ".join(str(c).ljust(w[i])
                              for i, c in enumerate(self.columns)))
        out.append("-|-".join("-" * x for x in w))
        for r in self.rows:
            out.append(" | ".join(str(c).ljust(w[i])
                                  for i, c in enumerate(r)))
        return "\n".join(out)

    def to_dict(self):
        return {"title": self.title, "columns": self.columns,
                "rows": self.rows}


def fmt_ms(s: float) -> str:
    return f"{s * 1e3:.1f}"


def fmt_mb(b: float) -> str:
    return f"{b / 2**20:.2f}"

"""Crash recovery: re-home MTTR and post-recovery wake latency.

The failure-domain story (ISSUE: node failure detection + replicated
CAS recovery) only counts if recovery is *fast* and the recovered
tenants wake as cheaply as they would have on their old home.  This
suite measures both ends:

  1. A 4-node cluster homes a pile of hibernated tenants on node 0,
     with ``replication_factor=2`` anti-entropy pinning a complete
     replica of every tenant's segments on a survivor.
  2. Node 0 is hard-killed.  The lease detector (virtual time) walks it
     ALIVE -> SUSPECT -> DEAD; the DEAD transition triggers
     ``recover_node``, which re-homes every tenant onto the best
     replica holder through ``receive_bundle`` — the same code path a
     migration commits through, so post-recovery wakes are
     byte-identical to pre-crash wakes.
  3. Post-recovery, every re-homed tenant is woken by a real request on
     its new home and the TTFT distribution is compared against a
     control group of identically-built tenants that never crashed.

Detection latency is policy-bound (``dead_after_s`` + heartbeat
slack) and driven in virtual time; the re-home itself is real work
(bundle adoption, refcount moves) and is measured in wall-clock —
``rehome/s`` is the gated throughput metric.  Zero lost tenants is a
claim check: with k=2 and one dead node, every tenant must survive.
"""
from __future__ import annotations

import shutil
import time

from benchmarks.common import Table, build_factory, request_for
from repro.cluster import ClusterPolicy, ClusterRouter, Node
from repro.cluster.health import HealthPolicy
from repro.core.governor import GovernorConfig
from repro.core.metrics import percentile
from repro.core.state import Rung

ARCH = "llama3.2-3b"
N_NODES = 4
PROMPT_LEN = 24
SALT = b"recovery-bench"
SPOOL = "/tmp/bench_recovery"
HEALTH = HealthPolicy(heartbeat_interval_s=1.0, suspect_after_s=3.0,
                      dead_after_s=10.0)


def _mk_cluster(n_victims: int, n_controls: int):
    """4 nodes, unconstrained budgets (this suite measures failure, not
    pressure): victims homed on n0, controls on n1, all hibernated."""
    shutil.rmtree(SPOOL, ignore_errors=True)
    factory = build_factory("tiny")
    gov_cfg = GovernorConfig(min_partial_bytes=4 << 10,
                             terminate_idle_s=None)
    nodes = [Node(f"n{i}", factory, spool_dir=SPOOL, salt=SALT,
                  governor_cfg=gov_cfg) for i in range(N_NODES)]
    policy = ClusterPolicy(replication_factor=2,
                           max_replications_per_round=256,
                           health=HEALTH)
    router = ClusterRouter(nodes, policy=policy)

    tenants = [(f"v{i}", nodes[0]) for i in range(n_victims)] \
        + [(f"c{i}", nodes[1]) for i in range(n_controls)]
    cfg0 = None
    for iid, node in tenants:
        router.placement[iid] = node.node_id
        router.arch_of[iid] = ARCH
        inst = node.engine.start_instance(iid, ARCH)
        cfg0 = inst.cfg
        # a long-lived ctx session (the private KV delta replication
        # actually ships) + a recorded probe for replayable wakes
        node.engine.handle(request_for(cfg0, iid, "ctx", PROMPT_LEN, 0,
                                       seed=hash(iid) % 1000))
        inst.recorder.start()
        node.engine.handle(request_for(cfg0, iid, "probe", PROMPT_LEN, 0,
                                       seed=1 + hash(iid) % 1000,
                                       close_session=True))
        inst.recorder.stop()
        node.manager.descend(iid, Rung.HIBERNATED)
    return router, nodes, cfg0


def _wake_ttft(router, cfg, iid: str, now: float) -> float:
    t0 = time.monotonic()
    router.handle(request_for(cfg, iid, f"w{now:.0f}", PROMPT_LEN, 0,
                              seed=int(now) % 9973, close_session=True),
                  now=now)
    dt = time.monotonic() - t0
    node = router.node_of(iid)
    inst = node.manager.instances.get(iid) if node is not None else None
    if inst is not None and inst.wake_pipeline is not None:
        inst.wake_pipeline.wait(60)
    return dt


def main(quick: bool = False):
    n_victims, n_controls = (8, 4) if quick else (12, 6)
    router, nodes, cfg = _mk_cluster(n_victims, n_controls)
    n0 = nodes[0]

    # seed the leases, then anti-entropy until every tenant has a
    # complete off-home replica (k=2 -> one holder each)
    router.check_health(now=0.0)
    t_rep0 = time.monotonic()
    t, rounds = 0.5, 0
    while router.replications < n_victims + n_controls and rounds < 32:
        router.anti_entropy(now=t)
        t += 0.5
        rounds += 1
    rep_wall = time.monotonic() - t_rep0
    replicas = sum(len(n.replicas) for n in nodes)

    # pre-crash reference: wake the never-crashed controls, re-hibernate
    pre = [_wake_ttft(router, cfg, f"c{i}", now=20.0 + i)
           for i in range(n_controls)]
    for i in range(n_controls):
        node = router.node_of(f"c{i}")
        node.manager.descend(f"c{i}", Rung.HIBERNATED)
    pre_p99 = percentile(pre, 99)

    # kill n0 and drive heartbeat rounds in virtual time; the round
    # that crosses DEAD does the actual re-home work — time it
    t_kill = 100.0
    router.check_health(now=t_kill - 1.0)   # fresh lease: detection is
    n0.kill()                               # paced by the policy, not
                                            # by stale pre-run leases
    detect_s = recover_wall = None
    for step in range(1, int(HEALTH.dead_after_s) + 5):
        t = t_kill + float(step)
        w0 = time.monotonic()
        router.check_health(now=t)
        w = time.monotonic() - w0
        if router.tenants_rehomed + router.tenants_lost >= n_victims:
            detect_s, recover_wall = t - t_kill, w
            break
    stats = router.migration_stats()
    rehomed, lost = int(stats["tenants_rehomed"]), int(stats["tenants_lost"])
    rehome_rate = rehomed / recover_wall if recover_wall else 0.0

    # post-recovery: wake every victim on its new home
    post = [_wake_ttft(router, cfg, f"v{i}", now=200.0 + i)
            for i in range(n_victims)]
    post_p99 = percentile(post, 99)
    quarantined = sum(n.store.stats()["quarantined"]
                      for n in nodes[1:] if n.store is not None)
    router.close()

    tab = Table(
        f"Crash recovery: {n_victims} tenants on n0, k=2 replicas, "
        f"kill n0 ({ARCH}, {N_NODES} nodes)",
        ["scenario", "tenants", "lost", "detect s", "recover ms",
         "rehome/s", "pre wake p99 ms", "post wake p99 ms"])
    tab.add("kill n0 (k=2)", n_victims, lost,
            f"{detect_s:.1f}" if detect_s is not None else "-",
            f"{recover_wall * 1e3:.1f}" if recover_wall else "-",
            f"{rehome_rate:.0f}", f"{pre_p99 * 1e3:.1f}",
            f"{post_p99 * 1e3:.1f}")
    print(tab.render())
    print(f"anti-entropy: {int(stats['replications'])} replications "
          f"({replicas} replica records) in {rep_wall * 1e3:.0f} ms "
          f"across {rounds} rounds")

    # post-recovery wakes run the same replay as pre-crash ones; the
    # envelope is generous because survivors now carry double load
    wake_budget = max(5.0 * pre_p99, pre_p99 + 0.25)
    checks = [
        ("every replicated tenant re-homed, zero lost",
         rehomed == n_victims and lost == 0),
        ("death detected within dead_after_s + 2 heartbeats",
         detect_s is not None and detect_s <= HEALTH.dead_after_s + 2.0),
        ("post-recovery wake p99 within 5x of pre-crash control p99",
         post_p99 <= wake_budget),
        ("survivor stores clean: zero quarantined segments",
         quarantined == 0),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

"""Network front door: streaming TTFT through the gateway, per SLO class
and container state, plus overload behaviour.

Three measurements over real loopback HTTP (chunked NDJSON streaming):

  * concurrency — 32 clients stream simultaneously through one gateway
    (mixed interactive/batch); the front door must hold every session
    open concurrently and every stream must deliver its full token
    count.
  * TTFT per state — time-to-first-token through the full network path
    (client -> gateway -> front door -> platform -> engine) for a warm
    tenant, a hibernated (woken) tenant, and a cold start; the woken
    path is compared against the direct-engine wake baseline (same
    Request, ``on_token`` callback, no network) — the gateway must add
    protocol overhead, not a second wake path.
  * overload — a flood past the per-tenant session cap: the excess gets
    429 + Retry-After immediately (bounded queues, honest backpressure),
    never an unbounded queue.

`python -m benchmarks.gateway_latency [--quick]`
"""
from __future__ import annotations

import http.client
import json
import threading
import time

from benchmarks.common import Table, fmt_ms, make_engine, request_for
from repro.core.metrics import percentile
from repro.core.state import Rung
from repro.serving import (AsyncPlatform, FrontDoor, FrontDoorPolicy,
                           Gateway, PlatformPolicy)
from repro.serving.engine import SLO_BATCH, SLO_INTERACTIVE

ARCH = "llama3.2-3b"


def _stream_once(addr, spec, timeout=120.0):
    """One streaming request; returns (status, ttft_s, tokens, headers)."""
    conn = http.client.HTTPConnection(*addr, timeout=timeout)
    try:
        t0 = time.monotonic()
        conn.request("POST", "/v1/generate", body=json.dumps(spec),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        ttft, toks = None, 0
        while True:
            ln = resp.readline()
            if not ln:
                break
            obj = json.loads(ln)
            if "token" in obj:
                if ttft is None:
                    ttft = time.monotonic() - t0
                toks += 1
        return resp.status, ttft, toks, dict(resp.getheaders())
    finally:
        conn.close()


def _mk_stack(spool, tenants, *, workers=4, scale="tiny",
              door_policy=None, plat_policy=None):
    eng, mgr = make_engine(spool, scale=scale)
    arch_of = {t: ARCH for t in tenants}
    plat = AsyncPlatform(eng, plat_policy or PlatformPolicy(keep_warm_s=1e9),
                         arch_of, workers=workers)
    door = FrontDoor(plat, policy=door_policy)
    return mgr, plat, door


def bench_concurrency(spool, sessions=32, new_tokens=6):
    """All ``sessions`` streams open at once through one gateway."""
    tenants = [f"g{i}" for i in range(8)]
    mgr, plat, door = _mk_stack(f"{spool}/conc", tenants, workers=4)
    results = [None] * sessions
    barrier = threading.Barrier(sessions)

    def client(i):
        tenant = tenants[i % len(tenants)]
        slo = SLO_BATCH if i % 4 == 3 else SLO_INTERACTIVE
        barrier.wait()
        results[i] = _stream_once(addr, {
            "tenant": tenant, "session": f"s{i}", "prompt": [1, 2, 3, 4],
            "max_new_tokens": new_tokens, "slo": slo, "arch": ARCH,
            "close": True})

    with plat, Gateway(door) as gw:
        addr = gw.address
        t0 = time.monotonic()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(sessions)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0

    ttfts = [r[1] for r in results if r[1] is not None]
    toks = sum(r[2] for r in results)
    return {
        "sessions": sessions,
        "ok": sum(1 for r in results
                  if r[0] == 200 and r[2] == new_tokens),
        "peak": door.peak_sessions,
        "tok_s": toks / wall,
        "p50": percentile(ttfts, 50), "p99": percentile(ttfts, 99),
    }


def bench_ttft_states(spool, iters=8, new_tokens=4):
    """TTFT through the gateway per container state, plus the
    direct-engine woken baseline the acceptance ratio is against."""
    # scaled config: the wake cost dominates, so the gateway/direct TTFT
    # ratio measures protocol overhead against a realistic wake, not
    # against a near-zero tiny-model inflate
    tenants = ["warm", "woken", "direct"]
    mgr, plat, door = _mk_stack(f"{spool}/states", tenants, workers=2,
                                scale="scaled")
    out = {"warm": [], "woken": [], "cold": [], "direct": []}

    def spec(tenant, sid):
        return {"tenant": tenant, "session": sid,
                "prompt": [1, 2, 3, 4], "max_new_tokens": new_tokens,
                "arch": ARCH, "close": True}

    with plat, Gateway(door) as gw:
        addr = gw.address
        # prime all three tenants (the first request is a cold start)
        _stream_once(addr, spec("warm", "prime"))
        _stream_once(addr, spec("woken", "prime"))
        cfg = mgr.instances["warm"].cfg      # same arch, same shapes
        plat.submit(request_for(cfg, "direct", "prime", 4, new_tokens,
                                close_session=True)).result(timeout=120)
        for i in range(iters):
            _, ttft, toks, _ = _stream_once(addr, spec("warm", f"w{i}"))
            assert toks == new_tokens
            out["warm"].append(ttft)
        # woken gateway vs direct-engine baseline, interleaved pairwise:
        # a host load spike lands on both sets, not just the one that
        # happened to run first — the ratio check compares wake paths,
        # not scheduler luck
        for i in range(iters):
            mgr.descend("woken", Rung.HIBERNATED)
            _, ttft, toks, _ = _stream_once(addr, spec("woken", f"h{i}"))
            assert toks == new_tokens
            out["woken"].append(ttft)

            # baseline: same wake path, no network — Request.on_token
            # fires on the engine worker at the same point the gateway's
            # first chunk is cut
            mgr.descend("direct", Rung.HIBERNATED)
            stamp = []
            req = request_for(cfg, "direct", f"d{i}", 4, new_tokens,
                              seed=i, close_session=True,
                              slo=SLO_INTERACTIVE,
                              on_token=lambda tok, s=stamp: (
                                  s.append(time.monotonic())
                                  if not s else None))
            t0 = time.monotonic()
            plat.submit(req).result(timeout=120)
            out["direct"].append(stamp[0] - t0)
        for i in range(iters):
            iid = f"cold{i}"                 # never started before
            door.register(iid, ARCH)
            _, ttft, _, _ = _stream_once(addr, spec(iid, "c0"))
            out["cold"].append(ttft)
            mgr.evict(iid)
    return out


def bench_overload(spool, flood=16, cap=4):
    """Flood one tenant past its session cap: the overflow must get an
    immediate 429 with a Retry-After hint, not a queue slot."""
    mgr, plat, door = _mk_stack(
        f"{spool}/flood", ["hot"], workers=2,
        door_policy=FrontDoorPolicy(max_sessions_per_tenant=cap))
    statuses = [None] * flood
    barrier = threading.Barrier(flood)

    def client(i):
        barrier.wait()
        status, _, _, headers = _stream_once(addr, {
            "tenant": "hot", "session": f"f{i}", "prompt": [1, 2],
            "max_new_tokens": 8, "arch": ARCH, "close": True})
        statuses[i] = (status, headers.get("Retry-After"))

    with plat, Gateway(door) as gw:
        addr = gw.address
        _stream_once(addr, {"tenant": "hot", "session": "prime",
                            "prompt": [1], "max_new_tokens": 1,
                            "arch": ARCH, "close": True})
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(flood)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    ok = sum(1 for s, _ in statuses if s == 200)
    rejected = [h for s, h in statuses if s == 429]
    return {"flood": flood, "cap": cap, "ok": ok,
            "rejected": len(rejected),
            "hinted": sum(1 for h in rejected if h is not None),
            "stats": door.stats()}


def _trimmed_p99(xs):
    """p99 after dropping the single worst sample — applied symmetrically
    to both sides of the gateway/direct ratio so one scheduler spike
    (hundreds of ms on a loaded host) can't flip a wake-path comparison
    whose true signal is tens of ms."""
    return percentile(sorted(xs)[:-1] if len(xs) > 3 else xs, 99)


def main(quick: bool = False):
    spool = "/tmp/bench_gateway"
    iters = 8 if quick else 16

    conc = bench_concurrency(spool)
    states = bench_ttft_states(spool, iters=iters)
    flood = bench_overload(spool)

    tab = Table("network front door (loopback HTTP streaming)",
                ["phase", "streams", "tok/s",
                 "ttft p50 (ms)", "ttft p99 (ms)"])
    tab.add(f"{conc['sessions']} concurrent sessions (mixed slo)",
            conc["peak"], f"{conc['tok_s']:.0f}",
            fmt_ms(conc["p50"]), fmt_ms(conc["p99"]))
    for phase in ("warm", "woken", "cold"):
        tab.add(f"gateway {phase} interactive", 1, "",
                fmt_ms(percentile(states[phase], 50)),
                fmt_ms(percentile(states[phase], 99)))
    tab.add("direct-engine woken baseline", 1, "",
            fmt_ms(percentile(states["direct"], 50)),
            fmt_ms(percentile(states["direct"], 99)))
    tab.add(f"overload flood ({flood['flood']} vs cap {flood['cap']})",
            flood["ok"], "", "", "")
    tab.add("overload 429 + Retry-After",
            flood["rejected"], "", "", "")
    print(tab.render())

    ratio = _trimmed_p99(states["woken"]) \
        / max(_trimmed_p99(states["direct"]), 1e-9)
    checks = [
        ("gateway holds >=32 concurrent streaming sessions",
         conc["peak"] >= 32),
        ("every concurrent stream delivered its full token count",
         conc["ok"] == conc["sessions"]),
        ("woken interactive p99 TTFT within 1.5x of direct wake path",
         ratio <= 1.5),
        ("overload sheds with 429, never queues unboundedly",
         flood["rejected"] > 0
         and flood["ok"] + flood["rejected"] == flood["flood"]),
        ("every 429 carried a Retry-After hint",
         flood["hinted"] == flood["rejected"]),
    ]
    return tab, checks


if __name__ == "__main__":
    import sys
    checks = main(quick="--quick" in sys.argv)[1]
    sys.exit(0 if all(all(c[1:]) for c in checks) else 1)

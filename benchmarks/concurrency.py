"""Concurrent multi-tenant serving: the paper's Fig. 3 scenario under load.

Three measurements:

  * tenants x workers — p50/p99 end-to-end latency of a mixed trace
    (every tenant hibernated between bursts) as the AsyncPlatform's
    worker pool grows.  Different tenants inflate and serve in parallel.
  * wake storm — N threads submit to ONE hibernating tenant at once; the
    wake-storm guard must perform exactly one batched inflate (REAP read)
    no matter how many requests race.
  * vectored fault IO — the same working set restored unit-by-unit
    (one `pread` per unit) vs through the coalesced `preadv` path; the
    vectored path must issue >= 4x fewer syscalls.

`python -m benchmarks.concurrency [--quick]`
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import Table, fmt_ms, make_engine, request_for
from repro.core.metrics import percentile
from repro.core.swap import SwapFile
from repro.serving import AsyncPlatform, PlatformPolicy, Request
from repro.core.state import Rung

TENANTS = ["chat", "search", "stream", "batch"]
ARCH = "llama3.2-3b"


def _prepare(spool: str):
    """Cold-start every tenant, record its working set, deflate it."""
    eng, mgr = make_engine(spool)
    for i, t in enumerate(TENANTS):
        eng.start_instance(t, ARCH)
        cfg = mgr.instances[t].cfg
        eng.record_sample(t, request_for(cfg, t, "probe", 6, 2, seed=i,
                                         close_session=True))
        mgr.descend(t, Rung.HIBERNATED)
    return eng, mgr


def bench_workers(spool: str, n_requests: int):
    """Same trace served with 1 worker vs len(TENANTS) workers."""
    rows = []
    for workers in (1, len(TENANTS)):
        eng, mgr = _prepare(f"{spool}/w{workers}")
        arch_of = {t: ARCH for t in TENANTS}
        plat = AsyncPlatform(eng, PlatformPolicy(keep_warm_s=1e9),
                             arch_of, workers=workers)
        cfgs = {t: mgr.instances[t].cfg for t in TENANTS}
        lats = []
        t0 = time.monotonic()
        with plat:
            futs = []
            for i in range(n_requests):
                t = TENANTS[i % len(TENANTS)]
                futs.append(plat.submit(request_for(
                    cfgs[t], t, f"s{i}", 6, 2, seed=i)))
            for f in futs:
                r = f.result(timeout=300)
                lats.append(r.spans["e2e"])
        wall = time.monotonic() - t0
        rows.append((workers, percentile(lats, 50), percentile(lats, 99),
                     wall))
        for t in TENANTS:
            mgr.evict(t)
    return rows


def bench_wake_storm(spool: str, n_threads: int = 8):
    """N threads hit one HIBERNATE tenant concurrently."""
    eng, mgr = _prepare(f"{spool}/storm")
    tenant = TENANTS[0]
    inst = mgr.instances[tenant]
    reads_before = inst.reap_file.reads
    wakes_before = mgr.wakes_performed
    cfg = inst.cfg
    arch_of = {t: ARCH for t in TENANTS}
    plat = AsyncPlatform(eng, PlatformPolicy(keep_warm_s=1e9), arch_of,
                         workers=n_threads)
    barrier = threading.Barrier(n_threads)
    futs = [None] * n_threads

    def submitter(i):
        barrier.wait()
        futs[i] = plat.submit(request_for(cfg, tenant, f"storm{i}", 4, 1,
                                          seed=i))

    with plat:
        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in futs:
            f.result(timeout=300)
    return {"threads": n_threads,
            "inflates": mgr.wakes_performed - wakes_before,
            "reap_reads": inst.reap_file.reads - reads_before,
            "deduped": mgr.wakes_deduped}


def bench_vectored_io(spool: str, n_units: int = 512):
    """Per-unit random faulting vs the coalesced preadv path."""
    rng = np.random.default_rng(0)
    items = [((i,), rng.standard_normal(1024).astype(np.float32))
             for i in range(n_units)]
    f = SwapFile(f"{spool}/vec.swap")
    f.write_units(items)
    keys = [k for k, _ in items]

    r0 = f.reads
    t0 = time.monotonic()
    for k in keys:
        f.read_unit(k)
    t_unit = time.monotonic() - t0
    unit_syscalls = f.reads - r0

    r0 = f.reads
    t0 = time.monotonic()
    out = f.read_units(keys)
    t_vec = time.monotonic() - t0
    vec_syscalls = f.reads - r0
    for k, a in items:
        np.testing.assert_array_equal(out[k], a)
    f.delete()
    return {"units": n_units, "unit_syscalls": unit_syscalls,
            "vec_syscalls": vec_syscalls, "t_unit": t_unit, "t_vec": t_vec}


def main(quick: bool = False):
    spool = "/tmp/bench_concurrency"
    n_requests = 8 if quick else 16

    rows = bench_workers(spool, n_requests)
    storm = bench_wake_storm(spool)
    vec = bench_vectored_io(spool)

    tab = Table("concurrent serving (tenants x workers, wake storm, "
                "vectored IO)",
                ["metric", "value"])
    for workers, p50, p99, wall in rows:
        tab.add(f"{len(TENANTS)} tenants / {workers} worker(s) p50 (ms)",
                fmt_ms(p50))
        tab.add(f"{len(TENANTS)} tenants / {workers} worker(s) p99 (ms)",
                fmt_ms(p99))
        tab.add(f"{len(TENANTS)} tenants / {workers} worker(s) wall (ms)",
                fmt_ms(wall))
    tab.add(f"wake storm ({storm['threads']} threads) inflates",
            storm["inflates"])
    tab.add("wake storm REAP reads", storm["reap_reads"])
    tab.add("wake storm deduped wakes", storm["deduped"])
    tab.add(f"fault {vec['units']} units per-unit syscalls",
            vec["unit_syscalls"])
    tab.add("fault vectored (preadv) syscalls", vec["vec_syscalls"])
    ratio = vec["unit_syscalls"] / max(1, vec["vec_syscalls"])
    tab.add("syscall reduction", f"{ratio:.0f}x")
    print(tab.render())

    checks = [
        ("wake storm performs exactly 1 batched inflate",
         storm["inflates"] == 1),
        ("storm REAP file read once", storm["reap_reads"] <= 1),
        ("vectored fault >=4x fewer syscalls", ratio >= 4.0),
    ]
    return tab, checks


if __name__ == "__main__":
    import sys
    checks = main(quick="--quick" in sys.argv)[1]
    sys.exit(0 if all(all(c[1:]) for c in checks) else 1)

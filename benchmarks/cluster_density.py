"""Cluster density: tenants-per-GB across 4 nodes, migration on vs off.

The single-node governor's density ceiling is structural: when skewed
placement piles tenants onto one node, that node must either thrash its
hot tenants down the ladder or TERMINATE cold husks — while a neighbour
idles.  The cluster tier (``repro.cluster``) migrates hibernated
snapshots over the CAS store instead, so provisioning follows *cluster*
load, not worst-case per-node load.

Scenario: 4 nodes, one hot tenant per node (steady traffic), plus a pile
of cold tenants that all started life on node 0 (the deployment ramped
up there before the cluster filled) and now mostly sleep.  Husk metadata
is modelled at a paper-realistic fraction of the warm footprint
(``ManagerConfig.husk_metadata_bytes``), so node 0's husk load alone
breaches a tight budget even fully deflated.

Policies, swept over per-node budgets:

  migration-on   — sustained breaches ship the most idle husks to peers
                   scored ``bytes_freed * predicted_idle /
                   (transfer_missing / link_bw + wake_cost)``; dedup
                   means base weights never cross the link (every node
                   already holds the deployment's digests).
  migration-off  — the pre-cluster world: a sustained breach falls back
                   to TERMINATED, and a terminated tenant's next request
                   pays a full cold start (seconds).

Tenants-per-GB uses provisioned cluster memory (sum of node budgets);
a row qualifies only if its p99 TTFT stays within a fixed multiple of
the unconstrained-cluster p99.  Arrivals are virtual-time (the governor
and router take ``now``), so the suite measures serve/wake/cold-start
cost, not wall-clock sleeps.
"""
from __future__ import annotations

import shutil
import time

import numpy as np

from benchmarks.common import Table, build_factory, fmt_mb, request_for
from repro.cluster import ClusterPolicy, ClusterRouter, Node
from repro.core.governor import GovernorConfig
from repro.core.metrics import percentile
from repro.core.state import Rung

ARCH = "llama3.2-3b"
N_NODES = 4
PROMPT_LEN = 24
HOT_GAP = 2.0                 # one hot tenant per node, steady traffic
COLD_GAP = 12.0               # cold husks: occasional requests
SALT = b"cluster-density-bench"
#: husk metadata as a fraction of the warm footprint — the paper's
#: deflated containers keep host state alive at a meaningful fraction
#: of warm (page tables, runtime threads, compiled handles)
HUSK_FRACTION = 4


def _mk_cluster(spool: str, per_node_budget, migration: bool,
                n_hot: int, n_cold: int, husk_bytes: int):
    shutil.rmtree(spool, ignore_errors=True)
    factory = build_factory("tiny")
    gov_cfg = GovernorConfig(min_partial_bytes=4 << 10,
                             terminate_idle_s=None)   # router owns evicts
    nodes = [Node(f"n{i}", factory, spool_dir=spool, salt=SALT,
                  budget_bytes=per_node_budget, governor_cfg=gov_cfg)
             for i in range(N_NODES)]
    for n in nodes:
        n.cfg.husk_metadata_bytes = husk_bytes
    policy = ClusterPolicy(sustained_breach_rounds=2, migration=migration,
                           max_migrations_per_round=2)
    router = ClusterRouter(nodes, policy=policy)

    # skewed placement: hot tenants one per node; EVERY cold tenant
    # began life on node 0
    tenants = []
    for i in range(n_hot):
        tenants.append((f"hot{i}", nodes[i % N_NODES], HOT_GAP))
    for i in range(n_cold):
        tenants.append((f"cold{i}", nodes[0], COLD_GAP))
    cfg0 = None
    for iid, node, _gap in tenants:
        router.placement[iid] = node.node_id
        router.arch_of[iid] = ARCH
        inst = node.engine.start_instance(iid, ARCH)
        cfg0 = inst.cfg
        # one long-lived ctx session (the tenant's private KV delta —
        # what migration actually ships) + a recorded sample request
        node.engine.handle(request_for(cfg0, iid, "ctx", PROMPT_LEN, 0,
                                       seed=hash(iid) % 1000))
        inst.recorder.start()
        node.engine.handle(request_for(cfg0, iid, "probe", PROMPT_LEN, 0,
                                       seed=1 + hash(iid) % 1000,
                                       close_session=True))
        inst.recorder.stop()
        # everyone starts hibernated: digests land in every node's store
        # (this is also what lets later migrations dedup base weights)
        node.manager.descend(iid, Rung.HIBERNATED)
    return router, nodes, tenants, cfg0


def _schedule(tenants, horizon, seed=7):
    """All Poisson arrivals within the horizon (no truncation: every
    cold tenant keeps arriving for the whole run, so a TERMINATED victim
    always comes back to pay its cold start)."""
    rng = np.random.default_rng(seed)
    evs = []
    for iid, _node, gap in tenants:
        t = rng.exponential(gap)
        while t < horizon:
            evs.append((t, iid, gap))
            t += rng.exponential(gap)
    evs.sort()
    return evs


def _run(router, cfg, tenants, horizon, rebalance=True):
    ttfts = []
    sched = _schedule(tenants, horizon)
    for t, iid, _gap in sched:
        if rebalance:
            router.rebalance(now=t)
        t0 = time.monotonic()
        router.handle(
            request_for(cfg, iid, f"s{t:.3f}", PROMPT_LEN, 0,
                        seed=int(t * 1000) % 9973, close_session=True),
            now=t)
        ttfts.append(time.monotonic() - t0)
        node = router.node_of(iid)
        inst = node.manager.instances.get(iid)
        if inst is not None:
            if inst.wake_pipeline is not None:
                inst.wake_pipeline.wait(60)
            inst.quiesce_bg()
            if inst.kv is not None:
                inst.kv.trim()
            inst.last_used = t
    return ttfts, len(sched)


def _alive(router):
    return sum(len(n.manager.instances) for n in router.nodes.values())


def _per_gb(n, bytes_):
    return n / (bytes_ / 2**30)


def main(quick: bool = False):
    n_hot, n_cold = (N_NODES, 10) if quick else (N_NODES, 16)
    horizon = 24.0 if quick else 48.0
    n_tenants = n_hot + n_cold

    # warm-footprint reference: one unconstrained cluster measures the
    # per-tenant warm bytes, the husk size, and the p99 TTFT floor
    router, nodes, tenants, cfg = _mk_cluster(
        "/tmp/bench_cluster/ref", None, True, n_hot, n_cold, 1 << 16)
    warm_bytes = nodes[0].manager.instances["hot0"].weight_bytes(
        resident_only=False)
    husk_bytes = warm_bytes // HUSK_FRACTION
    ref_tt, _ = _run(router, cfg, tenants, horizon, rebalance=False)
    ref_p99 = percentile(ref_tt, 99)
    router.close()

    # p99 TTFT budget: the "equal latency" envelope both policies must
    # meet for their density row to qualify.  Generous enough for wake
    # and disk-writeback jitter on loaded runners (isolated wakes are
    # ~20 ms; a co-scheduled teardown can triple that); a TERMINATED
    # tenant's cold-start re-entry (re-trace + dispatch, >=0.5 s) still
    # blows it several times over.
    tt_budget = max(6.0 * ref_p99, ref_p99 + 0.15)

    # per-node budget sweep: tight fits (hot warm + cluster-fair share of
    # husks); loose fits node 0's entire skewed husk pile locally
    tight = warm_bytes + (n_cold // N_NODES + 2) * husk_bytes
    loose = warm_bytes + (n_cold + 2) * husk_bytes
    budgets = (tight, loose)

    rows = []
    mig_stats = None
    for migration in (True, False):
        for budget in budgets:
            name = (f"{'migration' if migration else 'no-migration'}"
                    f"@{fmt_mb(budget)}MB/node")
            router, nodes, tenants, cfg = _mk_cluster(
                f"/tmp/bench_cluster/{'mig' if migration else 'off'}"
                f"{budget % 997}", budget, migration, n_hot, n_cold,
                husk_bytes)
            tt, _n_ev = _run(router, cfg, tenants, horizon)
            stats = router.migration_stats()
            stats["evictions"] = router.evictions
            if migration and budget == tight:
                mig_stats = stats
            rows.append((name, budget, tt, _alive(router), stats))
            router.close()

    cluster = N_NODES
    tab = Table(
        f"Cluster density: {n_tenants} tenants / {N_NODES} nodes "
        f"({ARCH}, skewed cold pile on n0); p99 TTFT budget "
        f"{tt_budget * 1e3:.0f} ms",
        ["policy", "node MB", "cluster MB", "tenants/GB", "ttft p50 ms",
         "ttft p99 ms", "within budget", "evictions", "migrations",
         "wire MB", "full-snap MB"])
    qualifying = {True: 0.0, False: 0.0}
    for name, budget, tt, _n_alive, stats in rows:
        prov = cluster * budget
        p50, p99 = percentile(tt, 50), percentile(tt, 99)
        ok = p99 <= tt_budget
        dens = _per_gb(n_tenants, prov)
        is_mig = name.startswith("migration")
        if ok:
            qualifying[is_mig] = max(qualifying[is_mig], dens)
        tab.add(name, fmt_mb(budget), fmt_mb(prov), f"{dens:.0f}",
                f"{p50 * 1e3:.1f}", f"{p99 * 1e3:.1f}",
                "yes" if ok else "NO", int(stats["evictions"]),
                int(stats["migrations"]),
                fmt_mb(stats["wire_bytes"]),
                fmt_mb(stats["full_snapshot_bytes"]))
    print(tab.render())

    wire_ratio = (mig_stats["wire_bytes"]
                  / max(mig_stats["full_snapshot_bytes"], 1)) \
        if mig_stats and mig_stats["migrations"] else 1.0
    print(f"dedup-aware transfer: {wire_ratio:.2f}x of naive "
          f"full-snapshot bytes over {int(mig_stats['migrations'])} "
          f"migrations" if mig_stats else "no migrations ran")

    checks = [
        ("migration >=1.5x cluster tenants-per-GB vs no-migration "
         "at equal p99 TTFT",
         qualifying[True] >= 1.5 * qualifying[False] > 0),
        ("migration traffic <=0.3x naive full-snapshot bytes (dedup)",
         bool(mig_stats) and mig_stats["migrations"] >= 1
         and wire_ratio <= 0.3),
        ("migration keeps every tenant alive at the tight budget "
         "(zero TERMINATED evictions)",
         any(s["evictions"] == 0 and alive == n_tenants
             and name.startswith("migration") and budget == tight
             for name, budget, _tt, alive, s in rows)),
        ("no-migration falls back to TERMINATED at the tight budget",
         any(s["evictions"] > 0 and name.startswith("no-migration")
             and budget == tight
             for name, budget, _tt, alive, s in rows)),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

"""Governor density: tenants-per-GB vs p99 TTFT under a shrinking budget.

The paper's economics are a spectrum between Warm and Hibernate; the
:class:`~repro.core.governor.MemoryGovernor` spends that spectrum against
a fixed node memory budget.  This suite drives a Poisson tenant mix (hot
/ medium / cold arrival rates) through one engine under four policies:

  always-warm     — no deflation: density is bounded by the warm PSS
                    footprint, latency is the floor.
  always-hib      — full deflate after every request: density is bounded
                    only by the peak of one inflated tenant, every
                    request pays a full REAP wake.
  governor@f%     — the rung ladder under a budget of f% of the warm
                    footprint: hot tenants stay high on the ladder
                    (EWMA next-arrival prediction), cold tenants sink
                    through MMAP_CLEAN/PARTIAL to HIBERNATED.

Tenants-per-GB is tenants divided by *provisioned* node memory: the warm
footprint for always-warm, the observed peak for always-hib, the enforced
budget for the governor rows.  A separate controlled micro-benchmark
measures the per-rung wake critical path (the same tenant deflated to
PARTIAL vs HIBERNATED, woken by a request) — the PARTIAL rung's reason to
exist is that its wake is measurably cheaper.

Arrival times are virtual (the governor's `now` is a parameter), so the
suite measures wake/serve cost, not wall-clock sleeps.
"""
from __future__ import annotations

import shutil
import time

import numpy as np

from benchmarks.common import (SHARED_PATHS, Table, build_factory, fmt_mb,
                               request_for, shared_loader_for)
from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import per_rung_report, percentile
from repro.serving.engine import ServingEngine
from repro.core.state import Rung

ARCH = "arctic-480b"         # MoE: expert units give the PARTIAL rung teeth
PROMPT_LEN = 24
HOT_GAP, MED_GAP, COLD_GAP = 0.5, 2.0, 8.0


def _make(spool: str, budget=None, governor_cfg=None):
    shutil.rmtree(spool, ignore_errors=True)
    factory = build_factory("tiny")
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool, wake_mode="reap",
                      share_base_weights=True,
                      memory_budget_bytes=budget,
                      governor_policy=governor_cfg),
        factory, shared_loader=shared_loader_for(factory))
    return ServingEngine(mgr), mgr


def _setup_tenants(eng, mgr, n):
    """Cold-start n tenants, warm the compile caches, record working sets.

    Each tenant keeps one long-lived "ctx" session open whose KV pages the
    recorded working set does NOT touch: cold deep-layer context — exactly
    the REAP-miss-ranked PARTIAL-rung victims.  Benchmark requests use
    fresh short sessions so serve shapes (and compile buckets) stay
    fixed."""
    for i in range(n):
        iid = f"t{i}"
        inst = eng.start_instance(iid, ARCH, shared_paths=SHARED_PATHS)
        eng.handle(request_for(inst.cfg, iid, "ctx", 64, 1, seed=i))
        inst.recorder.start()
        eng.handle(request_for(inst.cfg, iid, "probe", PROMPT_LEN, 1,
                               seed=100 + i, close_session=True))
        inst.recorder.stop()


def _gaps(n):
    """Per-tenant mean inter-arrival gap: 1/3 hot, 1/3 medium, 1/3 cold."""
    return [HOT_GAP if i < n // 3 else
            MED_GAP if i < 2 * n // 3 else COLD_GAP for i in range(n)]


def _schedule(n, events, seed=7):
    """Merged Poisson arrival schedule: [(t, tenant_idx)] sorted by t."""
    rng = np.random.default_rng(seed)
    gaps = _gaps(n)
    per = -(-events // n)
    evs = []
    for i in range(n):
        t = 0.0
        for _ in range(per):
            t += rng.exponential(gaps[i])
            evs.append((t, i))
    evs.sort()
    return evs[:events]


def _run(eng, mgr, n, events, policy, seed=7):
    """Drive the schedule; returns (ttfts, peak_resident, rung_counts)."""
    ttfts = []
    # peak is sampled after each event's policy+serve, not at entry: the
    # setup leaves every tenant warm, and charging the governor for
    # memory it has not yet been asked to reclaim would be noise
    peak = 0
    gov = mgr.governor
    for j, (t, i) in enumerate(_schedule(n, events, seed)):
        iid = f"t{i}"
        inst = mgr.instances[iid]
        gov.observe_arrival(iid, now=t)
        if policy == "governor":
            gov.step(now=t)
        t0 = time.monotonic()
        eng.handle(request_for(inst.cfg, iid, f"s{j}", PROMPT_LEN, 1,
                               seed=1000 + j, close_session=True))
        ttfts.append(time.monotonic() - t0)
        if inst.wake_pipeline is not None:
            inst.wake_pipeline.wait(60)
        inst.quiesce_bg()
        inst.kv.trim()                 # guest free of the closed session
        inst.last_used = t
        peak = max(peak, mgr.resident_bytes())
        if policy == "always-hib":
            mgr.descend(iid, Rung.HIBERNATED)
    return ttfts, peak, per_rung_report(mgr)


def _rung_wake_costs(eng, mgr, iid, cycles):
    """Controlled per-rung wake cost: deflate ONE tenant to PARTIAL vs
    HIBERNATED, wake it with a real request, average the measured
    critical-path seconds (WakeStats.rung distinguishes the ladders)."""
    inst = mgr.instances[iid]
    out = {"partial": [], "hibernated": []}
    for c in range(cycles):
        for rung in ("partial", "hibernated"):
            if rung == "partial":
                victims = [k for _, _, k in
                           mgr.governor._partial_candidates(inst)]
                mgr.descend(iid, Rung.PARTIAL, keys=victims)
            else:
                mgr.descend(iid, Rung.HIBERNATED)
            eng.handle(request_for(inst.cfg, iid, f"rw{c}{rung[0]}",
                                   PROMPT_LEN, 1, seed=500 + c,
                                   close_session=True))
            if inst.wake_pipeline is not None:
                inst.wake_pipeline.wait(60)
            inst.quiesce_bg()
            wakes = [s for op, _, s in mgr.hib.log if op == "wake"]
            assert wakes[-1].rung == rung, (wakes[-1].rung, rung)
            out[rung].append(wakes[-1].critical_path_seconds)
    return {r: float(np.mean(v)) for r, v in out.items()}


def _per_gb(n, bytes_):
    return n / (bytes_ / 2**30)


def main(quick: bool = False):
    n = 6 if quick else 9
    events = 36 if quick else 90
    fracs = (0.5, 0.3) if quick else (0.6, 0.4, 0.25)
    gov_cfg = GovernorConfig(min_partial_bytes=4 << 10, headroom=0.05)

    # warm footprint reference (also the always-warm run)
    eng, mgr = _make("/tmp/bench_governor/warm")
    _setup_tenants(eng, mgr, n)
    warm_bytes = mgr.resident_bytes()
    warm_tt, warm_peak, _ = _run(eng, mgr, n, events, "always-warm")
    rung_costs = _rung_wake_costs(eng, mgr, f"t{n - 1}", 3 if quick else 5)
    del eng, mgr

    rows = [("always-warm", warm_peak, warm_peak, warm_tt, None)]
    eng, mgr = _make("/tmp/bench_governor/hib")
    _setup_tenants(eng, mgr, n)
    for i in range(n):
        mgr.descend(f"t{i}", Rung.HIBERNATED)
    hib_tt, hib_peak, _ = _run(eng, mgr, n, events, "always-hib")
    rows.append(("always-hib", hib_peak, hib_peak, hib_tt, None))
    del eng, mgr

    budget_ok = True
    for f in fracs:
        budget = int(warm_bytes * f)
        eng, mgr = _make(f"/tmp/bench_governor/gov{int(f * 100)}",
                         budget=budget, governor_cfg=gov_cfg)
        _setup_tenants(eng, mgr, n)
        tt, peak, rungs = _run(eng, mgr, n, events, "governor")
        # enforcement: measured peak may transiently exceed the budget by
        # about one tenant's wake restore (the governor reclaims at the
        # next event), never by the whole fleet — a no-op governor would
        # sit at the warm footprint and fail this
        budget_ok &= peak <= budget + 2 * warm_bytes / n
        rows.append((f"governor@{int(f * 100)}%", max(budget, 1), peak,
                     tt, rungs))
        del eng, mgr

    # p99 TTFT budget: a fixed multiple of the warm floor (the "near-warm"
    # envelope a latency SLO would allow)
    warm_p99 = percentile(warm_tt, 99)
    tt_budget = max(3.0 * warm_p99, warm_p99 + 0.05)

    tab = Table(
        f"Governor density: {n} Poisson tenants ({ARCH}), shrinking budget; "
        f"p99 TTFT budget {tt_budget * 1e3:.0f} ms",
        ["policy", "provisioned MB", "peak MB", "tenants/GB", "ttft p50 ms",
         "ttft p99 ms", "within budget", "rungs at end"])
    densities = {}
    for name, prov, peak, tt, rungs in rows:
        p50, p99 = percentile(tt, 50), percentile(tt, 99)
        densities[name] = (_per_gb(n, prov), p99)
        rung_str = "-" if rungs is None else " ".join(
            f"{r}:{int(v['instances'])}" for r, v in sorted(rungs.items()))
        tab.add(name, fmt_mb(prov), fmt_mb(peak), f"{_per_gb(n, prov):.1f}",
                f"{p50 * 1e3:.1f}", f"{p99 * 1e3:.1f}",
                "yes" if p99 <= tt_budget else "NO", rung_str)
    print(tab.render())
    print(f"rung wake critical path: partial "
          f"{rung_costs['partial'] * 1e3:.2f} ms vs hibernated "
          f"{rung_costs['hibernated'] * 1e3:.2f} ms")

    warm_density = densities["always-warm"][0]
    gov_ok = [d for name, (d, p99) in densities.items()
              if name.startswith("governor") and p99 <= tt_budget]
    checks = [
        ("governor >=1.5x tenants-per-GB vs always-warm at fixed p99 TTFT",
         bool(gov_ok) and max(gov_ok) >= 1.5 * warm_density),
        ("partial wake critical path < hibernated wake critical path",
         rung_costs["partial"] < rung_costs["hibernated"]),
        # density rows are provisioned-budget based, so this is the claim
        # that makes them honest: the governor actually held the fleet to
        # the budget (modulo one tenant's transient wake restore)
        ("governor enforces budget (measured peak)", budget_ok),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

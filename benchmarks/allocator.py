"""§3.3 Bitmap Page Allocator micro-benchmark: alloc/free throughput and
reclamation behaviour vs a free-list (buddy-style) baseline that cannot
madvise without fixing up in-page metadata."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Table
from repro.core.bitmap_alloc import PAGES_PER_BLOCK, BitmapPageAllocator

N_OPS = 200_000


class FreeListAllocator:
    """Baseline: free list with 'next' stored in the page (conceptually);
    committed blocks can never be returned without walking/repairing the
    list (the paper's argument for the bitmap design)."""

    def __init__(self):
        self.free = []
        self.top = 0
        self.committed = set()

    def alloc(self):
        if self.free:
            return self.free.pop()
        p = self.top
        self.top += 1
        self.committed.add(p >> 10)
        return p

    def dealloc(self, p):
        self.free.append(p)


def bench(alloc_fn, free_fn, rng) -> float:
    live = []
    t0 = time.monotonic()
    for _ in range(N_OPS):
        if not live or rng.random() < 0.55:
            live.append(alloc_fn())
        else:
            free_fn(live.pop(int(rng.integers(len(live)))))
    return time.monotonic() - t0


def main(quick: bool = False):
    rng1, rng2 = (np.random.default_rng(0), np.random.default_rng(0))
    bm = BitmapPageAllocator()
    t_bm = bench(bm.alloc, bm.free, rng1)
    fl = FreeListAllocator()
    t_fl = bench(fl.alloc, fl.dealloc, rng2)

    # reclamation: free everything, count memory returned to the host
    for blk in list(bm.blocks.values()):
        for off in range(1, PAGES_PER_BLOCK):
            if not blk.is_free(off):
                bm.free(blk.block_id * PAGES_PER_BLOCK + off)
    tab = Table(f"§3.3 allocator ({N_OPS} mixed ops)",
                ["allocator", "ops/s", "reclaimable blocks"])
    tab.add("bitmap (paper)", f"{N_OPS / t_bm:,.0f}",
            f"all ({bm.stats['blocks_released']} released)")
    tab.add("free-list baseline", f"{N_OPS / t_fl:,.0f}",
            "0 (in-page metadata)")
    print(tab.render())
    return tab, [("bitmap reclaims", bm.committed_blocks == 0),
                 ("freelist cannot", len(fl.committed) > 0)]


if __name__ == "__main__":
    main()

"""REAP ablation (beyond the paper's Fig. 6): working-set coverage vs
request drift.

REAP's premise is that "functions access the same stable working set
across invocations".  LLM working sets drift: a different prompt routes
to different experts and touches different embedding rows.  This ablation
records the working set with a probe, then serves requests at increasing
token-distribution drift from the probe and measures residual page faults
and fault bytes — quantifying how much of the REAP benefit survives
drift, and how the recorder's union-over-invocations recovers it.
"""
from __future__ import annotations

import numpy as np

import dataclasses
import shutil

import jax

from benchmarks.common import Table, fmt_mb
from repro.core.manager import InstanceManager, ManagerConfig
from repro.models import model
from repro.serving import Request, ServingEngine
from repro.core.state import Rung

ARCH = "deepseek-v2-236b"      # experts + embed blocks: the drifting parts
N_TOKENS, NEW = 24, 4
PROBE_TOKENS, PROBE_NEW = 6, 1   # narrow probe -> drift has room to show


def _make_engine(spool):
    """Custom scale: 16 experts / 8 embed blocks so the working set has
    enough granularity for drift to show."""
    from repro.configs import get_config, scaled_config

    shutil.rmtree(spool, ignore_errors=True)

    def factory(arch):
        cfg = scaled_config(get_config(arch))
        cfg = dataclasses.replace(
            cfg, vocab_size=4096,
            moe=dataclasses.replace(cfg.moe, num_experts=16, top_k=2,
                                    expert_d_ff=128))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(ManagerConfig(spool_dir=spool, wake_mode="reap"),
                          factory)
    return ServingEngine(mgr), mgr


def _prompt(rng, cfg, lo, hi):
    return rng.integers(lo, hi, N_TOKENS).astype(np.int32)


def run(drift: float, union_probes: int, spool: str):
    """drift: fraction of the vocab range shifted away from the probe's."""
    eng, mgr = _make_engine(f"{spool}/{drift}-{union_probes}")
    inst = eng.start_instance("i", ARCH)
    cfg = inst.cfg
    rng = np.random.default_rng(0)
    V = cfg.vocab_size
    # probe(s) draw tokens from the low half; drifted requests shift up
    for j in range(union_probes):
        span = (V // 2) if union_probes == 1 else (V // 2) * (j + 1)
        probe = rng.integers(span - V // 2, span,
                             PROBE_TOKENS).astype(np.int32)
        eng.record_sample("i", Request(
            "i", f"probe{j}", probe,
            max_new_tokens=PROBE_NEW, close_session=True))
    mgr.descend("i", Rung.HIBERNATED)
    lo = int(drift * (V // 2))
    r = eng.handle(Request("i", "req", _prompt(rng, cfg, lo, lo + V // 2),
                           max_new_tokens=NEW, close_session=True))
    ws_units = len(inst.recorder.working_set)
    return {"faults": r.faults, "fault_bytes": r.faulted_bytes,
            "prefetched": r.prefetched_bytes, "ws_units": ws_units,
            "e2e": r.spans["e2e"]}


def main(quick: bool = False):
    tab = Table(f"REAP drift ablation ({ARCH}, scaled)",
                ["drift", "probes", "ws units", "prefetch MB",
                 "residual faults", "fault MB"])
    checks = []
    drifts = [0.0, 1.0] if quick else [0.0, 0.5, 1.0]
    rows = {}
    for drift in drifts:
        r = run(drift, 1, "/tmp/bench_reap_abl")
        rows[drift] = r
        tab.add(f"{drift:.0%}", 1, r["ws_units"], fmt_mb(r["prefetched"]),
                r["faults"], fmt_mb(r["fault_bytes"]))
    # union-of-probes recovery: probe both halves of the distribution
    r2 = run(1.0, 2, "/tmp/bench_reap_abl_u")
    tab.add("100%", "2 (union)", r2["ws_units"], fmt_mb(r2["prefetched"]),
            r2["faults"], fmt_mb(r2["fault_bytes"]))
    print(tab.render())
    checks.append(("drift increases faults",
                   rows[drifts[-1]]["faults"] >= rows[0.0]["faults"]))
    checks.append(("matched request ~ fault-free", rows[0.0]["faults"]
                   <= rows[drifts[-1]]["faults"]))
    return tab, checks


if __name__ == "__main__":
    main()

"""Diff two ``benchmarks.run`` result files and fail on regression.

Higher-is-better metrics (table columns whose header contains ``/s`` or
``/GB`` — throughputs and densities) must not drop more than
``--max-regress`` relative to the committed baseline;
claim checks that passed in the baseline must still pass.  Only suites
present in BOTH files are compared, so a quick CI subset can be diffed
against a full baseline.

Usage:
  python -m benchmarks.compare bench_results.json new.json \
      --max-regress 0.25 --suites allocator,swap_throughput
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple


def _to_float(cell) -> float | None:
    if isinstance(cell, (int, float)):
        return float(cell)
    if isinstance(cell, str):
        try:
            return float(cell.replace(",", ""))
        except ValueError:
            return None
    return None


#: column-header markers for higher-is-better metrics: rates ("ops/s",
#: "wakes/s") and densities ("tenants/GB")
_HIGHER_IS_BETTER = ("/s", "/GB")


def throughput_metrics(results: dict) -> Dict[Tuple[str, str, str], float]:
    """(suite, row-label, column) -> value for every higher-is-better cell."""
    out = {}
    for suite, payload in results.items():
        tab = payload.get("table", {})
        cols = tab.get("columns", [])
        for row in tab.get("rows", []):
            label = str(row[0]) if row else ""
            for col, cell in zip(cols[1:], row[1:]):
                if not any(m in str(col) for m in _HIGHER_IS_BETTER):
                    continue
                v = _to_float(cell)
                if v is not None and v > 0:
                    out[(suite, label, str(col))] = v
    return out


def passed_checks(results: dict):
    return {(suite, name) for suite, payload in results.items()
            for name, ok in payload.get("checks", []) if ok}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="max tolerated fractional throughput drop")
    ap.add_argument("--suites", default=None,
                    help="comma-separated allowlist (default: all shared)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        cand = json.load(f)
    if args.suites:
        keep = set(args.suites.split(","))
        base = {k: v for k, v in base.items() if k in keep}
        cand = {k: v for k, v in cand.items() if k in keep}

    b, c = throughput_metrics(base), throughput_metrics(cand)
    shared = sorted(set(b) & set(c))
    if not shared:
        print("compare: no shared throughput metrics — nothing to diff",
              file=sys.stderr)
        return 1

    failures = []
    # a metric that silently vanished is exactly the signal this gate
    # exists for (e.g. a renamed row hiding a lost fast path)
    for key in sorted(set(b) - set(c)):
        if key[0] in cand:                 # suite ran but metric is gone
            print(f"METRIC MISSING from candidate: {key}")
            failures.append((key, 0.0))
    print(f"{'suite':<16} {'metric':<40} {'baseline':>12} {'new':>12} "
          f"{'ratio':>7}")
    for key in shared:
        suite, label, col = key
        ratio = c[key] / b[key]
        flag = ""
        if ratio < 1.0 - args.max_regress:
            flag = "  << REGRESSION"
            failures.append((key, ratio))
        print(f"{suite:<16} {label + ' [' + col + ']':<40} "
              f"{b[key]:>12,.0f} {c[key]:>12,.0f} {ratio:>6.2f}x{flag}")

    lost = passed_checks(base) - passed_checks(cand) \
        if set(cand) else set()
    for suite, name in sorted(lost):
        # only flag checks the candidate actually ran and failed
        ran = {n for n, _ in cand.get(suite, {}).get("checks", [])}
        if name in ran:
            print(f"CHECK LOST: {suite}: {name}")
            failures.append(((suite, name, "check"), 0.0))

    if failures:
        print(f"\n{len(failures)} regression(s) beyond "
              f"{args.max_regress:.0%} tolerance", file=sys.stderr)
        return 1
    print(f"\nOK: {len(shared)} throughput metrics within "
          f"{args.max_regress:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

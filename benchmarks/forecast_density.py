"""Forecast density: predictive pre-inflate vs the reactive governor.

The reactive governor (PR 5) predicts each tenant's next arrival with a
memoryless inter-arrival EWMA — good for steady Poisson traffic, blind
to *structure*: a diurnal tenant quiet for most of the period looks
exactly like a dead one, and a flash crowd (hundreds of tenants hit in
the same few seconds, the paper's motivating burst) gives the EWMA no
warning at all.  The :class:`~repro.core.forecast.TrafficForecaster`
adds per-tenant seasonal phase bins plus a short/long-window burst
detector, and the :class:`~repro.core.forecast.ForecastDaemon` spends
those predictions as low-priority pre-inflates through the existing
wake pipeline.

This suite drives two virtual-time traces through one budgeted node,
each under two policies (reactive = ``GovernorConfig(forecast=None)``,
forecast = the same governor with a forecaster):

  diurnal      — tenants in four phase cohorts, each active only in its
                 quarter of the period (Poisson inside the window).
  flash-crowd  — sparse background arrivals, plus a cohort that slams
                 the node at the same phase every period.

Both traces run identical learning periods (arrivals observed, tenants
hibernated, no serving measured) before one measured period.  In the
forecast runs the daemon is stepped *before* each arrival is revealed,
so a pre-inflate only ever comes from the seasonal model / burst
detector, never from peeking at the event being measured; its wake cost
is paid off the request path, which is exactly the mechanism under
test.  Arrival times are virtual — the suite measures wake/serve cost,
not wall-clock sleeps.  Tenants-per-GB is tenants over the enforced
budget, identical for both policies by construction: the claim gated
here is that forecasting makes the burst land on pre-inflated tenants
(fewer deflated burst hits, lower burst-arrival TTFT) at *equal*
density, not that it changes the budget.
"""
from __future__ import annotations

import shutil
import time

import numpy as np

from benchmarks.common import (SHARED_PATHS, Table, build_factory, fmt_mb,
                               request_for, shared_loader_for)
from repro.core.forecast import ForecastConfig, ForecastDaemon
from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import percentile
from repro.core.state import ContainerState, Rung
from repro.serving.engine import ServingEngine

DEFLATED = (ContainerState.HIBERNATE, ContainerState.PARTIAL,
            ContainerState.MMAP_CLEAN)

ARCH = "arctic-480b"
PROMPT_LEN = 24
PERIOD_S = 60.0              # one virtual "day"
LEARN_PERIODS = 3            # observed-only periods before the measure one


def _forecast_cfg() -> ForecastConfig:
    return ForecastConfig(
        season_period_s=PERIOD_S, n_bins=12, min_periods=2,
        confidence_arrivals=8, preinflate_margin_s=6.0,
        preinflate_min_confidence=0.2, max_preinflates_per_pass=16,
        short_window_s=2.0, long_window_s=20.0,
        burst_ratio=3.0, burst_min_arrivals=4)


def _make(spool: str, budget, forecast: bool):
    shutil.rmtree(spool, ignore_errors=True)
    factory = build_factory("tiny")
    gov_cfg = GovernorConfig(
        min_partial_bytes=4 << 10, headroom=0.05,
        forecast=_forecast_cfg() if forecast else None)
    mgr = InstanceManager(
        ManagerConfig(spool_dir=spool, wake_mode="reap",
                      share_base_weights=True,
                      memory_budget_bytes=budget,
                      governor_policy=gov_cfg),
        factory, shared_loader=shared_loader_for(factory))
    return ServingEngine(mgr), mgr


def _setup_tenants(eng, mgr, n):
    """Cold-start n tenants with recorded working sets, then hibernate
    the whole fleet — both traces start from the deflated steady state
    the density numbers assume."""
    for i in range(n):
        iid = f"t{i}"
        inst = eng.start_instance(iid, ARCH, shared_paths=SHARED_PATHS)
        inst.recorder.start()
        eng.handle(request_for(inst.cfg, iid, "probe", PROMPT_LEN, 1,
                               seed=100 + i, close_session=True))
        inst.recorder.stop()
    for i in range(n):
        mgr.descend(f"t{i}", Rung.HIBERNATED)


def _diurnal_schedule(n, periods, seed):
    """[(t, tenant_idx, in_burst)]: four phase cohorts, each tenant
    Poisson-active only inside its quarter of every period (no burst
    cohort — ``in_burst`` is always False here)."""
    rng = np.random.default_rng(seed)
    evs = []
    win = PERIOD_S / 4.0
    for i in range(n):
        start = (i % 4) * win
        for p in range(periods):
            t = p * PERIOD_S + start
            end = t + win
            while True:
                t += rng.exponential(8.0)
                if t >= end:
                    break
                evs.append((t, i, False))
    evs.sort()
    return evs


def _flash_schedule(n, periods, seed):
    """[(t, tenant_idx, in_burst)]: the first quarter of the fleet is
    the crowd — quiet all day, then slamming the node together at phase
    0.6 every period (the paper's motivating burst); the rest is sparse
    Poisson background.  Crowd events carry ``in_burst=True`` so the
    run can score the wake storm separately from scattered background
    wakes; a memoryless EWMA sees one arrival per period from a crowd
    tenant and predicts it cold forever."""
    rng = np.random.default_rng(seed)
    evs = []
    crowd = max(1, n // 4)
    for i in range(crowd, n):
        t = 0.0
        while True:
            t += rng.exponential(PERIOD_S * 1.5)
            if t >= periods * PERIOD_S:
                break
            evs.append((t, i, False))
    for p in range(periods):
        base = p * PERIOD_S + 0.6 * PERIOD_S
        for i in range(crowd):
            evs.append((base + rng.uniform(0.0, 2.0), i, True))
    evs.sort()
    return evs


def _tick(mgr, daemon, t):
    """One control-plane tick: the daemon pre-inflates whoever the model
    says is due, and we absorb the wake cost *here* — off the request
    path, which is the whole point.  A governor pass follows in the same
    tick (as in the platform's policy daemon), so pre-inflating the next
    cohort displaces colder tenants immediately instead of letting the
    transient stack until the next arrival."""
    woke = daemon.step(t)
    for wid in woke:
        winst = mgr.instances.get(wid)
        if winst is not None and winst.wake_pipeline is not None:
            winst.wake_pipeline.wait(60)
    if woke:
        mgr.governor.step(now=t)


def _run(eng, mgr, schedule, *, measure_from, tick_s=1.0):
    """Drive the schedule: arrivals before ``measure_from`` only train
    the models; after it, every request is served and timed, with the
    forecast daemon ticking on a steady virtual cadence between events
    (like the platform's policy daemon, it never sees the unrevealed
    arrivals).  Returns a result dict — deflated-arrival counts are
    kept separately for burst-flagged events, because under a hard
    budget pre-inflating the crowd *displaces* warm background tenants:
    the claim is that the clustered wake storm lands warm, not that the
    total number of (scattered, cheap) wakes drops."""
    gov = mgr.governor
    daemon = ForecastDaemon(mgr) if gov.forecaster is not None else None
    ttfts, burst_ttfts = [], []
    deflated = burst_deflated = 0
    peak = 0
    clock = measure_from
    for j, (t, i, in_burst) in enumerate(schedule):
        iid = f"t{i}"
        if t < measure_from:
            gov.observe_arrival(iid, now=t)
            continue
        if daemon is not None:
            while clock < t:
                _tick(mgr, daemon, clock)
                clock += tick_s
            _tick(mgr, daemon, t)
        gov.observe_arrival(iid, now=t)
        gov.step(now=t)
        inst = mgr.instances[iid]
        was_deflated = inst.state in DEFLATED
        t0 = time.monotonic()
        eng.handle(request_for(inst.cfg, iid, f"s{j}", PROMPT_LEN, 1,
                               seed=1000 + j, close_session=True))
        dt = time.monotonic() - t0
        ttfts.append(dt)
        deflated += was_deflated
        if in_burst:
            burst_ttfts.append(dt)
            burst_deflated += was_deflated
        if inst.wake_pipeline is not None:
            inst.wake_pipeline.wait(60)
        inst.quiesce_bg()
        inst.kv.trim()
        inst.last_used = t
        peak = max(peak, mgr.resident_bytes())
    return {
        "ttfts": ttfts, "burst_ttfts": burst_ttfts,
        "deflated": deflated, "burst_deflated": burst_deflated,
        "peak": peak,
        "prewarmed": daemon.prewarmed_tenants if daemon is not None else 0,
    }


def _per_gb(n, bytes_):
    return n / (bytes_ / 2**30)


def main(quick: bool = False):
    n = 24 if quick else 240
    seed = 7
    periods = LEARN_PERIODS + 1
    measure_from = LEARN_PERIODS * PERIOD_S

    traces = [
        ("diurnal", _diurnal_schedule(n, periods, seed)),
        ("flash-crowd", _flash_schedule(n, periods, seed + 1)),
    ]

    # budget reference: one warm fleet build (reused for its footprint
    # only — each measured run gets a fresh node)
    eng, mgr = _make("/tmp/bench_forecast/ref", None, forecast=False)
    _setup_tenants(eng, mgr, min(n, 6))
    per_tenant = mgr.resident_bytes() // min(n, 6)
    del eng, mgr
    budget = max(int(per_tenant * n * 0.35), 64 << 20)

    tab = Table(
        f"Forecast density: {n} tenants ({ARCH}), budget {fmt_mb(budget)} MB,"
        f" {LEARN_PERIODS} learning periods + 1 measured",
        ["trace", "policy", "tenants/GB", "ttft p50 ms", "ttft p99 ms",
         "burst mean ms", "deflated hits", "burst deflated", "prewarmed",
         "peak MB"])
    results = {}
    budget_ok = True
    for trace, schedule in traces:
        for policy in ("reactive", "forecast"):
            eng, mgr = _make(f"/tmp/bench_forecast/{trace}-{policy}",
                             budget, forecast=(policy == "forecast"))
            _setup_tenants(eng, mgr, n)
            r = _run(eng, mgr, schedule, measure_from=measure_from)
            # transient slack: wake restores may overshoot until the
            # next governor pass reclaims them
            budget_ok &= r["peak"] <= budget + max(3 * per_tenant,
                                                   budget // 8)
            tt, btt = r["ttfts"], r["burst_ttfts"]
            r["p99"] = percentile(tt, 99)
            r["burst_mean"] = sum(btt) / len(btt) if btt else 0.0
            results[(trace, policy)] = r
            tab.add(trace, policy, f"{_per_gb(n, budget):.1f}",
                    f"{percentile(tt, 50) * 1e3:.1f}",
                    f"{r['p99'] * 1e3:.1f}",
                    f"{r['burst_mean'] * 1e3:.1f}" if btt else "-",
                    f"{r['deflated']}/{len(tt)}",
                    f"{r['burst_deflated']}/{len(btt)}" if btt else "-",
                    str(r["prewarmed"]), fmt_mb(r["peak"]))
            del eng, mgr
    print(tab.render())

    flash_re, flash_fc = results[("flash-crowd", "reactive")], \
        results[("flash-crowd", "forecast")]
    diur_fc = results[("diurnal", "forecast")]
    checks = [
        # the headline: at identical tenants-per-GB the forecaster eats
        # the flash crowd's wake storm off the request path — the
        # *clustered* burst arrivals land on pre-inflated tenants.
        # (Total deflated count is NOT gated for this trace: under a
        # hard budget, pre-inflating the crowd displaces warm background
        # tenants into scattered — individually cheap — wakes.)
        ("flash-crowd: burst arrivals land on pre-inflated tenants "
         "(fewer deflated burst hits than reactive)",
         flash_fc["burst_deflated"] < flash_re["burst_deflated"]),
        ("flash-crowd: mean burst-arrival TTFT forecast < reactive",
         flash_fc["burst_mean"] < flash_re["burst_mean"]),
        # the diurnal trace is informative, not gated on counts: at a
        # budget ~3 tenants short of the warm fleet, cohort-transition
        # displacement is sensitive to wall-clock wake-cost EWMAs and
        # the deflated-hit count swings run to run — the deterministic
        # mechanism claim lives on the flash trace above
        ("forecast daemon actually pre-inflated tenants (flash-crowd)",
         flash_fc["prewarmed"] > 0),
        ("forecast daemon actually pre-inflated tenants (diurnal)",
         diur_fc["prewarmed"] > 0),
        ("governor enforces budget under both policies (measured peak)",
         budget_ok),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

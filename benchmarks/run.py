"""Benchmark harness: one module per paper table/figure.

  latency_states   — Fig. 6 (request latency per container state)
  memory_states    — Fig. 7 (PSS per state, 10 instances, sharing on)
  density          — deployment-density conclusion
  governor_density — memory governor: tenants-per-GB vs p99 TTFT under a
                     shrinking budget (rung ladder vs warm/hibernate)
  forecast_density — predictive control plane: seasonal + flash-crowd
                     pre-inflate vs the reactive governor, p99 TTFT at
                     equal tenants-per-GB
  dedup_store      — content-addressed swap store: cross-tenant dedup,
                     zero-page elision, compression tiers
  wake_latency     — streamed wake pipeline: synchronous vs pipelined
                     time-to-first-token (p50/p99)
  swap_throughput  — §3.4 random-vs-sequential storage asymmetry
  sharing          — §3.5 runtime-binary (base-weight) sharing
  allocator        — §3.3 bitmap allocator vs free-list baseline
  concurrency      — AsyncPlatform: tenants x workers, wake storms,
                     vectored fault IO
  cluster_density  — cluster fabric: 4 nodes, skewed tenant pile,
                     migration-on vs migration-off tenants-per-GB
  prefix_density   — prefix registry: resident-KV dedup across tenants
                     and nodes, adopted vs prefilled TTFT, sharing on/off
  gateway_latency  — network front door: streaming TTFT per SLO class
                     and container state over loopback HTTP, overload 429s
  recovery         — failure domain: kill a node, re-home MTTR from
                     replicated segments, post-recovery wake p99
  zygote_cold_start— zygote pool: fork-admission vs cold-start TTFT for
                     brand-new tenants (dense/MoE/SSM), byte identity
  roofline         — brief: per-(arch x shape x mesh) roofline table

`python -m benchmarks.run [--quick] [--only NAME[,NAME...]]`
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    # default deliberately NOT bench_results.json: that file is the
    # committed CI bench-regression baseline (conservative floor) and must
    # only be updated intentionally
    ap.add_argument("--out", default="bench_out.json")
    args = ap.parse_args(argv)

    from benchmarks import (allocator, cluster_density, concurrency,
                            dedup_store, density, forecast_density,
                            gateway_latency, governor_density,
                            latency_states, memory_states, prefix_density,
                            reap_ablation, recovery, roofline, sharing,
                            swap_throughput, wake_latency,
                            zygote_cold_start)
    suites = [
        ("allocator", allocator),
        ("swap_throughput", swap_throughput),
        ("wake_latency", wake_latency),
        ("latency_states", latency_states),
        ("memory_states", memory_states),
        ("density", density),
        ("governor_density", governor_density),
        ("forecast_density", forecast_density),
        ("cluster_density", cluster_density),
        ("prefix_density", prefix_density),
        ("gateway_latency", gateway_latency),
        ("recovery", recovery),
        ("zygote_cold_start", zygote_cold_start),
        ("dedup_store", dedup_store),
        ("sharing", sharing),
        ("reap_ablation", reap_ablation),
        ("concurrency", concurrency),
        ("roofline", roofline),
    ]
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - {n for n, _ in suites}
        if unknown:
            ap.error(f"unknown suite(s): {sorted(unknown)}")
    results = {}
    all_checks = []
    for name, mod in suites:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.monotonic()
        tab, checks = mod.main(quick=args.quick)
        dt = time.monotonic() - t0
        print(f"({name}: {dt:.1f}s)")
        results[name] = {"table": tab.to_dict(),
                         "checks": [(c[0], bool(all(c[1:]))) for c in checks],
                         "seconds": dt}
        all_checks += [(name, c[0], bool(all(c[1:]))) for c in checks]

    print("\n===== claim checks =====")
    n_bad = 0
    for suite, claim, ok in all_checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {suite}: {claim}")
        n_bad += (not ok)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\n{len(all_checks) - n_bad}/{len(all_checks)} claim checks pass"
          f" -> {args.out}")
    return 0 if n_bad == 0 else 1


if __name__ == "__main__":
    sys.exit(main())

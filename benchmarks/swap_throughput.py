"""§3.4's storage asymmetry on THIS host: random 4 KB-unit reads vs one
batched sequential read, measured with the framework's own swap files.

The paper reports ~100 MB/s random vs >1 GB/s sequential on its SSD; the
absolute numbers differ per host — the *ratio* is what motivates REAP.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Table
from repro.core.swap import ReapFile, SwapFile

UNIT_KB = 4
N_UNITS = 8192              # 32 MB working set (serverless-sized, cf. §1)


#: best-of-N repeats: a shared host's disk scheduler adds 2-3x run-to-run
#: noise, which would make the CI bench-regression gate flap — the best
#: run approximates the storage ceiling the ratio argument is about
REPEATS = 5


def run(spool="/tmp/bench_swapio"):
    os.makedirs(spool, exist_ok=True)
    rng = np.random.default_rng(0)
    units = [((i,), rng.standard_normal(UNIT_KB * 1024 // 8))
             for i in range(N_UNITS)]
    total = sum(a.nbytes for _, a in units)
    best = {"write_units": None, "write_batch": None,
            "read_random": None, "read_batch": None}

    def note(key, dt):
        if best[key] is None or dt < best[key]:
            best[key] = dt

    for _ in range(REPEATS):
        swap = SwapFile(f"{spool}/pf.swap")
        t0 = time.monotonic()
        swap.write_units(units)
        note("write_units", time.monotonic() - t0)

        reap = ReapFile(f"{spool}/reap.swap")
        t0 = time.monotonic()
        reap.write_batch(units)
        note("write_batch", time.monotonic() - t0)

        # force real storage reads: flush dirty pages, then drop the clean
        # page-cache copies of both files (the paper measures SSD, not
        # cache)
        for f in (swap, reap):
            os.fsync(f.fd)
            os.posix_fadvise(f.fd, 0, 0, os.POSIX_FADV_DONTNEED)

        # random-order unit reads (page-fault swap-in)
        order = rng.permutation(N_UNITS)
        t0 = time.monotonic()
        for i in order:
            swap.read_unit((int(i),))
        note("read_random", time.monotonic() - t0)

        # one batched sequential read (REAP swap-in); re-evict first so
        # both paths start cold
        os.posix_fadvise(reap.fd, 0, 0, os.POSIX_FADV_DONTNEED)
        t0 = time.monotonic()
        reap.read_batch()
        note("read_batch", time.monotonic() - t0)

        swap.delete()
        reap.delete()
    return {"total_mb": total / 2**20,
            "write_units_mbs": total / best["write_units"] / 2**20,
            "write_batch_mbs": total / best["write_batch"] / 2**20,
            "read_random_mbs": total / best["read_random"] / 2**20,
            "read_batch_mbs": total / best["read_batch"] / 2**20}


def main(quick: bool = False):
    r = run()
    tab = Table(f"§3.4 swap IO ({r['total_mb']:.0f} MB, "
                f"{UNIT_KB} KB units)",
                ["path", "MB/s"])
    tab.add("write per-unit (pwrite xN)", f"{r['write_units_mbs']:.0f}")
    tab.add("write batch (pwritev)", f"{r['write_batch_mbs']:.0f}")
    tab.add("read random (page-fault)", f"{r['read_random_mbs']:.0f}")
    tab.add("read batch (REAP preadv)", f"{r['read_batch_mbs']:.0f}")
    ratio = r["read_batch_mbs"] / r["read_random_mbs"]
    tab.add("batch/random read ratio", f"{ratio:.1f}x")
    print(tab.render())
    return tab, [("seq>rand", ratio > 1.0)]


if __name__ == "__main__":
    main()

"""Zygote fork admission vs classic cold start: TTFT and byte identity.

A brand-new tenant normally pays the full cold init — factory
construction plus the per-instance prefill XLA compile — before its
first token.  The zygote pool moves that work off the serve path: a
pre-initialized donor of the tenant's model family already holds the
base weights (shared-registry ref) and pre-built prefill executables,
so admission becomes a warm fork (weights memcpy + inherited compiled
handles).  This suite measures **time-to-first-token** for the first
request of a brand-new tenant, fork-admitted vs cold-started, across a
dense, a MoE, and an SSM family — and asserts the first response is
byte-identical either way (a fork is an optimization, never a different
model).

One throwaway admission per family charges the factory's param cache
and JAX's one-time lazy init before either path is timed; each fork rep
spawns its donor *outside* the timed window (that is the design: spawn
cost is paid off-path, by the pre-fork daemon).
"""
from __future__ import annotations

import shutil
import time

from benchmarks.common import (SHARED_PATHS, Table, build_factory,
                               request_for, shared_loader_for)
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.metrics import percentile
from repro.core.zygote import ZygoteConfig
from repro.serving.engine import ServingEngine

FAMILIES = [
    ("dense", "llama3.2-3b"),
    ("moe", "arctic-480b"),
    ("ssm", "mamba2-130m"),
]
PROMPT_LEN = 8
NEW_TOKENS = 4


def _setup(spool: str):
    shutil.rmtree(spool, ignore_errors=True)
    factory = build_factory()
    cfg = ManagerConfig(spool_dir=spool,
                        zygote_pool=ZygoteConfig(per_family=1,
                                                 max_total=len(FAMILIES)))
    mgr = InstanceManager(cfg, factory,
                          shared_loader=shared_loader_for(factory))
    return ServingEngine(mgr), mgr


def _admit_and_ttft(eng, mcfg, iid, admit):
    """Admission + first request; returns (ttft_seconds, tokens)."""
    marks = []
    req = request_for(mcfg, iid, "s0", PROMPT_LEN, NEW_TOKENS,
                      on_token=lambda t: marks.append(time.perf_counter()))
    t0 = time.perf_counter()
    admit()
    resp = eng.handle(req)
    return marks[0] - t0, list(resp.tokens)


def main(quick: bool = False):
    reps = 2 if quick else 5
    tab = Table("Zygote fork admission vs cold start "
                f"({PROMPT_LEN}-token prompt, first-token latency)",
                ["family", "arch", "cold ttft p50 ms", "fork ttft p50 ms",
                 "ratio", "identical"])
    checks = []
    eng, mgr = _setup("/tmp/bench_zygote_cold_start")
    for label, arch in FAMILIES:
        # throwaway admission: charges the factory cache (first
        # init_params of the arch) so neither timed path pays it
        warm = eng.start_instance(f"warmup-{arch}", arch,
                                  shared_paths=SHARED_PATHS)
        mcfg = warm.cfg
        eng.handle(request_for(mcfg, f"warmup-{arch}", "s0",
                               PROMPT_LEN, NEW_TOKENS))
        mgr.evict(f"warmup-{arch}")
        cold_ttfts, fork_ttfts, identical = [], [], True
        for rep in range(reps):
            cid, fid = f"cold-{arch}-{rep}", f"fork-{arch}-{rep}"
            t, cold_toks = _admit_and_ttft(
                eng, mcfg, cid,
                lambda: eng.start_instance(cid, arch,
                                           shared_paths=SHARED_PATHS))
            cold_ttfts.append(t)
            mgr.evict(cid)
            # the donor spawns OFF the timed path (pre-fork daemon work)
            mgr.zygotes.spawn(arch, shared_paths=SHARED_PATHS)
            t, fork_toks = _admit_and_ttft(
                eng, mcfg, fid,
                lambda: eng.fork_instance(fid, arch,
                                          shared_paths=SHARED_PATHS))
            fork_ttfts.append(t)
            identical = identical and fork_toks == cold_toks
            mgr.evict(fid)
        cold_p50 = percentile(cold_ttfts, 50)
        fork_p50 = percentile(fork_ttfts, 50)
        tab.add(label, arch, f"{cold_p50 * 1e3:.1f}",
                f"{fork_p50 * 1e3:.1f}",
                f"{fork_p50 / cold_p50:.2f}x", str(identical))
        checks.append((f"{label}: fork ttft p50 <= 0.5x cold",
                       fork_p50 <= 0.5 * cold_p50))
        checks.append((f"{label}: first response byte-identical",
                       identical))
    stats = mgr.zygotes.stats()
    checks.append(("every fork consumed exactly one donor",
                   stats["forked"] == stats["spawned"]
                   and stats["live"] == 0))
    print(tab.render())
    return tab, checks


if __name__ == "__main__":
    main()

"""Content-addressed swap store: cross-tenant density win vs inflate cost.

The paper's Swapping Manager de-dup table is what pushes Hibernate
Container down to 7-25% of Warm memory; here we measure its disk-tier
analogue.  N tenants run the SAME model config (the common serverless
case: many customers of one base model).  The PR-1 baseline stores every
tenant's deflated units verbatim in private SwapFiles — disk scales
linearly with tenant count.  The SwapStore hashes units on deflate,
stores duplicate payloads once (refcounted), elides constant pages, and
compresses cold payloads.

Claims checked:
  * >=2x disk-byte reduction for 8 tenants sharing one model config
    (in practice ~Nx for identical weights);
  * wake p99 (full page-fault inflate through the store) within 1.5x of
    the private-file path — dedup must not forfeit PR-1's vectored IO.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import Table, fmt_mb, make_engine, request_for
from repro.core.metrics import percentile
from repro.core.state import Rung

ARCH = "llama3.2-3b"
N_TENANTS = 8
WAKE_CYCLES = 5


def run(dedup: bool, cycles: int, spool="/tmp/bench_dedup"):
    eng, mgr = make_engine(f"{spool}/{'cas' if dedup else 'flat'}",
                           "tiny", "pagefault", dedup=dedup)
    for t in range(N_TENANTS):
        iid = f"t{t}"
        inst = eng.start_instance(iid, ARCH)
        eng.handle(request_for(inst.cfg, iid, "s", 8, 4, close_session=True))
    # deflate everyone, measure the disk tier
    for t in range(N_TENANTS):
        mgr.descend(f"t{t}", Rung.HIBERNATED)
    if dedup:
        st = mgr.store.stats()
        disk = st["stored_bytes"]
        logical = st["logical_bytes"]
    else:
        disk = logical = sum(i.swap_file.file_bytes
                             for i in mgr.instances.values())
    disk += sum(i.reap_file.file_bytes for i in mgr.instances.values())

    # wake latency: full page-fault inflate (every unit through the swap
    # tier) per tenant per cycle — the dedup'd read path must stay
    # vectored.  One untimed warm-up cycle + fsync first: the claim is
    # about steady-state wake latency, not the writeback backlog of
    # whichever phase ran previously
    for t in range(N_TENANTS):
        inst = mgr.instances[f"t{t}"]
        mgr.hib.fault(inst, inst.nonresident_keys())
        mgr.hib.wake(inst, mode="pagefault", trigger="sigcont")
        mgr.descend(f"t{t}", Rung.HIBERNATED)
    for inst in mgr.instances.values():
        if getattr(inst.swap_file, "fd", None) is not None:
            os.fsync(inst.swap_file.fd)
    if dedup:
        os.fsync(mgr.store.fd)
    wakes = []
    for _ in range(cycles):
        for t in range(N_TENANTS):
            inst = mgr.instances[f"t{t}"]
            t0 = time.monotonic()
            mgr.hib.fault(inst, inst.nonresident_keys())
            wakes.append(time.monotonic() - t0)
            mgr.hib.wake(inst, mode="pagefault", trigger="sigcont")
            mgr.descend(f"t{t}", Rung.HIBERNATED)
    syscalls = (mgr.store.reads if dedup else
                sum(i.swap_file.reads for i in mgr.instances.values()))
    return {"disk": disk, "logical": logical,
            "wake_p50": percentile(wakes, 50),
            "wake_p99": percentile(wakes, 99),
            "read_syscalls": syscalls,
            "stats": mgr.store.stats() if dedup else {}}


def main(quick: bool = False):
    cycles = 2 if quick else WAKE_CYCLES
    flat = run(False, cycles)
    cas = run(True, cycles)
    red = flat["disk"] / max(cas["disk"], 1)
    p99x = cas["wake_p99"] / max(flat["wake_p99"], 1e-9)
    tab = Table(f"Content-addressed swap store ({N_TENANTS} tenants x "
                f"{ARCH}, {cycles} wake cycles)",
                ["metric", "private files (PR1)", "dedup store", "delta"])
    tab.add("disk bytes (MB)", fmt_mb(flat["disk"]), fmt_mb(cas["disk"]),
            f"{red:.1f}x smaller")
    tab.add("logical bytes (MB)", fmt_mb(flat["logical"]),
            fmt_mb(cas["logical"]), "-")
    tab.add("wake p50 (ms)", f"{flat['wake_p50']*1e3:.1f}",
            f"{cas['wake_p50']*1e3:.1f}",
            f"{cas['wake_p50']/max(flat['wake_p50'],1e-9):.2f}x")
    tab.add("wake p99 (ms)", f"{flat['wake_p99']*1e3:.1f}",
            f"{cas['wake_p99']*1e3:.1f}", f"{p99x:.2f}x")
    tab.add("read syscalls", flat["read_syscalls"], cas["read_syscalls"],
            "-")
    s = cas["stats"]
    tab.add("dedup hits / elisions / sinks",
            "-", f"{s['dedup_hits']} / {s['elisions']} / {s['sink_events']}",
            "-")
    print(tab.render())
    return tab, [("disk reduction >= 2x", red >= 2.0),
                 ("wake p99 within 1.5x", p99x <= 1.5)]


if __name__ == "__main__":
    main()

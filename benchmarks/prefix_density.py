"""Prefix density: resident KV bytes + adopted-vs-prefilled TTFT.

Deployments front every request with the same system prompt, so a node
serving N sessions of M tenants holds N*M copies of byte-identical KV
prefix pages — unless the prefix registry dedups them.  With sharing ON
the first prefill registers the prompt under its salted digest; every
later session (any tenant of the deployment, same arch) COW-adopts the
resident pages and emits its first token without a forward pass.  With
sharing OFF every session pays a full private prefill and its own pages.

Cross-node: one tenant hibernates and migrates to node 1 carrying
prefix records + CAS segments; node-1 tenants then adopt by reviving
the digest from the local store — still never re-running the prefill.

Scenario: 2 nodes, M tenants x N=8 sessions, one page-aligned system
prompt.  Rows sweep sharing on/off; "KV sessions/GB" is the gated
density metric (sessions per GB of resident KV).
"""
from __future__ import annotations

import os
import shutil
import time

import numpy as np

from benchmarks.common import Table, build_factory, fmt_mb
from repro.cluster import ClusterRouter, Node
from repro.core.manager import ManagerConfig
from repro.core.metrics import percentile
from repro.core.prefix import PREFIX_OWNER
from repro.core.state import Rung
from repro.serving.engine import Request

ARCH = "llama3.2-3b"
SALT = b"prefix-density-bench"
N_SESSIONS = 8                 # per tenant; the >=3x claim is at N=8
PREFIX_PAGES = 4               # system prompt spans exactly 4 KV pages


def _mk_cluster(spool: str, shared: bool):
    shutil.rmtree(spool, ignore_errors=True)
    factory = build_factory("tiny")
    nodes = [Node(f"n{i}", factory, spool_dir=spool, salt=SALT,
                  manager_cfg=ManagerConfig(
                      spool_dir=os.path.join(spool, f"n{i}"),
                      store_salt=SALT, wake_mode="reap",
                      prefix_sharing=shared))
             for i in range(2)]
    return ClusterRouter(nodes), nodes


def _resident_kv_bytes(nodes) -> int:
    """PSS over every mapper (tenants + the registry owner) sums each
    shared page exactly once."""
    total = 0
    for node in nodes:
        pool = node.manager.pool
        for owner in list(node.manager.instances) + [PREFIX_OWNER]:
            total += int(pool.pss_bytes(owner))
    return total


def _start(router, node, iid):
    router.placement[iid] = node.node_id
    router.arch_of[iid] = ARCH
    return node.engine.start_instance(iid, ARCH)


def _run(shared: bool, tenants_per_node: int):
    router, nodes = _mk_cluster(
        f"/tmp/bench_prefix/{'on' if shared else 'off'}", shared)
    n0, n1 = nodes
    mid = f"t{tenants_per_node}"            # the tenant that migrates
    iids = [f"t{i}" for i in range(2 * tenants_per_node)]

    inst0 = _start(router, n0, iids[0])
    cfg = inst0.cfg
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size,
                          PREFIX_PAGES * inst0.kv.page_tokens) \
        .astype(np.int32)

    # jit warm-up on an unrelated prompt of the same shape; close + trim
    # so its pages (and, sharing-on, its spilled registry entry) don't
    # count toward the resident measurement
    warm = rng.integers(0, cfg.vocab_size, prompt.size).astype(np.int32)
    n0.engine.handle(Request(iids[0], "warm", warm, max_new_tokens=1))
    n0.engine.handle(Request(iids[0], "warm", [3], max_new_tokens=3,
                             close_session=True))
    inst0.kv.trim()

    prefill_ts, adopt_ts, xnode_ts = [], [], []
    transcript = []

    def open_sessions(node, iid, bucket=None):
        for s in range(N_SESSIONS):
            sid = f"{iid}_s{s}"
            t0 = time.monotonic()
            r = node.engine.handle(Request(iid, sid, prompt,
                                           max_new_tokens=1))
            dt = time.monotonic() - t0
            if bucket is not None:
                bucket.append(dt)
            elif r.adopted_prefix:
                adopt_ts.append(dt)
            else:
                prefill_ts.append(dt)
            c = node.engine.handle(Request(iid, sid, [5 + s],
                                           max_new_tokens=3))
            transcript.append((r.tokens, c.tokens))

    # node 0: tenants_per_node residents + the tenant that will migrate
    for iid in iids[:tenants_per_node + 1]:
        if iid != iids[0]:
            _start(router, n0, iid)
        open_sessions(n0, iid)

    # hibernate + migrate mid -> n1: prefix records + segments ship
    n0.engine.record_sample(mid, Request(mid, "probe", [9],
                                         max_new_tokens=1,
                                         close_session=True))
    n0.manager.descend(mid, Rung.HIBERNATED)
    h = router.migrate(mid, "n1")
    assert h.ok, h.error

    # node 1: fresh tenants of the same deployment.  Every session here
    # is a cross-node adoption of the migrated prefix — the first one
    # revives it by digest from the CAS segments the migration shipped,
    # the rest map the revived resident copy; the bucket's p50 is the
    # gated metric (a single revive sample is too noisy on shared
    # runners).  Warm each tenant on an unrelated prompt first so the
    # timed requests isolate adopt-vs-prefill rather than first-request
    # instance costs (both configs pay those identically).
    for iid in iids[tenants_per_node + 1:]:
        inst = _start(router, n1, iid)
        # distinct warm prompt per tenant: a shared one would itself be
        # registered and adopted, polluting the adoption accounting
        w = rng.integers(0, cfg.vocab_size, prompt.size).astype(np.int32)
        n1.engine.handle(Request(iid, "warm", w, max_new_tokens=1))
        n1.engine.handle(Request(iid, "warm", [3], max_new_tokens=3,
                                 close_session=True))
        inst.kv.trim()
        open_sessions(n1, iid, bucket=xnode_ts)

    # the migrated tenant's sessions survive the move: decode each
    for s in range(N_SESSIONS):
        c = n1.engine.handle(Request(mid, f"{mid}_s{s}", [7 + s],
                                     max_new_tokens=3))
        transcript.append(tuple(c.tokens))

    # make the migrated tenant fully resident so both configs measure
    # the same all-awake steady state
    inst = n1.manager.instances[mid]
    missing = inst.kv.nonresident_logical_keys()
    if missing:
        with inst.install_lock:
            inst.kv.fault_in(missing, inst.swap_file, inst.reap_file)

    resident = _resident_kv_bytes(nodes)
    adoptions = sum(
        (n.manager.prefix_registry.stats()["adoptions"]
         if n.manager.prefix_registry is not None else 0)
        for n in nodes)
    router.close()
    return {"resident": resident, "prefill_ts": prefill_ts,
            "adopt_ts": adopt_ts, "xnode_ts": xnode_ts,
            "adoptions": adoptions, "transcript": transcript,
            "sessions": len(iids) * N_SESSIONS}


def main(quick: bool = False):
    tpn = 2 if quick else 4
    on = _run(True, tpn)
    off = _run(False, tpn)

    def _ms(ts, p=50):
        return f"{percentile(ts, p) * 1e3:.2f}" if ts else "-"

    n_sessions = on["sessions"]
    tab = Table(
        f"Prefix density: {2 * tpn} tenants x {N_SESSIONS} sessions / "
        f"2 nodes ({ARCH}), one {PREFIX_PAGES}-page system prompt",
        ["config", "sessions", "resident KV MB", "KV sessions/GB",
         "adoptions", "prefill p50 ms", "adopt p50 ms", "x-node adopt ms"])
    for name, r in (("prefix-on", on), ("prefix-off", off)):
        tab.add(name, r["sessions"], fmt_mb(r["resident"]),
                f"{r['sessions'] / (r['resident'] / 2**30):.0f}",
                r["adoptions"], _ms(r["prefill_ts"]), _ms(r["adopt_ts"]),
                _ms(r["xnode_ts"]))
    print(tab.render())

    reduction = off["resident"] / max(on["resident"], 1)
    xnode = percentile(on["xnode_ts"], 50) if on["xnode_ts"] else 1e9
    prefill_p50 = percentile(off["prefill_ts"], 50)
    print(f"resident KV reduction: {reduction:.2f}x; cross-node adopt "
          f"{xnode * 1e3:.2f} ms vs full prefill "
          f"{prefill_p50 * 1e3:.2f} ms")

    checks = [
        (f">=3x resident KV reduction at N={N_SESSIONS} sessions "
         "sharing one prompt", reduction >= 3.0),
        ("every session after the first adopts (incl. cross-node)",
         on["adoptions"] == n_sessions - 1),
        ("cross-node adopted TTFT <=0.5x full prefill",
         xnode <= 0.5 * prefill_p50),
        ("adopted decode byte-identical to private prefill",
         on["transcript"] == off["transcript"]),
    ]
    return tab, checks


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run JSONL artifacts (launch/dryrun.py).

Reads dryrun_single.jsonl / dryrun_multi.jsonl if present and renders the
per-(arch x shape x mesh) three-term roofline with bottleneck + useful-
FLOPs fraction.  Run `python -m repro.launch.dryrun --all --out ...` to
regenerate (it needs the 512-placeholder-device env and so cannot run
inside this process).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Table

FILES = [("single", "dryrun_single.jsonl"), ("multi", "dryrun_multi.jsonl"),
         ("single-opt", "dryrun_single_opt.jsonl"),
         ("multi-opt", "dryrun_multi_opt.jsonl")]


def load_rows(root="."):
    rows = []
    for tag, fn in FILES:
        path = os.path.join(root, fn)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                r["mesh"] = tag
                rows.append(r)
    return rows


def main(quick: bool = False):
    rows = load_rows()
    tab = Table("Roofline per (arch x shape x mesh)",
                ["arch", "shape", "mesh", "GiB/dev", "compute_ms",
                 "memory_ms", "coll_ms", "bottleneck", "useful"])
    checks = []
    n_ok = 0
    for r in rows:
        if r.get("status") == "skipped":
            tab.add(r["arch"], r["shape"], r["mesh"], "-", "-", "-", "-",
                    "skipped by design", "-")
            continue
        if r.get("status") != "ok":
            tab.add(r["arch"], r["shape"], r["mesh"], "-", "-", "-", "-",
                    "ERROR", "-")
            checks.append((f"{r['arch']}x{r['shape']}x{r['mesh']}", False))
            continue
        n_ok += 1
        gb = (r.get("bytes_per_device") or 0) / 2**30
        tab.add(r["arch"], r["shape"], r["mesh"], f"{gb:.2f}",
                f"{r['compute_s'] * 1e3:.2f}",
                f"{r['memory_s'] * 1e3:.2f}",
                f"{r['collective_s'] * 1e3:.2f}",
                r["bottleneck"], f"{r['useful_flops_frac']:.2f}")
    print(tab.render())
    if not rows:
        print("  (no dryrun_*.jsonl found — run repro.launch.dryrun --all)")
    checks.append(("dryrun cases ok", n_ok >= 39 * 2 or (n_ok and not rows)))
    return tab, checks


if __name__ == "__main__":
    main()

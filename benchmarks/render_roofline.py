"""Render EXPERIMENTS.md-style roofline tables from dry-run JSONL files.

  PYTHONPATH=src python -m benchmarks.render_roofline dryrun_single.jsonl
  PYTHONPATH=src python -m benchmarks.render_roofline --compare \
      dryrun_single.jsonl dryrun_single_opt.jsonl

``--compare`` prints per-pair dominant-term ratios (baseline/optimized) —
the §Perf summary table is generated this way.
"""
from __future__ import annotations

import argparse
import json
import sys


def load(fn):
    out = {}
    with open(fn) as f:
        for line in f:
            r = json.loads(line)
            out[(r["arch"], r["shape"])] = r
    return out


def dominant(r):
    return max(r.get("compute_s", 0), r.get("memory_s", 0),
               r.get("collective_s", 0))


def render(fn):
    rows = load(fn)
    print(f"\n### {fn}\n")
    print("| arch | shape | GiB/dev | compute_s | memory_s | coll_s "
          "| bottleneck | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s), r in rows.items():
        if r["status"] != "ok":
            print(f"| {a} | {s} | — | — | — | — | {r['status']} | — |")
            continue
        gb = (r.get("bytes_per_device") or 0) / 2**30
        print(f"| {a} | {s} | {gb:.2f} | {r['compute_s']:.4f} "
              f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
              f"| {r['bottleneck']} | {r['useful_flops_frac']:.2f} |")


def compare(base_fn, opt_fn):
    base, opt = load(base_fn), load(opt_fn)
    print(f"\n### dominant-term ratio: {base_fn} -> {opt_fn}\n")
    print("| arch | shape | baseline dom. | optimized dom. | gain |")
    print("|---|---|---|---|---|")
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or o is None or o["status"] != "ok":
            continue
        db, do = dominant(b), dominant(o)
        if do <= 0:
            continue
        print(f"| {key[0]} | {key[1]} | {db:.3f} s ({b['bottleneck']}) "
              f"| {do:.3f} s ({o['bottleneck']}) | {db / do:.2f}x |")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args(argv)
    if args.compare:
        if len(args.files) != 2:
            ap.error("--compare needs exactly 2 files")
        compare(*args.files)
    else:
        for fn in args.files:
            render(fn)
    return 0


if __name__ == "__main__":
    sys.exit(main())

from repro.configs.base import (ARCH_IDS, FrontendConfig, MLAConfig,
                                MoEConfig, ModelConfig, SSMConfig,
                                get_config, list_archs, scaled_config,
                                tiny_config)
from repro.configs.shapes import SHAPES, InputShape, get_shape

__all__ = ["ARCH_IDS", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "FrontendConfig", "get_config", "list_archs", "tiny_config",
           "scaled_config", "SHAPES", "InputShape", "get_shape"]

"""chatglm3-6b — dense decoder, 2d (partial) RoPE, GQA kv=2. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="chatglm3-6b", family="dense",
        citation="arXiv:2406.12793",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024,
        attention="gqa", activation="swiglu", norm="rmsnorm",
        rope_mode="2d", rope_theta=10_000.0,
        long_context_mode="sliding_window",
        tp=2, sp=8,
    )

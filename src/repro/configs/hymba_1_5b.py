"""hymba-1.5b — hybrid: parallel attention + Mamba heads per block.

[arXiv:2411.13676] — 25 q-heads / 5 kv-heads (head_dim 64) in parallel with
SSD heads (ssm_state=16). 25 heads share no factor with 16, so tp=1 and the
entire model axis is sequence/state parallel (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, SSMConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b", family="hybrid",
        citation="arXiv:2411.13676",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        attention="gqa", hybrid_parallel_ssm=True,
        # chunk_size=128: the SSD dual form materialises O(Q^2 H) decay
        # tensors; 128 halves prefill HBM traffic vs 256 with identical
        # math (EXPERIMENTS.md §Perf bonus P4: 73.5 -> 42.6 s, exact)
        ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk_size=128),
        activation="swiglu", norm="rmsnorm", rope_theta=10_000.0,
        sliding_window=1024,            # hymba uses SWA on most layers
        long_context_mode="native",     # hybrid: SSM carries global context
        tp=1, sp=16,
    )

"""whisper-large-v3 — encoder-decoder; conv/mel frontend is a STUB.

[arXiv:2212.04356] — 32 encoder + 32 decoder layers, d_model=1280, 20 MHA
heads, d_ff=5120, vocab=51866, GELU + LayerNorm, learned positions (no RoPE).
input_specs supplies precomputed 1500-frame encoder embeddings.  decode_32k
extends the decoder position table beyond the native 448 (deviation noted in
DESIGN.md §5); long_500k is skipped for this family.
"""
from repro.configs.base import FrontendConfig, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-large-v3", family="audio",
        citation="arXiv:2212.04356",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        attention="gqa", activation="gelu", norm="layernorm",
        rope_mode="none",
        is_encoder_decoder=True, encoder_layers=32, encoder_max_len=1500,
        max_position=40_000,     # learned positions; covers decode_32k (+pad)
        frontend=FrontendConfig(kind="audio", num_embeddings=1500,
                                embed_dim=1280),
        long_context_mode="skip",
        tp=4, sp=4,
    )

"""yi-6b — llama-architecture dense decoder with GQA kv=4. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="yi-6b", family="dense",
        citation="arXiv:2403.04652",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        attention="gqa", activation="swiglu", norm="rmsnorm",
        rope_theta=5_000_000.0,
        long_context_mode="sliding_window",
        tp=4, sp=4,
    )

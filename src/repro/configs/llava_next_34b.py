"""llava-next-34b — VLM backbone (Yi-34B-style decoder) with anyres tiling.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] (anyres tiling scheme; 34B variant
backbone dims per assignment: 60L, d_model=7168, 56 heads, GQA kv=8,
d_ff=20480, vocab=64000). Vision frontend (CLIP ViT-L/14-336 + projector)
is a STUB per the brief: input_specs supplies patch embeddings.
"""
from repro.configs.base import FrontendConfig, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llava-next-34b", family="vlm",
        citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20480, vocab_size=64000,
        attention="gqa", activation="swiglu", norm="rmsnorm",
        rope_theta=5_000_000.0,
        # anyres: up to 4 tiles + base image, 576 patches each, CLIP-L dim 1024
        frontend=FrontendConfig(kind="vision", num_embeddings=2880,
                                embed_dim=1024),
        long_context_mode="sliding_window",
        tp=8, sp=2,
    )

"""Configuration system for the repro framework.

A single :class:`ModelConfig` dataclass covers every assigned architecture
family (dense / MoE / MLA / SSM / hybrid / enc-dec / VLM / audio).  Each
architecture lives in its own ``configs/<arch>.py`` module exposing
``make_config() -> ModelConfig`` with the exact assigned hyper-parameters and
a source citation.  ``get_config(arch_id)`` resolves through the registry and
``tiny_config(cfg)`` derives the reduced smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) mandated for CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0          # DeepSeek-style always-on experts
    expert_d_ff: int = 0                 # per-expert FFN hidden size
    dense_residual: bool = False         # Arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01        # load-balance loss


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention configuration."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD (state-space duality) configuration."""

    state_dim: int = 128                 # N: per-head state size
    head_dim: int = 64                   # P: channels per SSD head
    expand: int = 2                      # d_inner = expand * d_model
    chunk_size: int = 256                # SSD chunk length
    conv_width: int = 4                  # depthwise conv kernel


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB (vision patches / audio frames).

    Per the brief, only the transformer backbone is implemented; the frontend
    supplies precomputed embeddings of the right shape via ``input_specs``.
    """

    kind: str = "none"                   # "vision" | "audio" | "none"
    num_embeddings: int = 0              # patches per image / encoder frames
    embed_dim: int = 0                   # pre-projection embedding dim


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio
    citation: str

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention flavour
    attention: str = "gqa"               # gqa | mla | none
    rope_theta: float = 10_000.0
    rope_mode: str = "full"              # full | 2d (chatglm partial rotary) | none
    max_position: int = 1 << 20

    # long-context handling: "full" archs skip long_500k unless a
    # sliding-window variant is enabled (DESIGN.md §5).
    long_context_mode: str = "sliding_window"   # sliding_window | native | skip
    sliding_window: int = 4096

    activation: str = "swiglu"           # swiglu | gelu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)

    # enc-dec (whisper): decoder uses the top-level fields; encoder below.
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_max_len: int = 0

    # hybrid (hymba): parallel attention + SSD heads inside one block
    hybrid_parallel_ssm: bool = False

    # distribution: refinement of the production "model" axis (DESIGN.md §4)
    tp: int = 1                          # tensor-parallel degree (divides heads)
    sp: int = 1                          # sequence/context-parallel degree

    # serving
    kv_page_size: int = 16               # tokens per KV page

    def __post_init__(self):
        if self.attention == "gqa" and self.num_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived quantities -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so it shards over the mesh
        (e.g. hymba's 32001).  Logical vocab stays ``vocab_size``."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def kv_token_bytes(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token (per layer): what one pool page stores."""
        if self.attention == "mla":
            per = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
        elif self.attention == "none":
            per = 0
        else:
            per = 2 * self.num_kv_heads * self.head_dim
        return per * bytes_per_el

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.attention == "mla":
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        elif self.attention == "none":
            attn = 0
        else:
            attn = (d * self.num_heads * self.head_dim
                    + 2 * d * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * d)
        n_mats = 3 if self.activation == "swiglu" else 2
        mlp = n_mats * d * f if f else 0
        if self.moe:
            mo = self.moe
            expert = n_mats * d * mo.expert_d_ff
            mlp = (mo.num_experts + mo.num_shared_experts) * expert
            mlp += d * mo.num_experts                       # router
            if mo.dense_residual:
                mlp += n_mats * d * self.d_ff
        ssm = 0
        if self.ssm:
            di, s = self.d_inner, self.ssm
            ssm = (d * (2 * di + 2 * self.ssm_heads * s.state_dim + self.ssm_heads)
                   + di * d + s.conv_width * di)
        per_layer = attn + mlp + ssm
        enc = 0
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (attn + n_mats * d * f) + per_layer * 0
            per_layer += attn + self.num_heads * self.head_dim * d  # cross-attn
        return emb + L * per_layer + enc

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed experts only)."""
        if not self.moe:
            return self.param_count()
        mo = self.moe
        n_mats = 3 if self.activation == "swiglu" else 2
        expert = n_mats * self.d_model * mo.expert_d_ff
        inactive = (mo.num_experts - mo.top_k) * expert
        return self.param_count() - self.num_layers * inactive


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "llava-next-34b",
    "phi4-mini-3.8b",
    "deepseek-v2-236b",
    "yi-6b",
    "chatglm3-6b",
    "llama3.2-3b",
    "arctic-480b",
    "hymba-1.5b",
    "mamba2-130m",
    "whisper-large-v3",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    cfg = mod.make_config()
    assert cfg.arch_id == arch_id, (cfg.arch_id, arch_id)
    return cfg


def list_archs() -> tuple:
    return ARCH_IDS


def tiny_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = (heads // kv) * kv or kv
    updates = dict(
        num_layers=2, d_model=d, num_heads=heads, num_kv_heads=kv,
        head_dim=d // max(heads, 1),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_position=2_048, sliding_window=64, kv_page_size=8,
        tp=1, sp=1, dtype="float32",
    )
    if cfg.moe:
        # capacity_factor 4.0: no token dropping at smoke-test batch sizes,
        # so decode-vs-full consistency is exact (GShard dropping makes
        # outputs depend on co-batched tokens otherwise)
        updates["moe"] = replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            expert_d_ff=128, capacity_factor=4.0)
    if cfg.mla:
        updates["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=64,
                                   qk_nope_head_dim=32, qk_rope_head_dim=16,
                                   v_head_dim=32)
        updates["head_dim"] = 0
    if cfg.ssm:
        updates["ssm"] = replace(cfg.ssm, state_dim=16, head_dim=32,
                                 chunk_size=32)
    if cfg.frontend.kind != "none":
        updates["frontend"] = replace(cfg.frontend, num_embeddings=8,
                                      embed_dim=64)
    if cfg.is_encoder_decoder:
        updates["encoder_layers"] = 2
        updates["encoder_max_len"] = 64
    return replace(cfg, **updates)


def scaled_config(cfg: ModelConfig, d_model: int = 512, layers: int = 4) -> ModelConfig:
    """Mid-size variant for benchmarks (bigger than tiny, CPU-runnable)."""
    t = tiny_config(cfg)
    heads = max(4, min(cfg.num_heads, 8))
    kv = max(1, min(cfg.num_kv_heads, heads))
    heads = (heads // kv) * kv or kv
    return replace(t, num_layers=layers, d_model=d_model, num_heads=heads,
                   num_kv_heads=kv, head_dim=d_model // heads,
                   d_ff=2 * d_model, vocab_size=min(cfg.vocab_size, 2048))

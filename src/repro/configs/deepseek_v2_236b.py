"""deepseek-v2-236b — MoE with Multi-head Latent Attention. [arXiv:2405.04434]

MLA kv_lora_rank=512; 2 shared + 160 routed experts, top-6; per-expert
d_ff=1536 (the assigned d_ff is the expert hidden size). 128 q-heads share
the compressed KV latent, so TP=16 is head-divisible.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v2-236b", family="moe",
        citation="arXiv:2405.04434",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=1536, vocab_size=102400,
        attention="mla",
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                      expert_d_ff=1536, capacity_factor=1.25),
        activation="swiglu", norm="rmsnorm", rope_theta=10_000.0,
        long_context_mode="sliding_window",
        # tp=8 (128 q-heads / 8), sp=2: the 32k latent cache (60L x 32k x 576)
        # is 2.26 GB/sequence — sequence-sharding over sp=2 keeps decode_32k
        # under the 16 GB v5e HBM budget (see EXPERIMENTS.md §Dry-run).
        tp=8, sp=2,
    )

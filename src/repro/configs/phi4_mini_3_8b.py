"""phi4-mini-3.8b — dense decoder, RoPE + SwiGLU + GQA. [arXiv:2412.08905]"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi4-mini-3.8b", family="dense",
        citation="arXiv:2412.08905",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=200064,
        attention="gqa", activation="swiglu", norm="rmsnorm",
        rope_theta=10_000.0, tie_embeddings=True,
        long_context_mode="sliding_window",
        tp=8, sp=2,
    )

"""mamba2-130m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-130m", family="ssm",
        citation="arXiv:2405.21060",
        num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        attention="none", rope_mode="none",
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
        activation="swiglu", norm="rmsnorm", tie_embeddings=True,
        long_context_mode="native",
        # 130M params replicate trivially; the in_proj output mixes z|x|B|C|dt
        # semantics so d_inner tensor-parallelism would cut across semantic
        # split points (2*768 + 2*768 + 256 + 24 = 3352 is not 16-divisible).
        # The model axis instead joins batch parallelism where the batch
        # allows (launch/sharding.py) — the right call at this model size.
        tp=1, sp=16,
    )

"""arctic-480b — 128-expert top-2 MoE + parallel dense residual MLP.

[hf:Snowflake/snowflake-arctic-base] — dense-MoE hybrid: every block has a
dense FFN residual in parallel with the routed MoE (d_ff=4864 for both).
"""
from repro.configs.base import MoEConfig, ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b", family="moe",
        citation="hf:Snowflake/snowflake-arctic-base",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        attention="gqa",
        moe=MoEConfig(num_experts=128, top_k=2, num_shared_experts=0,
                      expert_d_ff=4864, dense_residual=True,
                      capacity_factor=1.25),
        activation="swiglu", norm="rmsnorm", rope_theta=10_000.0,
        long_context_mode="sliding_window",
        tp=8, sp=2,
    )

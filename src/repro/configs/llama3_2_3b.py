"""llama3.2-3b — small llama3 dense decoder. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3.2-3b", family="dense",
        citation="hf:meta-llama/Llama-3.2-1B",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256,
        attention="gqa", activation="swiglu", norm="rmsnorm",
        rope_theta=500_000.0, tie_embeddings=True,
        long_context_mode="sliding_window",
        tp=8, sp=2,
    )

"""Snapshot migration over the content-addressed store.

A migratable tenant (MMAP_CLEAN / PARTIAL / HIBERNATED) is, or can
cheaply become, a pile of disk state: a private REAP file holding the
working set in first-touch order, plus per-unit digests into the
deployment's refcounted CAS segment file.  Migration therefore ships
*metadata plus missing digests*, never a full snapshot:

  1. **Fence** — under the source engine's serve lock the instance fires
     ``MIGRATE`` and lands in the MIGRATING state: the governor can no
     longer deflate or TERMINATE it (the transitions are illegal), and
     requests/wakes block on the :class:`MigrationHandle` exactly like
     late arrivals block on a shared wake pipeline.
  2. **Flush** — a not-yet-hibernated source runs the normal full-deflate
     body (same code path, different state-machine event), then the REAP
     file's units are hashed into the source store so *everything* the
     tenant owns is content-addressed.  The REAP file itself is not
     shipped — only its key order is, so the target can rebuild it with
     identical streaming layout.
  3. **Ship** — the :class:`StorePeer` asks the target store which
     digests it lacks and transfers only those, at their stored
     compression level.  Base weights a same-deployment tenant already
     parked on the target cost zero bytes; per-session KV deltas are the
     usual payload.
  4. **Rebuild** — the target constructs a hibernated husk: factory
     shapes, adopted extent table (refcounts taken), REAP file rewritten
     from the local store in first-touch order, recorder + arrival-EWMA
     state installed, KV session page tables recreated Not-Present.
     The first wake on the target is byte-identical to an in-place wake.
  5. **Commit** — the source fires ``MIGRATE_DONE``, releases its store
     refs (segment GC: bytes another local tenant still references
     survive), deletes its REAP file, and records the forwarding address
     so stragglers raise ``TenantMigrated`` and get rerouted.

On any transfer/rebuild error the source fires ``MIGRATE_ABORT`` back to
HIBERNATE — its disk state was never touched, so it keeps serving
locally.  The channel is in-process (two stores on one host); a real
network transport behind the same ``StorePeer`` interface is an open
item (see ROADMAP).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.governor import MIGRATABLE_STATES
from repro.core.instance import ModelInstance
from repro.core.state import ContainerState, Event
from repro.serving.paged_kv import KVSession, PagedKVCache

S = ContainerState

#: rough per-key wire cost of the metadata half of a migration (extent
#: records, recorder entries, KV page-table slots) — accounting only
_META_BYTES_PER_KEY = 64


class MigrationError(RuntimeError):
    pass


@dataclass
class TransferStats:
    """What one migration actually moved."""
    digests_total: int = 0
    digests_shipped: int = 0          # absent on the target: crossed the link
    bytes_shipped: int = 0            # stored (compressed) payload bytes sent
    bytes_dedup: int = 0              # stored bytes the target already held
    meta_bytes: int = 0               # extent/recorder/page-table metadata
    full_snapshot_bytes: int = 0      # naive verbatim snapshot (raw units)
    link_seconds: float = 0.0         # bytes over the modelled link bw
    seconds: float = 0.0              # wall time of the whole migration

    @property
    def wire_bytes(self) -> int:
        return self.bytes_shipped + self.meta_bytes


class StorePeer:
    """Transfer channel between two nodes' CAS stores.

    Both stores must share the deployment salt — the digest *is* the
    cluster-wide content address, so an unsalted-compatible peer would be
    a different deployment and shipping to it is refused."""

    def __init__(self, src_store, dst_store,
                 link_bw_bytes_s: float = 4 << 30):
        if src_store is None or dst_store is None:
            raise MigrationError("migration requires the dedup store on "
                                 "both nodes (ManagerConfig.dedup_store)")
        if src_store.salt != dst_store.salt:
            raise MigrationError("peer stores use different deployment "
                                 "salts: digests are not comparable")
        self.src = src_store
        self.dst = dst_store
        self.link_bw_bytes_s = link_bw_bytes_s

    def missing(self, digests) -> List[bytes]:
        return self.dst.missing_digests(digests)

    def ship(self, digests, stats: TransferStats) -> None:
        """Move the given digests' segments src -> dst, dedup-aware:
        only segments absent on the target cross the link."""
        digests = list(digests)
        stats.digests_total += len(digests)
        missing = self.missing(digests)
        stats.digests_shipped += len(missing)
        stats.bytes_dedup += self.src.stored_bytes_of(
            [d for d in digests if d not in set(missing)])
        if missing:
            wire = self.src.export_segments(missing)
            stats.bytes_shipped += sum(len(p) for _, _, _, p in wire)
            self.dst.import_segments(wire)
        stats.link_seconds += (stats.bytes_shipped
                               / max(self.link_bw_bytes_s, 1.0))


class MigrationHandle:
    """Shared handle for one in-flight migration — ``inst.migration``.

    Requests and wakes landing on the MIGRATING tenant :meth:`wait` on
    it (the in-flight-request handoff), mirroring how late wake arrivals
    share the wake pipeline handle."""

    def __init__(self, instance_id: str, source: str, target: str):
        self.instance_id = instance_id
        self.source_node_id = source
        self.target_node_id = target
        self.stats = TransferStats()
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ok(self) -> bool:
        return self._done.is_set() and self.error is None

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def _finish(self, error: Optional[BaseException] = None) -> None:
        self.error = error
        self._done.set()


@dataclass
class _Bundle:
    """The metadata half of a migration (in-process wire format)."""
    instance_id: str
    arch_key: str
    base_id: Optional[str]
    shared_paths: frozenset
    extents: Dict                      # key -> UnitMeta (digests)
    reap_order: List                   # REAP file keys, first-touch order
    stable: List                       # recorder stable set, ordered
    misses: Dict                       # recorder coldness counters (pruned)
    kv_sessions: List[Dict]
    last_used: float
    created_at: float
    #: the kept-alive compiled executables ride along — in this
    #: in-process simulation they transfer by reference, standing in for
    #: a node-shared persistent compilation cache; without them the
    #: migrant's first request would pay a cold-start-sized re-JIT,
    #: which is exactly the cost hibernation exists to avoid
    compiled: Dict = field(default_factory=dict)
    arrival: Optional[Tuple] = None    # governor EWMA (last_ts, gap)
    wire_keys: int = 0

    def meta_bytes(self) -> int:
        return self.wire_keys * _META_BYTES_PER_KEY


def _export_bundle(src_node, inst: ModelInstance,
                   arch_key: str) -> _Bundle:
    """Steps 1½–2: flush the REAP file into the CAS store and snapshot
    every piece of metadata the target needs.  Runs with the instance
    fenced in MIGRATING."""
    # the coldness counters ship with the tenant; prune dead keys (closed
    # sessions' KV pages) FIRST or they would leak onto the target forever
    live = set(inst.units) | set(inst.reap_file.extents) \
        | set(inst.swap_file.extents)
    inst.recorder.prune_misses(live)

    reap_order = list(inst.reap_file.extents)
    # the full-deflate body already content-addressed the working set
    # (write-through); this is only the safety net for keys that missed
    # it, so an already-inventoried tenant pays zero re-hashing here
    missing_ws = [k for k in reap_order if k not in inst.swap_file]
    if missing_ws:
        data = inst.reap_file.read_batch()
        inst.swap_file.write_units([(k, data[k]) for k in missing_ws])

    kv_sessions: List[Dict] = []
    if inst.kv is not None:
        for sid, s in inst.kv.sessions.items():
            kv_sessions.append({
                "session_id": sid,
                "num_tokens": s.num_tokens,
                "token_ids": list(s.token_ids),
                "closed": s.closed,
                "last_page_fill": s.last_page_fill,
                "page_counts": [len(layer) for layer in s.pages],
                "host_shapes": dict(s.host_shapes),
                "host_keys": list(s.host_units),
            })

    store = src_node.manager.store
    extents = store.export_meta(inst.swap_file)
    gov = src_node.manager.governor
    bundle = _Bundle(
        instance_id=inst.instance_id,
        arch_key=arch_key,
        base_id=inst.base_id,
        shared_paths=frozenset(inst.shared_paths),
        extents=extents,
        reap_order=reap_order,
        stable=list(inst.recorder.stable),
        misses=dict(inst.recorder.misses),
        kv_sessions=kv_sessions,
        last_used=inst.last_used,
        created_at=inst.created_at,
        compiled=dict(inst.compiled),
        arrival=gov.arrivals.get(inst.instance_id),
    )
    bundle.wire_keys = (len(extents) + len(bundle.stable)
                        + len(bundle.misses)
                        + sum(sum(sd["page_counts"]) + len(sd["host_keys"])
                              for sd in kv_sessions))
    return bundle


def _rebuild_on_target(dst_node, bundle: _Bundle) -> ModelInstance:
    """Step 4: construct the hibernated husk on the target node."""
    mgr = dst_node.manager
    model_cfg, params = dst_node.factory(bundle.arch_key)
    shared_on = mgr.shared is not None and bundle.base_id is not None
    inst = ModelInstance(
        bundle.instance_id, model_cfg, params, pool=mgr.pool,
        spool_dir=mgr.cfg.spool_dir,
        shared_paths=bundle.shared_paths if shared_on else None,
        base_id=bundle.base_id if shared_on else None,
        store=mgr.store,
        metadata_bytes=mgr.cfg.husk_metadata_bytes)
    try:
        return _populate_target(mgr, inst, bundle)
    except BaseException:
        # abort mid-rebuild (store read error, disk full): the adopted
        # segment refs and half-built spool files must not leak on the
        # target — terminate releases the client (refcount GC) and
        # deletes the files
        inst.terminate()
        raise


def _populate_target(mgr, inst: ModelInstance,
                     bundle: _Bundle) -> ModelInstance:
    # adopt the shipped extent table (takes segment refs) BEFORE touching
    # the instance's own client — ModelInstance.__init__ created it empty
    mgr.store.adopt_extents(bundle.instance_id, bundle.extents)

    # the factory params are placeholder shapes: drop them so every unit
    # is Not-Present and the first wake restores the *migrated* bytes
    inst.sm.fire(Event.COLD_START)
    inst.sm.fire(Event.SIGSTOP)
    inst.drop_weights()
    inst.inflated = False
    inst.mmap_dropped = True          # wake re-maps via the registry

    inst.recorder.stable = {k: None for k in bundle.stable}
    inst.recorder.misses = dict(bundle.misses)
    inst.compiled.update(bundle.compiled)
    inst.last_used = bundle.last_used
    inst.created_at = bundle.created_at

    # rebuild the private REAP file from the local store, preserving the
    # first-touch order — the streamed wake pipeline depends on it
    if bundle.reap_order:
        data = inst.swap_file.read_units(bundle.reap_order)
        inst.reap_file.write_batch([(k, data[k]) for k in bundle.reap_order])

    inst.kv = PagedKVCache(bundle.instance_id, inst.cfg, mgr.pool)
    for sd in bundle.kv_sessions:
        s = KVSession(
            sd["session_id"],
            num_tokens=sd["num_tokens"],
            token_ids=list(sd["token_ids"]),
            pages=[[None] * c for c in sd["page_counts"]],
            host_units={k: None for k in sd["host_keys"]},
            host_shapes=dict(sd["host_shapes"]),
            closed=sd["closed"],
            last_page_fill=sd["last_page_fill"])
        inst.kv.sessions[sd["session_id"]] = s
    if bundle.kv_sessions:
        inst.kv.dropped = True

    if bundle.arrival is not None:
        mgr.governor.arrivals[bundle.instance_id] = bundle.arrival
    return inst


def migrate_instance(src_node, dst_node, instance_id: str, arch_key: str,
                     *, link_bw_bytes_s: float = 4 << 30,
                     on_commit: Optional[Callable[[], None]] = None,
                     block: bool = True,
                     threaded: bool = True) -> MigrationHandle:
    """Migrate one idle tenant ``src_node -> dst_node``.

    The fence (state flip to MIGRATING) happens synchronously under the
    source serve lock — after this function returns the tenant is either
    MIGRATING (handle in flight) or the call raised.  The transfer runs
    on a thread (``threaded=False`` inlines it; ``block`` waits either
    way).  Raises :class:`MigrationError` if the tenant is busy serving
    or not on a migratable rung.
    """
    mgr = src_node.manager
    handle = MigrationHandle(instance_id, src_node.node_id,
                             dst_node.node_id)
    peer = StorePeer(mgr.store, dst_node.manager.store,
                     link_bw_bytes_s=link_bw_bytes_s)

    lock = src_node.engine.instance_lock(instance_id)
    if not lock.acquire(blocking=False):
        raise MigrationError(f"{instance_id}: busy serving")
    try:
        inst = mgr.instances.get(instance_id)
        if inst is None:
            raise MigrationError(f"{instance_id}: not on node "
                                 f"{src_node.node_id}")
        if inst.state not in MIGRATABLE_STATES:
            raise MigrationError(
                f"{instance_id}: state {inst.state.value} not migratable")
        mgr.hib.quiesce(inst)
        try:
            if inst.state == S.HIBERNATE:
                inst.sm.fire(Event.MIGRATE)   # disk state already complete
            else:
                # MMAP_CLEAN / PARTIAL: run the full-deflate body, landing
                # on MIGRATING instead of HIBERNATE — same flush, fenced
                mgr.hib.deflate(inst, event=Event.MIGRATE)
        except BaseException:
            # a MIGRATING tenant with no handle would block forever:
            # fall back to HIBERNATE before letting the error out
            if inst.state == S.MIGRATING:
                inst.sm.fire(Event.MIGRATE_ABORT)
            raise
        inst.migration = handle
    finally:
        lock.release()

    def _transfer() -> None:
        t0 = time.monotonic()
        st = handle.stats
        try:
            bundle = _export_bundle(src_node, inst, arch_key)
            st.meta_bytes = bundle.meta_bytes()
            st.full_snapshot_bytes = sum(
                m.nbytes for m in bundle.extents.values())
            digests = {m.digest for m in bundle.extents.values()
                       if m.digest is not None}
            peer.ship(digests, st)
            rebuilt = _rebuild_on_target(dst_node, bundle)
            # commit: target first (the tenant must exist somewhere at
            # every instant), then the source forgets + GCs
            dst_node.manager.admit(rebuilt)
            inst.sm.fire(Event.MIGRATE_DONE)
            mgr.detach(instance_id, target=dst_node.node_id)
            if on_commit is not None:
                on_commit()
            inst.terminate()       # store refs released (GC), REAP gone
            st.seconds = time.monotonic() - t0
            handle._finish()
        except BaseException as e:
            # abort: the source's disk state was never mutated
            # destructively — fall back to a plain hibernated tenant
            try:
                if inst.state == S.MIGRATING:
                    inst.sm.fire(Event.MIGRATE_ABORT)
            finally:
                inst.migration = None
                st.seconds = time.monotonic() - t0
                handle._finish(error=e)

    if threaded:
        t = threading.Thread(target=_transfer, daemon=True,
                             name=f"migrate-{instance_id}")
        t.start()
        if block:
            handle.wait()
    else:
        _transfer()
    if block and handle.error is not None:
        raise MigrationError(str(handle.error)) from handle.error
    return handle

"""Snapshot migration over the content-addressed store.

A migratable tenant (MMAP_CLEAN / PARTIAL / HIBERNATED) is, or can
cheaply become, a pile of disk state: a private REAP file holding the
working set in first-touch order, plus per-unit digests into the
deployment's refcounted CAS segment file.  Migration therefore ships
*metadata plus missing digests*, never a full snapshot:

  1. **Fence** — under the source engine's serve lock the instance fires
     ``MIGRATE`` and lands in the MIGRATING state: the governor can no
     longer deflate or TERMINATE it (the transitions are illegal), and
     requests/wakes block on the :class:`MigrationHandle` exactly like
     late arrivals block on a shared wake pipeline.
  2. **Flush** — a not-yet-hibernated source runs the normal full-deflate
     body (same code path, different state-machine event), then the REAP
     file's units are hashed into the source store so *everything* the
     tenant owns is content-addressed.  The REAP file itself is not
     shipped — only its key order is, so the target can rebuild it with
     identical streaming layout.
  3. **Ship** — the :class:`StorePeer` asks the target store which
     digests it lacks and transfers only those, at their stored
     compression level.  Base weights a same-deployment tenant already
     parked on the target cost zero bytes; per-session KV deltas are the
     usual payload.
  4. **Rebuild** — the target constructs a hibernated husk: factory
     shapes, adopted extent table (refcounts taken), REAP file rewritten
     from the local store in first-touch order, recorder + arrival-EWMA
     state installed, KV session page tables recreated Not-Present.
     The first wake on the target is byte-identical to an in-place wake.
  5. **Commit** — the source fires ``MIGRATE_DONE``, releases its store
     refs (segment GC: bytes another local tenant still references
     survive), deletes its REAP file, and records the forwarding address
     so stragglers raise ``TenantMigrated`` and get rerouted.

On any transfer/rebuild error the source fires ``MIGRATE_ABORT`` back to
HIBERNATE — its disk state was never touched, so it keeps serving
locally — and the :class:`StorePeer` sweeps whatever segments it had
already imported on the target (never-adopted imports are refcount-zero
orphans; leaking them would be a slow disk leak on every failed
transfer).  Once ``MIGRATE_DONE`` fires the commit is irrevocable: the
target owns the tenant, so source-side finalization (forwarding address,
terminate, store GC) runs to completion even if the commit callback or
cleanup itself fails — a crash there must never strand the tenant on
both nodes or neither.

The channel is a :class:`~repro.cluster.transport.Transport`: in-process
loopback by default, or a length-prefixed socket speaking the
:mod:`repro.cluster.wire` binary protocol for real multi-host moves.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.cluster.faults import checkpoint
from repro.cluster.transport import (AuthError, LoopbackTransport,
                                     Transport, TransportError)
from repro.core.governor import MIGRATABLE_STATES
from repro.core.instance import ModelInstance
from repro.core.prefix import PREFIX_OWNER
from repro.core.state import ContainerState, Event
from repro.serving.paged_kv import KVSession, PagedKVCache

S = ContainerState

#: rough per-key wire cost of the metadata half of a migration (extent
#: records, recorder entries, KV page-table slots) — accounting only
_META_BYTES_PER_KEY = 64


class MigrationError(RuntimeError):
    pass


@dataclass
class TransferStats:
    """What one migration actually moved."""
    digests_total: int = 0
    digests_shipped: int = 0          # absent on the target: crossed the link
    bytes_shipped: int = 0            # stored (compressed) payload bytes sent
    bytes_dedup: int = 0              # stored bytes the target already held
    meta_bytes: int = 0               # extent/recorder/page-table metadata
    full_snapshot_bytes: int = 0      # naive verbatim snapshot (raw units)
    link_seconds: float = 0.0         # bytes over the modelled link bw
    seconds: float = 0.0              # wall time of the whole migration

    @property
    def wire_bytes(self) -> int:
        return self.bytes_shipped + self.meta_bytes


class StorePeer:
    """Transfer channel between two nodes' CAS stores, over a
    :class:`~repro.cluster.transport.Transport`.

    Both stores must share the deployment salt — the digest *is* the
    cluster-wide content address, so an unsalted-compatible peer would be
    a different deployment and shipping to it is refused (loopback
    compares salts directly; the socket transport proves possession via
    the keyed-nonce handshake, so the salt never crosses the wire).

    The peer remembers every digest it ships; if the migration aborts
    before the target adopts them, :meth:`release_remote` sweeps those
    refcount-zero imports so a failed transfer leaks nothing."""

    def __init__(self, src_store, dst_store=None, *,
                 transport: Optional[Transport] = None,
                 link_bw_bytes_s: float = 4 << 30,
                 chunk_bytes: int = 4 << 20):
        if src_store is None or (dst_store is None and transport is None):
            raise MigrationError("migration requires the dedup store on "
                                 "both nodes (ManagerConfig.dedup_store)")
        if transport is None:
            transport = LoopbackTransport(dst_store=dst_store)
        self.src = src_store
        self.transport = transport
        self.link_bw_bytes_s = link_bw_bytes_s
        self.chunk_bytes = chunk_bytes
        self.shipped: List[bytes] = []    # imported on target, not adopted
        try:
            transport.authenticate(src_store.salt)
        except AuthError as e:
            raise MigrationError(str(e)) from e

    def missing(self, digests) -> List[bytes]:
        return self.transport.missing_digests(list(digests))

    def ship(self, digests, stats: TransferStats) -> None:
        """Move the given digests' segments src -> dst, dedup-aware:
        only segments absent on the target cross the link, in chunks so
        the transport's flow control applies within one migration.  On
        failure the already-shipped chunks are swept on the target
        before the error propagates — no refcount leak mid-bundle."""
        digests = list(digests)
        stats.digests_total += len(digests)
        missing = self.missing(digests)
        stats.digests_shipped += len(missing)
        stats.bytes_dedup += self.src.stored_bytes_of(
            [d for d in digests if d not in set(missing)])
        sent = 0
        try:
            for chunk in self.src.export_segments_iter(
                    missing, chunk_bytes=self.chunk_bytes):
                self.shipped.extend(d for d, _, _, _ in chunk)
                sent += self.transport.send_segments(chunk)
            self.transport.barrier()
        except BaseException:
            self.release_remote()
            raise
        stats.bytes_shipped += sent
        stats.link_seconds += sent / max(self.link_bw_bytes_s, 1.0)

    def adopted(self) -> None:
        """The bundle landed and the target took refs: nothing to sweep."""
        self.shipped = []

    def release_remote(self) -> int:
        """Abort cleanup: free segments we imported on the target that
        were never adopted.  Best-effort — if the channel itself is dead
        the server's connection-teardown sweep reclaims them instead."""
        if not self.shipped:
            return 0
        digests, self.shipped = self.shipped, []
        try:
            return self.transport.sweep_orphans(digests)
        except (TransportError, OSError):
            return 0


class MigrationHandle:
    """Shared handle for one in-flight migration — ``inst.migration``.

    Requests and wakes landing on the MIGRATING tenant :meth:`wait` on
    it (the in-flight-request handoff), mirroring how late wake arrivals
    share the wake pipeline handle."""

    def __init__(self, instance_id: str, source: str, target: str):
        self.instance_id = instance_id
        self.source_node_id = source
        self.target_node_id = target
        self.stats = TransferStats()
        self.error: Optional[BaseException] = None
        #: True once ``MIGRATE_DONE`` fired — past this point the target
        #: owns the tenant and the source will finish its teardown even
        #: if a later step (commit callback, local GC) records an error
        self.committed = False
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ok(self) -> bool:
        return self._done.is_set() and self.error is None

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def _finish(self, error: Optional[BaseException] = None) -> None:
        self.error = error
        self._done.set()


@dataclass
class _Bundle:
    """The metadata half of a migration (in-process wire format)."""
    instance_id: str
    arch_key: str
    base_id: Optional[str]
    shared_paths: frozenset
    extents: Dict                      # key -> UnitMeta (digests)
    reap_order: List                   # REAP file keys, first-touch order
    stable: List                       # recorder stable set, ordered
    misses: Dict                       # recorder coldness counters (pruned)
    kv_sessions: List[Dict]
    last_used: float
    created_at: float
    #: the kept-alive compiled executables ride along — in this
    #: in-process simulation they transfer by reference, standing in for
    #: a node-shared persistent compilation cache; without them the
    #: migrant's first request would pay a cold-start-sized re-JIT,
    #: which is exactly the cost hibernation exists to avoid
    compiled: Dict = field(default_factory=dict)
    arrival: Optional[Tuple] = None    # governor EWMA (last_ts, gap)
    wire_keys: int = 0
    #: prefix-registry entries the tenant's sessions share, as pure
    #: metadata records — the target rebuilds pages from its own
    #: registry/store by digest, never from re-transferred payloads
    prefix_records: List[Dict] = field(default_factory=list)
    #: store extent table for the registry's CAS keys (pfx/pfxh) backing
    #: those records; adopted under the target's ``__prefix__`` client
    prefix_extents: Dict = field(default_factory=dict)

    def meta_bytes(self) -> int:
        return self.wire_keys * _META_BYTES_PER_KEY


def _export_bundle(src_node, inst: ModelInstance,
                   arch_key: str) -> _Bundle:
    """Steps 1½–2: flush the REAP file into the CAS store and snapshot
    every piece of metadata the target needs.  Runs with the instance
    fenced in MIGRATING."""
    # the coldness counters ship with the tenant; prune dead keys (closed
    # sessions' KV pages) FIRST or they would leak onto the target forever
    live = set(inst.units) | set(inst.reap_file.extents) \
        | set(inst.swap_file.extents)
    inst.recorder.prune_misses(live)

    reap_order = list(inst.reap_file.extents)
    # the full-deflate body already content-addressed the working set
    # (write-through); this is only the safety net for keys that missed
    # it, so an already-inventoried tenant pays zero re-hashing here
    missing_ws = [k for k in reap_order if k not in inst.swap_file]
    if missing_ws:
        data = inst.reap_file.read_batch()
        inst.swap_file.write_units([(k, data[k]) for k in missing_ws])

    kv_sessions: List[Dict] = []
    if inst.kv is not None:
        for sid, s in inst.kv.sessions.items():
            kv_sessions.append({
                "session_id": sid,
                "num_tokens": s.num_tokens,
                "token_ids": list(s.token_ids),
                "closed": s.closed,
                "page_counts": [len(layer) for layer in s.pages],
                "host_shapes": dict(s.host_shapes),
                "host_keys": list(s.host_units),
                "prefix_digest": s.prefix_digest,
                "prefix_tokens": s.prefix_tokens,
            })

    store = src_node.manager.store
    extents = store.export_meta(inst.swap_file)
    reg = src_node.manager.prefix_registry
    prefix_records, prefix_extents = (
        reg.export_records(inst.instance_id) if reg is not None
        else ([], {}))
    gov = src_node.manager.governor
    bundle = _Bundle(
        instance_id=inst.instance_id,
        arch_key=arch_key,
        base_id=inst.base_id,
        shared_paths=frozenset(inst.shared_paths),
        extents=extents,
        reap_order=reap_order,
        stable=list(inst.recorder.stable),
        misses=dict(inst.recorder.misses),
        kv_sessions=kv_sessions,
        last_used=inst.last_used,
        created_at=inst.created_at,
        compiled=dict(inst.compiled),
        arrival=gov.arrivals.get(inst.instance_id),
        prefix_records=prefix_records,
        prefix_extents=prefix_extents,
    )
    bundle.wire_keys = (len(extents) + len(bundle.stable)
                        + len(bundle.misses) + len(prefix_extents)
                        + len(prefix_records)
                        + sum(sum(sd["page_counts"]) + len(sd["host_keys"])
                              for sd in kv_sessions))
    return bundle


def _rebuild_on_target(dst_node, bundle: _Bundle) -> ModelInstance:
    """Step 4: construct the hibernated husk on the target node."""
    mgr = dst_node.manager
    model_cfg, params = dst_node.factory(bundle.arch_key)
    shared_on = mgr.shared is not None and bundle.base_id is not None
    inst = ModelInstance(
        bundle.instance_id, model_cfg, params, pool=mgr.pool,
        spool_dir=mgr.cfg.spool_dir,
        shared_paths=bundle.shared_paths if shared_on else None,
        base_id=bundle.base_id if shared_on else None,
        store=mgr.store,
        metadata_bytes=mgr.cfg.husk_metadata_bytes,
        arch_key=bundle.arch_key)
    try:
        return _populate_target(mgr, inst, bundle)
    except BaseException:
        # abort mid-rebuild (store read error, disk full): the adopted
        # segment refs and half-built spool files must not leak on the
        # target — terminate releases the client (refcount GC) and
        # deletes the files
        inst.terminate()
        raise


def _populate_target(mgr, inst: ModelInstance,
                     bundle: _Bundle) -> ModelInstance:
    # adopt the shipped extent table (takes segment refs) BEFORE touching
    # the instance's own client — ModelInstance.__init__ created it empty
    mgr.store.adopt_extents(bundle.instance_id, bundle.extents)

    # the factory params are placeholder shapes: drop them so every unit
    # is Not-Present and the first wake restores the *migrated* bytes
    inst.sm.fire(Event.COLD_START)
    inst.sm.fire(Event.SIGSTOP)
    inst.drop_weights()
    inst.inflated = False
    inst.mmap_dropped = True          # wake re-maps via the registry

    inst.recorder.stable = {k: None for k in bundle.stable}
    inst.recorder.misses = dict(bundle.misses)
    inst.compiled.update(bundle.compiled)
    inst.last_used = bundle.last_used
    inst.created_at = bundle.created_at

    # rebuild the private REAP file from the local store, preserving the
    # first-touch order — the streamed wake pipeline depends on it
    if bundle.reap_order:
        data = inst.swap_file.read_units(bundle.reap_order)
        inst.reap_file.write_batch([(k, data[k]) for k in bundle.reap_order])

    # prefix registry: adopt the shipped pfx/pfxh extents (segment refs
    # under the target's __prefix__ client) and install the records as
    # spilled entries — pages revive lazily by digest, nothing prefills.
    # Entries the target's registry already holds cost metadata only.
    reg = mgr.prefix_registry
    if reg is not None and bundle.prefix_records:
        mgr.store.adopt_extents(PREFIX_OWNER, bundle.prefix_extents)
        reg.install_records(bundle.prefix_records)

    inst.kv = PagedKVCache(bundle.instance_id, inst.cfg, mgr.pool,
                           registry=reg)
    for sd in bundle.kv_sessions:
        s = KVSession(
            sd["session_id"],
            num_tokens=sd["num_tokens"],
            token_ids=list(sd["token_ids"]),
            pages=[[None] * c for c in sd["page_counts"]],
            host_units={k: None for k in sd["host_keys"]},
            host_shapes=dict(sd["host_shapes"]),
            closed=sd["closed"])
        digest = sd.get("prefix_digest")
        if reg is not None and digest is not None \
                and reg.get(digest) is not None:
            s.prefix_digest = digest
            s.prefix_tokens = int(sd.get("prefix_tokens", 0))
            reg.attach_session(digest, bundle.instance_id,
                               sd["session_id"])
        inst.kv.sessions[sd["session_id"]] = s
    if bundle.kv_sessions:
        inst.kv.dropped = True

    if bundle.arrival is not None:
        mgr.governor.arrivals[bundle.instance_id] = bundle.arrival
    return inst


def bundle_digests(bundle: _Bundle) -> frozenset:
    """Every CAS digest a bundle's tenant references (unit extents plus
    prefix-registry extents) — the content fingerprint replication and
    recovery operate on."""
    return frozenset(
        {m.digest for m in bundle.extents.values() if m.digest is not None}
        | {m.digest for m in bundle.prefix_extents.values()
           if m.digest is not None})


@dataclass
class ReplicaRecord:
    """One tenant's recovery replica as held by a non-home node: the
    full metadata bundle plus the digest set pinned in the holder's
    store.  ``receive_bundle(holder, rec.bundle)`` is the entire
    recovery path — the same code migration commits through."""
    bundle: _Bundle
    digests: frozenset
    source_node_id: str
    stored_bytes: int = 0

    @property
    def instance_id(self) -> str:
        return self.bundle.instance_id


def replicate_instance(src_node, dst_node, instance_id: str,
                       arch_key: str, *,
                       transport: Optional[Transport] = None
                       ) -> ReplicaRecord:
    """Copy a hibernated tenant's recovery substrate onto ``dst_node``
    without moving the tenant: ship the digests its bundle references
    (dedup-aware — shared base weights usually cost zero bytes), pin
    them in the holder's store so local GC cannot free them, and record
    the bundle.  The source stays the home; the replica only ever
    activates through :func:`receive_bundle` during crash recovery.

    Only HIBERNATE tenants replicate: their disk state is complete and
    frozen, so the bundle is a consistent snapshot by construction (a
    PARTIAL tenant's next deflate would invalidate it immediately)."""
    mgr = src_node.manager
    if transport is None:
        transport = LoopbackTransport(dst_node=dst_node)
    peer = StorePeer(mgr.store, transport=transport)

    lock = src_node.engine.instance_lock(instance_id)
    if not lock.acquire(blocking=False):
        raise MigrationError(f"{instance_id}: busy serving")
    try:
        inst = mgr.instances.get(instance_id)
        if inst is None:
            raise MigrationError(f"{instance_id}: not on node "
                                 f"{src_node.node_id}")
        if inst.state != S.HIBERNATE:
            raise MigrationError(
                f"{instance_id}: state {inst.state.value} — only "
                f"HIBERNATE tenants replicate")
        bundle = _export_bundle(src_node, inst, arch_key)
    finally:
        lock.release()

    digests = bundle_digests(bundle)
    stats = TransferStats()
    try:
        peer.ship(sorted(digests), stats)
        checkpoint("replicate.shipped", instance_id)
        stored = dst_node.store.pin_replicas(digests)
    except BaseException:
        peer.release_remote()
        raise
    peer.adopted()          # pinned: the pins are the references now
    rec = ReplicaRecord(bundle=bundle, digests=digests,
                        source_node_id=src_node.node_id,
                        stored_bytes=stored)
    dst_node.replicas[instance_id] = rec
    return rec


def receive_bundle(dst_node, bundle: _Bundle) -> ModelInstance:
    """Target-side bundle commit: rebuild the hibernated husk and admit
    it.  This is the single entry point both transports call — the
    loopback directly, the :class:`~repro.cluster.transport.StoreServer`
    as its ``BUNDLE`` handler — so socket and in-process migrations are
    byte-identical from here down."""
    rebuilt = _rebuild_on_target(dst_node, bundle)
    dst_node.manager.admit(rebuilt)
    return rebuilt


def migrate_instance(src_node, dst_node, instance_id: str, arch_key: str,
                     *, link_bw_bytes_s: float = 4 << 30,
                     transport: Optional[Transport] = None,
                     on_commit: Optional[Callable[[], None]] = None,
                     block: bool = True,
                     threaded: bool = True) -> MigrationHandle:
    """Migrate one idle tenant ``src_node -> dst_node``.

    The fence (state flip to MIGRATING) happens synchronously under the
    source serve lock — after this function returns the tenant is either
    MIGRATING (handle in flight) or the call raised.  The transfer runs
    on a thread (``threaded=False`` inlines it; ``block`` waits either
    way).  Raises :class:`MigrationError` if the tenant is busy serving
    or not on a migratable rung; a transfer failure raised with
    ``block=True`` carries the handle as ``exc.handle`` so callers can
    tell a refused fence from a failed target.

    ``transport`` defaults to in-process loopback against ``dst_node``;
    pass a connected :class:`~repro.cluster.transport.SocketTransport`
    to move the tenant to a remote :class:`StoreServer` instead (then
    ``dst_node`` may be ``None``).
    """
    mgr = src_node.manager
    if transport is None:
        if dst_node is None:
            raise MigrationError("migration needs a target node or a "
                                 "connected transport")
        transport = LoopbackTransport(dst_node=dst_node)
    target_id = transport.target_node_id or (
        dst_node.node_id if dst_node is not None else "remote")
    handle = MigrationHandle(instance_id, src_node.node_id, target_id)
    peer = StorePeer(mgr.store, transport=transport,
                     link_bw_bytes_s=link_bw_bytes_s)

    lock = src_node.engine.instance_lock(instance_id)
    if not lock.acquire(blocking=False):
        raise MigrationError(f"{instance_id}: busy serving")
    try:
        inst = mgr.instances.get(instance_id)
        if inst is None:
            raise MigrationError(f"{instance_id}: not on node "
                                 f"{src_node.node_id}")
        if inst.state not in MIGRATABLE_STATES:
            raise MigrationError(
                f"{instance_id}: state {inst.state.value} not migratable")
        mgr.hib.quiesce(inst)
        try:
            if inst.state == S.HIBERNATE:
                inst.sm.fire(Event.MIGRATE)   # disk state already complete
            else:
                # MMAP_CLEAN / PARTIAL: run the full-deflate body, landing
                # on MIGRATING instead of HIBERNATE — same flush, fenced
                mgr.hib.deflate(inst, event=Event.MIGRATE)
        except BaseException:
            # a MIGRATING tenant with no handle would block forever:
            # fall back to HIBERNATE before letting the error out
            if inst.state == S.MIGRATING:
                inst.sm.fire(Event.MIGRATE_ABORT)
            raise
        inst.migration = handle
    finally:
        lock.release()

    def _transfer() -> None:
        t0 = time.monotonic()
        st = handle.stats
        try:
            bundle = _export_bundle(src_node, inst, arch_key)
            checkpoint("migrate.exported", instance_id)
            st.meta_bytes = bundle.meta_bytes()
            st.full_snapshot_bytes = sum(
                m.nbytes for m in bundle.extents.values())
            # prefix segments ride the same dedup-aware transfer: a
            # target already holding the prompt's pages ships nothing
            digests = sorted(bundle_digests(bundle))
            peer.ship(digests, st)
            # fault point between import and adopt: a crash here leaves
            # refcount-zero imports on the target that the abort sweep
            # (or the server's connection teardown) must reclaim
            checkpoint("migrate.shipped", instance_id)
            # commit: target first (the tenant must exist somewhere at
            # every instant), then the source forgets + GCs
            peer.transport.send_bundle(bundle)
            peer.adopted()
            inst.sm.fire(Event.MIGRATE_DONE)
            handle.committed = True
            checkpoint("migrate.committed", instance_id)
        except BaseException as e:
            # abort: the source's disk state was never mutated
            # destructively — fall back to a plain hibernated tenant;
            # anything already imported on the target is swept
            try:
                peer.release_remote()
            finally:
                try:
                    if inst.state == S.MIGRATING:
                        inst.sm.fire(Event.MIGRATE_ABORT)
                finally:
                    inst.migration = None
                    st.seconds = time.monotonic() - t0
                    handle._finish(error=e)
            return
        # Past MIGRATE_DONE the commit is irrevocable — the target owns
        # the tenant.  Every source-side step below must be attempted
        # even if an earlier one fails (crash consistency: a commit
        # callback blowing up must not leave a DEAD husk holding store
        # refs and no forwarding address).
        commit_err: Optional[BaseException] = None
        try:
            mgr.detach(instance_id, target=target_id)
        except BaseException as e:
            commit_err = e
        if on_commit is not None:
            try:
                on_commit()
            except BaseException as e:
                commit_err = commit_err or e
        try:
            inst.terminate()       # store refs released (GC), REAP gone
        except BaseException as e:
            commit_err = commit_err or e
        st.seconds = time.monotonic() - t0
        handle._finish(error=commit_err)

    if threaded:
        t = threading.Thread(target=_transfer, daemon=True,
                             name=f"migrate-{instance_id}")
        t.start()
        if block:
            handle.wait()
    else:
        _transfer()
    if block and handle.error is not None:
        err = MigrationError(str(handle.error))
        err.handle = handle     # lets callers distinguish transfer
        raise err from handle.error  # failures from fence refusals
    return handle

"""Deterministic fault injection for the cluster layer.

The chaos tests and ``benchmarks/recovery.py`` need to kill a node *at
a named point* ("after the segments shipped but before the bundle
landed"), flip a byte inside a transport frame, or crash a store
between import and adopt — reproducibly, from a seed.  This module is
that harness:

  * Cluster code calls :func:`checkpoint("migrate.shipped", ...)` at
    interesting points.  With no injector armed it is a dict lookup and
    a return — zero cost, always on, never imported by ``repro.core``
    (the core stays fault-free; tests crash core paths by
    monkeypatching ``os`` primitives instead).
  * A test arms a :class:`FaultInjector` with actions bound to points:
    ``inj.arm("migrate.shipped", kill_node("n0"))``.  Actions fire on
    the Nth hit (default first), once or always, and draw any
    randomness (which byte to corrupt, how long to delay) from the
    injector's seeded ``random.Random`` — same seed, same chaos.
  * :class:`FaultyTransport` wraps any :class:`~.transport.Transport`
    and applies frame-level mutations (drop / delay / corrupt /
    truncate) to the segment stream, exercising the server's
    protocol-error hardening end to end.

Everything is stdlib-only and in-process; "kill node" means
``Node.kill()`` (fail pending work, close sockets), not ``os.kill``.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .transport import Transport, TransportError


class FaultError(RuntimeError):
    """Raised by the ``crash`` action: simulates the process dying at a
    checkpoint.  Deliberately NOT a subclass of TransportError — code
    under test must survive it via its generic cleanup paths."""


@dataclass
class _Arm:
    action: Callable[[Any], None]
    hit: int = 1          # fire on the Nth time the point is reached
    repeat: bool = False  # keep firing on every hit >= `hit`
    count: int = 0        # times the point was reached
    fired: int = 0        # times the action ran


class FaultInjector:
    """Seeded registry of (checkpoint -> action) arms.

    Use as a context manager to install it as the process-wide active
    injector::

        inj = FaultInjector(seed=7)
        inj.arm("migrate.shipped", inj.kill_node(node0))
        with inj:
            ... drive the cluster ...
        assert inj.fired("migrate.shipped") == 1
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.seed = seed
        self._arms: Dict[str, List[_Arm]] = {}
        self._hits: Dict[str, int] = {}        # every checkpoint reached
        self.log: List[Tuple[str, int]] = []   # (point, hit#) of fired arms
        self._lock = threading.RLock()

    # ------------------------------------------------------------- arming
    def arm(self, point: str, action: Callable[[Any], None], *,
            hit: int = 1, repeat: bool = False) -> "FaultInjector":
        with self._lock:
            self._arms.setdefault(point, []).append(
                _Arm(action=action, hit=hit, repeat=repeat))
        return self

    def hits(self, point: str) -> int:
        with self._lock:
            return self._hits.get(point, 0)

    def fired(self, point: str) -> int:
        with self._lock:
            return sum(a.fired for a in self._arms.get(point, []))

    # ------------------------------------------------------------- actions
    @staticmethod
    def crash() -> Callable[[Any], None]:
        """Simulate the process dying here: raises :class:`FaultError`
        out of the checkpoint, abandoning whatever was in flight."""
        def _act(payload):
            raise FaultError("injected crash")
        return _act

    @staticmethod
    def kill_node(node) -> Callable[[Any], None]:
        """Hard-kill a node at the checkpoint (then lets the caller
        continue — the *next* interaction with the node fails)."""
        def _act(payload):
            node.kill()
        return _act

    @staticmethod
    def call(fn: Callable[[], None]) -> Callable[[Any], None]:
        def _act(payload):
            fn()
        return _act

    # ------------------------------------------------------------- firing
    def fire(self, point: str, payload: Any = None) -> None:
        acts: List[Callable[[Any], None]] = []
        with self._lock:
            self._hits[point] = self._hits.get(point, 0) + 1
            for a in self._arms.get(point, ()):
                a.count += 1
                due = a.count == a.hit or (a.repeat and a.count >= a.hit)
                if due:
                    a.fired += 1
                    self.log.append((point, a.count))
                    acts.append(a.action)
        for act in acts:       # outside the lock: actions may re-enter
            act(payload)

    def __enter__(self) -> "FaultInjector":
        _install(self)
        return self

    def __exit__(self, *exc) -> None:
        _install(None)


# ---------------------------------------------------------------- hookup
_active: Optional[FaultInjector] = None
_active_lock = threading.Lock()


def _install(inj: Optional[FaultInjector]) -> None:
    global _active
    with _active_lock:
        if inj is not None and _active is not None:
            raise RuntimeError("a FaultInjector is already active")
        _active = inj


def checkpoint(point: str, payload: Any = None) -> None:
    """Named fault point.  No-op unless an injector is active AND armed
    for this point; the armed action may raise (crash) or mutate
    cluster state (kill_node) before control returns."""
    inj = _active
    if inj is not None:
        inj.fire(point, payload)


# ------------------------------------------------------------ transport
@dataclass
class FrameFaults:
    """Per-call frame mutations for :class:`FaultyTransport`, applied to
    the ``send_segments`` stream.  Probabilities are evaluated against
    the owning injector's seeded RNG — deterministic per seed."""
    drop_p: float = 0.0        # silently drop a segment (server sees a gap)
    corrupt_p: float = 0.0     # flip one byte of the payload
    truncate_p: float = 0.0    # cut the payload short
    delay_p: float = 0.0       # sleep before forwarding
    delay_s: float = 0.0
    fail_after: Optional[int] = None   # raise TransportError after N sends


class FaultyTransport(Transport):
    """Wraps a transport and mutates the segment stream per
    :class:`FrameFaults`.  Corruption happens *on the wire* (between
    export and import), so the receiver's content verification — not
    the sender's checksum — is what must catch it."""

    def __init__(self, inner: Transport, injector: FaultInjector,
                 faults: Optional[FrameFaults] = None):
        self.inner = inner
        self.injector = injector
        self.faults = faults or FrameFaults()
        self.sent = 0
        self.dropped = 0
        self.corrupted = 0
        self.truncated = 0

    # pass-throughs
    @property
    def target_node_id(self):
        return self.inner.target_node_id

    def __getattr__(self, name):
        # StorePeer pokes transport internals (e.g. the socket path's
        # salt fingerprint); forward anything we don't mutate
        return getattr(self.inner, name)

    def authenticate(self, salt: bytes) -> None:
        self.inner.authenticate(salt)

    def missing_digests(self, digests):
        return self.inner.missing_digests(digests)

    def barrier(self) -> None:
        self.inner.barrier()

    def send_bundle(self, bundle) -> None:
        checkpoint("transport.send_bundle", bundle)
        self.inner.send_bundle(bundle)

    def sweep_orphans(self, digests) -> int:
        return self.inner.sweep_orphans(digests)

    def close(self) -> None:
        self.inner.close()

    # the mutated path
    def send_segments(self, segments: Iterable[Tuple[bytes, int, int, bytes]]
                      ) -> int:
        return self.inner.send_segments(list(self._mutate(segments)))

    def _mutate(self, segments):
        rng, f = self.injector.rng, self.faults
        for digest, level, raw_nbytes, payload in segments:
            self.sent += 1
            if f.fail_after is not None and self.sent > f.fail_after:
                raise TransportError("injected transport failure")
            if f.delay_p and rng.random() < f.delay_p:
                import time
                time.sleep(f.delay_s)
            if f.drop_p and rng.random() < f.drop_p:
                self.dropped += 1
                continue
            if f.truncate_p and payload and rng.random() < f.truncate_p:
                self.truncated += 1
                payload = payload[:rng.randrange(len(payload))]
            elif f.corrupt_p and payload and rng.random() < f.corrupt_p:
                self.corrupted += 1
                i = rng.randrange(len(payload))
                b = bytearray(payload)
                b[i] ^= 1 + rng.randrange(255)   # guaranteed bit flip
                payload = bytes(b)
            yield digest, level, raw_nbytes, payload


def corrupt_one_byte(buf: bytes, rng: random.Random) -> bytes:
    """Flip one byte of ``buf`` (never a no-op); helper for tests that
    corrupt a store file on disk rather than a wire frame."""
    if not buf:
        return buf
    i = rng.randrange(len(buf))
    b = bytearray(buf)
    b[i] ^= 1 + rng.randrange(255)
    return bytes(b)

"""Canonical binary wire format for the cluster fabric.

Everything StorePeer moves between nodes — unit keys, ``UnitMeta``
extent tables, ``_Bundle`` migration metadata, segment payloads — has an
in-process representation made of Python tuples and dataclasses.  This
module defines the *wire-safe* encoding of those values: a type-tagged,
length-prefixed binary codec with one canonical byte string per value,
so digest tables and REAP key orders round-trip bit-exact between
deployments built on different hosts.

Design rules:

* **Self-describing** — every value carries a one-byte type tag; the
  decoder never needs out-of-band schema.
* **Canonical** — a given value has exactly one encoding.  Varints are
  minimal-length, floats are big-endian IEEE-754 doubles, and the only
  unordered container (``frozenset``) is serialised with its elements'
  *encodings* sorted, so ``encode(decode(b)) == b`` for any valid ``b``.
  Dicts and lists preserve order (REAP first-touch order is load-bearing
  for the streamed wake pipeline).
* **Bounded** — the decoder enforces nesting and size limits so a
  malformed or hostile peer cannot balloon memory before auth completes.

Framing (``pack_frame`` / ``unpack_frame``) is a plain
``u32 length | u8 msg-type | payload`` envelope used by the socket
transport; the loopback transport never touches it.

The one non-wire-safe bundle field is ``compiled`` — jitted executables
stand in for a node-shared compilation cache and only transfer by
reference in-process.  ``encode_bundle`` drops them; a migrant arriving
over a real socket re-JITs against the target's compilation cache.
"""
from __future__ import annotations

import struct
from typing import Any, List, Tuple

import numpy as np

from repro.core.store import UnitMeta

PROTOCOL_VERSION = 2

#: hard ceiling on one frame (payloads are chunked well below this)
MAX_FRAME_BYTES = 256 << 20
#: recursion guard for nested containers
MAX_DEPTH = 32

# value type tags -----------------------------------------------------------
_T_NONE = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03      # zigzag varint
_T_FLOAT = 0x04    # big-endian IEEE-754 double
_T_STR = 0x05      # varint length + utf-8
_T_BYTES = 0x06    # varint length + raw
_T_TUPLE = 0x07    # varint count + values
_T_LIST = 0x08     # varint count + values
_T_DICT = 0x09     # varint count + key/value pairs, insertion order
_T_FSET = 0x0A     # varint count + element encodings, sorted bytewise
_T_META = 0x0B     # UnitMeta: digest, fill, nbytes, dtype, shape

# frame message types -------------------------------------------------------
MSG_HELLO = 0x10       # server -> client: {proto, node_id, nonce}
MSG_AUTH = 0x11        # client -> server: {node_id, nonce, proof}
MSG_AUTH_OK = 0x12     # server -> client: {proof}  (mutual)
MSG_MISSING = 0x13     # client -> server: [digests]
MSG_MISSING_OK = 0x14  # server -> client: [missing digests]
MSG_SEGMENTS = 0x15    # client -> server: [(digest, level, raw, payload)]
MSG_SEGMENTS_OK = 0x16 # server -> client: {imported}   (flow-control ack)
MSG_BUNDLE = 0x17      # client -> server: encoded bundle
MSG_BUNDLE_OK = 0x18   # server -> client: {}
MSG_SWEEP = 0x19       # client -> server: [digests] to orphan-sweep
MSG_SWEEP_OK = 0x1A    # server -> client: {freed}
MSG_BYE = 0x1B         # client -> server: clean shutdown
MSG_ERR = 0x1C         # either direction: {error}


class WireError(ValueError):
    """Malformed, non-canonical, or oversized wire data."""


# --------------------------------------------------------------------------
# varints
# --------------------------------------------------------------------------

def _put_uvarint(out: bytearray, n: int) -> None:
    while n >= 0x80:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)


def _get_uvarint(buf, pos: int) -> Tuple[int, int]:
    shift = 0
    n = 0
    while True:
        if pos >= len(buf):
            raise WireError("truncated varint")
        b = buf[pos]
        pos += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            if b == 0 and shift:
                raise WireError("non-canonical varint (padded)")
            return n, pos
        shift += 7
        if shift > 63:
            raise WireError("varint overflow")


def _zigzag(n: int) -> int:
    if not -(1 << 63) <= n < (1 << 63):
        raise WireError("int out of 64-bit range")
    return (n << 1) ^ (n >> 63)


def _unzigzag(n: int) -> int:
    return (n >> 1) ^ -(n & 1)


# --------------------------------------------------------------------------
# values
# --------------------------------------------------------------------------

def _encode_into(out: bytearray, v: Any, depth: int) -> None:
    if depth > MAX_DEPTH:
        raise WireError("value nests too deep")
    if v is None:
        out.append(_T_NONE)
    elif v is True:
        out.append(_T_TRUE)
    elif v is False:
        out.append(_T_FALSE)
    elif isinstance(v, (int, np.integer)):
        # numpy scalars canonicalise to plain ints (token ids, fills)
        out.append(_T_INT)
        _put_uvarint(out, _zigzag(int(v)))
    elif isinstance(v, (float, np.floating)):
        out.append(_T_FLOAT)
        out += struct.pack(">d", float(v))
    elif isinstance(v, str):
        raw = v.encode("utf-8")
        out.append(_T_STR)
        _put_uvarint(out, len(raw))
        out += raw
    elif isinstance(v, (bytes, bytearray, memoryview)):
        raw = bytes(v)
        out.append(_T_BYTES)
        _put_uvarint(out, len(raw))
        out += raw
    elif isinstance(v, UnitMeta):
        out.append(_T_META)
        _encode_into(out, v.digest, depth + 1)
        _put_uvarint(out, _zigzag(v.fill))
        _put_uvarint(out, v.nbytes)
        raw = v.dtype.encode("utf-8")
        _put_uvarint(out, len(raw))
        out += raw
        _put_uvarint(out, len(v.shape))
        for d in v.shape:
            _put_uvarint(out, d)
    elif isinstance(v, tuple):
        out.append(_T_TUPLE)
        _put_uvarint(out, len(v))
        for x in v:
            _encode_into(out, x, depth + 1)
    elif isinstance(v, list):
        out.append(_T_LIST)
        _put_uvarint(out, len(v))
        for x in v:
            _encode_into(out, x, depth + 1)
    elif isinstance(v, dict):
        out.append(_T_DICT)
        _put_uvarint(out, len(v))
        for k, x in v.items():
            _encode_into(out, k, depth + 1)
            _encode_into(out, x, depth + 1)
    elif isinstance(v, frozenset):
        encs = []
        for x in v:
            e = bytearray()
            _encode_into(e, x, depth + 1)
            encs.append(bytes(e))
        encs.sort()
        out.append(_T_FSET)
        _put_uvarint(out, len(encs))
        for e in encs:
            out += e
    else:
        raise WireError(f"type {type(v).__name__} is not wire-safe")


def encode_value(v: Any) -> bytes:
    """Canonical encoding of one wire-safe value."""
    out = bytearray()
    _encode_into(out, v, 0)
    return bytes(out)


def _decode_at(buf, pos: int, depth: int) -> Tuple[Any, int]:
    if depth > MAX_DEPTH:
        raise WireError("value nests too deep")
    if pos >= len(buf):
        raise WireError("truncated value")
    tag = buf[pos]
    pos += 1
    if tag == _T_NONE:
        return None, pos
    if tag == _T_TRUE:
        return True, pos
    if tag == _T_FALSE:
        return False, pos
    if tag == _T_INT:
        n, pos = _get_uvarint(buf, pos)
        return _unzigzag(n), pos
    if tag == _T_FLOAT:
        if pos + 8 > len(buf):
            raise WireError("truncated float")
        return struct.unpack_from(">d", buf, pos)[0], pos + 8
    if tag in (_T_STR, _T_BYTES):
        n, pos = _get_uvarint(buf, pos)
        if pos + n > len(buf):
            raise WireError("truncated string/bytes")
        raw = bytes(buf[pos:pos + n])
        pos += n
        return (raw.decode("utf-8") if tag == _T_STR else raw), pos
    if tag == _T_META:
        digest, pos = _decode_at(buf, pos, depth + 1)
        if digest is not None and not isinstance(digest, bytes):
            raise WireError("UnitMeta.digest must be bytes or None")
        fill, pos = _get_uvarint(buf, pos)
        nbytes, pos = _get_uvarint(buf, pos)
        n, pos = _get_uvarint(buf, pos)
        if pos + n > len(buf):
            raise WireError("truncated dtype")
        dtype = bytes(buf[pos:pos + n]).decode("utf-8")
        pos += n
        rank, pos = _get_uvarint(buf, pos)
        if rank > 64:
            raise WireError("absurd tensor rank")
        shape = []
        for _ in range(rank):
            d, pos = _get_uvarint(buf, pos)
            shape.append(d)
        return UnitMeta(digest=digest, fill=_unzigzag(fill),
                        nbytes=nbytes, dtype=dtype,
                        shape=tuple(shape)), pos
    if tag in (_T_TUPLE, _T_LIST):
        n, pos = _get_uvarint(buf, pos)
        items = []
        for _ in range(n):
            x, pos = _decode_at(buf, pos, depth + 1)
            items.append(x)
        return (tuple(items) if tag == _T_TUPLE else items), pos
    if tag == _T_DICT:
        n, pos = _get_uvarint(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = _decode_at(buf, pos, depth + 1)
            v, pos = _decode_at(buf, pos, depth + 1)
            d[k] = v
        if len(d) != n:
            raise WireError("duplicate dict key")
        return d, pos
    if tag == _T_FSET:
        n, pos = _get_uvarint(buf, pos)
        items = []
        prev = b""
        for _ in range(n):
            start = pos
            x, pos = _decode_at(buf, pos, depth + 1)
            enc = bytes(buf[start:pos])
            if enc <= prev and items:
                raise WireError("frozenset elements not canonically "
                                "sorted")
            prev = enc
            items.append(x)
        fs = frozenset(items)
        if len(fs) != n:
            raise WireError("duplicate frozenset element")
        return fs, pos
    raise WireError(f"unknown type tag 0x{tag:02x}")


def decode_value(buf) -> Any:
    """Decode one value; the buffer must hold exactly one value."""
    v, pos = _decode_at(buf, 0, 0)
    if pos != len(buf):
        raise WireError(f"{len(buf) - pos} trailing bytes after value")
    return v


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------

def encode_segments(items) -> bytes:
    """``[(digest, level, raw_nbytes, payload), ...]`` — the exact tuple
    shape ``SwapStore.export_segments`` emits and ``import_segments``
    accepts."""
    return encode_value([(d, int(level), int(raw), bytes(payload))
                         for d, level, raw, payload in items])


def decode_segments(buf) -> List[Tuple[bytes, int, int, bytes]]:
    items = decode_value(buf)
    if not isinstance(items, list):
        raise WireError("segment chunk must be a list")
    out = []
    for it in items:
        if (not isinstance(it, tuple) or len(it) != 4
                or not isinstance(it[0], bytes)
                or not isinstance(it[1], int)
                or not isinstance(it[2], int)
                or not isinstance(it[3], bytes)):
            raise WireError("malformed segment tuple")
        out.append(it)
    return out


# --------------------------------------------------------------------------
# bundles
# --------------------------------------------------------------------------

_BUNDLE_FIELDS = ("instance_id", "arch_key", "base_id", "shared_paths",
                  "extents", "reap_order", "stable", "misses",
                  "kv_sessions", "last_used", "created_at", "arrival",
                  "wire_keys", "prefix_records", "prefix_extents")


def encode_bundle(bundle) -> bytes:
    """Encode a migration ``_Bundle``.  ``compiled`` does not cross the
    wire (executables are host-local; see module docstring)."""
    body = tuple(getattr(bundle, f) for f in _BUNDLE_FIELDS)
    return encode_value((PROTOCOL_VERSION,) + body)


def decode_bundle(buf):
    from repro.cluster.migrate import _Bundle  # import cycle: call-time
    body = decode_value(buf)
    if not isinstance(body, tuple) or len(body) != len(_BUNDLE_FIELDS) + 1:
        raise WireError("malformed bundle")
    if body[0] != PROTOCOL_VERSION:
        raise WireError(f"bundle protocol {body[0]} != "
                        f"{PROTOCOL_VERSION}")
    return _Bundle(**dict(zip(_BUNDLE_FIELDS, body[1:])))


# --------------------------------------------------------------------------
# frames
# --------------------------------------------------------------------------

_FRAME_HDR = struct.Struct(">IB")


def pack_frame(msg_type: int, payload: bytes) -> bytes:
    if len(payload) > MAX_FRAME_BYTES:
        raise WireError(f"frame payload {len(payload)}B exceeds "
                        f"{MAX_FRAME_BYTES}B")
    return _FRAME_HDR.pack(len(payload), msg_type) + payload


def read_frame(recv_exact, max_bytes: int = MAX_FRAME_BYTES
               ) -> Tuple[int, bytes]:
    """Read one frame via ``recv_exact(n) -> bytes`` (raises on EOF).

    The declared length is bounded *before* any payload byte is read —
    ``max_bytes`` lets a server clamp below the protocol-wide cap, so a
    hostile or corrupt header can never make the receiver allocate
    gigabytes."""
    hdr = recv_exact(_FRAME_HDR.size)
    length, msg_type = _FRAME_HDR.unpack(hdr)
    if length > min(max_bytes, MAX_FRAME_BYTES):
        raise WireError(f"frame length {length}B exceeds cap")
    return msg_type, recv_exact(length)

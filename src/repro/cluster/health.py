"""Node failure detection: leases, heartbeats, and an enumerated
ALIVE -> SUSPECT -> DEAD machine.

The cluster router treats node liveness exactly like the container
lifecycle: a small enumerated state machine whose every edge is in one
table, so the chaos tests can assert the detector never leaves the
graph.  Mirrors :mod:`repro.core.state`:

    ALIVE --MISS--> SUSPECT --EXPIRE--> DEAD
      ^                |                  |
      +-----BEAT-------+   (hysteresis)   +--REINSTATE--> ALIVE

  * **ALIVE** — the node's lease is fresh (a heartbeat arrived within
    ``suspect_after_s``).  Placement and rebalance treat it normally.
  * **SUSPECT** — the lease lapsed.  The node takes no *new* tenants
    and is skipped as a migration/replication target, but nothing is
    torn down: a transient stall (GC pause, network blip) must not
    trigger a cluster-wide re-home.
  * **DEAD** — the lease stayed lapsed past ``dead_after_s`` (or direct
    failure evidence arrived: connection refused, dispatch error).
    Crossing this edge is the expensive one — the router re-homes every
    tenant the node held from replicated segments — so it is guarded by
    both timers *and* hysteresis on the way back: a DEAD node never
    rejoins implicitly; an operator (or the node-agent's re-register
    path) must ``reinstate`` it, and a flapping node that beats once
    while SUSPECT needs ``revive_beats`` *consecutive* beats to count.

There is deliberately no ALIVE -> DEAD edge: even direct failure
evidence walks MISS then EXPIRE, so the history always shows the
SUSPECT observation and an illegal jump is impossible by construction.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class NodeHealth(enum.Enum):
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"


class HealthEvent(enum.Enum):
    MISS = "miss"              # lease lapsed past suspect_after_s
    BEAT = "beat"              # revive_beats consecutive heartbeats
    EXPIRE = "expire"          # lease lapsed past dead_after_s
    REINSTATE = "reinstate"    # explicit readmission of a DEAD node


H, HE = NodeHealth, HealthEvent

#: (state, event) -> (next_state, tag) — every legal edge; anything else
#: raises :class:`InvalidHealthTransition` (enumeration-tested like the
#: container ladder's TRANSITIONS table)
HEALTH_TRANSITIONS: Dict[Tuple[NodeHealth, HealthEvent],
                         Tuple[NodeHealth, str]] = {
    (H.ALIVE, HE.MISS):        (H.SUSPECT, "(s)"),
    (H.SUSPECT, HE.BEAT):      (H.ALIVE, "(r)"),
    (H.SUSPECT, HE.EXPIRE):    (H.DEAD, "(d)"),
    (H.DEAD, HE.REINSTATE):    (H.ALIVE, "(a)"),
}


class InvalidHealthTransition(RuntimeError):
    pass


@dataclass
class NodeHealthMachine:
    """One node's liveness machine (same shape as
    :class:`repro.core.state.StateMachine`, kept separate so liveness
    edges can never be confused with container-lifecycle edges)."""
    node_id: str = ""
    state: NodeHealth = NodeHealth.ALIVE
    history: List[Tuple[float, NodeHealth, HealthEvent, NodeHealth, str]] = \
        field(default_factory=list)

    def can(self, event: HealthEvent) -> bool:
        return (self.state, event) in HEALTH_TRANSITIONS

    def fire(self, event: HealthEvent, now: float = 0.0) -> NodeHealth:
        key = (self.state, event)
        if key not in HEALTH_TRANSITIONS:
            raise InvalidHealthTransition(
                f"{self.node_id}: event {event.value!r} invalid in "
                f"health state {self.state.value!r}")
        new, tag = HEALTH_TRANSITIONS[key]
        self.history.append((now, self.state, event, new, tag))
        self.state = new
        return new


@dataclass
class HealthPolicy:
    #: expected heartbeat cadence (what ``check_health`` callers should
    #: roughly tick at; the detector itself is cadence-agnostic)
    heartbeat_interval_s: float = 1.0
    #: lease: no beat for this long -> SUSPECT
    suspect_after_s: float = 3.0
    #: no beat for this long (total, from the last beat) -> DEAD
    dead_after_s: float = 10.0
    #: hysteresis: a SUSPECT node needs this many *consecutive* beats to
    #: return to ALIVE — one lucky packet from a flapping node must not
    #: re-admit it as a placement/replication target
    revive_beats: int = 2
    #: direct failure evidence (connection refused, dispatch raised)
    #: short-circuits the lease timers: MISS then EXPIRE immediately.
    #: False keeps even hard evidence on the timer path (debug knob).
    fail_fast: bool = True


class FailureDetector:
    """Lease/heartbeat failure detector over a (mostly) fixed node set.

    Cluster elasticity grows and shrinks the set through
    :meth:`add_node` / :meth:`remove_node`; everything else treats the
    membership as fixed between those explicit calls.

    Time is injected (``now``) so virtual-time benchmarks and the chaos
    tests drive it deterministically.  A node's lease starts at its
    first observation (beat or step) — mixing wall-clock construction
    with virtual-time ticks can therefore never fabricate a lapse.

    Transitions are reported back from :meth:`step` /
    :meth:`observe_failure` and fanned out to ``on_transition``
    subscribers; the router's DEAD subscriber is what triggers
    recovery.
    """

    def __init__(self, node_ids, policy: Optional[HealthPolicy] = None):
        self.policy = policy or HealthPolicy()
        self.machines: Dict[str, NodeHealthMachine] = {
            nid: NodeHealthMachine(nid) for nid in node_ids}
        self._last_beat: Dict[str, Optional[float]] = {
            nid: None for nid in self.machines}
        self._revive_streak: Dict[str, int] = {
            nid: 0 for nid in self.machines}
        self.on_transition: List[Callable[[str, NodeHealth, NodeHealth],
                                          None]] = []
        self.ignored_beats = 0          # beats from DEAD nodes (no resurrect)
        self._lock = threading.RLock()

    # ----------------------------------------------------------- membership
    def add_node(self, node_id: str, now: Optional[float] = None) -> None:
        """Admit a new node (cluster scale-out): starts ALIVE with a
        fresh lease.  Re-adding a known node id is an error — a DEAD
        node re-joining must go through :meth:`reinstate`, never a
        fresh machine (its history would vanish)."""
        with self._lock:
            if node_id in self.machines:
                raise ValueError(f"node {node_id!r} already tracked")
            self.machines[node_id] = NodeHealthMachine(node_id)
            self._last_beat[node_id] = now
            self._revive_streak[node_id] = 0

    def remove_node(self, node_id: str) -> None:
        """Forget a node entirely (drained + decommissioned): its id may
        be reused later as a brand-new member."""
        with self._lock:
            self.machines.pop(node_id, None)
            self._last_beat.pop(node_id, None)
            self._revive_streak.pop(node_id, None)

    # -------------------------------------------------------------- queries
    def state(self, node_id: str) -> NodeHealth:
        return self.machines[node_id].state

    def is_dead(self, node_id: str) -> bool:
        return self.machines[node_id].state is H.DEAD

    def alive_ids(self) -> List[str]:
        """Nodes usable as placement/replication targets (strictly
        ALIVE — a SUSPECT node serves what it has but takes nothing
        new)."""
        return [nid for nid, m in self.machines.items()
                if m.state is H.ALIVE]

    # -------------------------------------------------------------- inputs
    def beat(self, node_id: str, now: float) -> NodeHealth:
        """A heartbeat (or any successful interaction) from the node."""
        with self._lock:
            m = self.machines[node_id]
            if m.state is H.DEAD:
                # no implicit resurrection: a partitioned node coming
                # back after its tenants were re-homed must re-register
                # (reinstate) so it never serves stale placements
                self.ignored_beats += 1
                return m.state
            self._last_beat[node_id] = now
            if m.state is H.SUSPECT:
                self._revive_streak[node_id] += 1
                if self._revive_streak[node_id] >= self.policy.revive_beats:
                    self._fire(m, HE.BEAT, now)
                    self._revive_streak[node_id] = 0
            return m.state

    def step(self, now: float) -> List[Tuple[str, NodeHealth, NodeHealth]]:
        """One lease-expiry pass; returns ``(node_id, old, new)`` for
        every transition it fired."""
        out: List[Tuple[str, NodeHealth, NodeHealth]] = []
        with self._lock:
            for nid, m in self.machines.items():
                last = self._last_beat[nid]
                if last is None:             # first observation seeds lease
                    self._last_beat[nid] = now
                    continue
                age = now - last
                if m.state is H.ALIVE and \
                        age >= self.policy.suspect_after_s:
                    out.append((nid, m.state, self._fire(m, HE.MISS, now)))
                    self._revive_streak[nid] = 0
                if m.state is H.SUSPECT and \
                        age >= self.policy.dead_after_s:
                    out.append((nid, m.state, self._fire(m, HE.EXPIRE, now)))
        return out

    def observe_failure(self, node_id: str, now: float) -> NodeHealth:
        """Direct failure evidence (connection refused, dispatch error).
        With ``fail_fast`` this walks MISS -> EXPIRE immediately — both
        edges fire, so the history still shows the enumerated path."""
        with self._lock:
            m = self.machines[node_id]
            self._revive_streak[node_id] = 0
            if m.state is H.ALIVE:
                self._fire(m, HE.MISS, now)
            if m.state is H.SUSPECT and self.policy.fail_fast:
                self._fire(m, HE.EXPIRE, now)
            return m.state

    def reinstate(self, node_id: str, now: float) -> NodeHealth:
        """Explicit readmission of a DEAD node (operator / re-register
        path).  Its lease restarts fresh."""
        with self._lock:
            m = self.machines[node_id]
            state = self._fire(m, HE.REINSTATE, now)
            self._last_beat[node_id] = now
            self._revive_streak[node_id] = 0
            return state

    # -------------------------------------------------------------- internal
    def _fire(self, m: NodeHealthMachine, event: HealthEvent,
              now: float) -> NodeHealth:
        old = m.state
        new = m.fire(event, now)
        for fn in self.on_transition:
            fn(m.node_id, old, new)
        return new

"""Cluster fabric: multi-node placement and snapshot migration.

A hibernated tenant is a portable artifact — REAP metadata plus
content-addressed digests in the dedup store — so moving it between
nodes is a digest-transfer problem, not a memory-copy problem.  This
package adds the cluster tier over the single-node stack:

  * :class:`~repro.cluster.node.Node` — one simulated node: an
    ``InstanceManager`` (+ ``MemoryGovernor`` + ``SwapStore``), a
    ``ServingEngine``, and optionally an ``AsyncPlatform``;
  * :class:`~repro.cluster.migrate.StorePeer` /
    :func:`~repro.cluster.migrate.migrate_instance` — the dedup-aware
    transfer channel and the MIGRATING-state protocol;
  * :class:`~repro.cluster.router.ClusterRouter` — hibernate-aware
    placement and the cluster-escalated governor (migrate before
    TERMINATED).
"""
from repro.cluster.migrate import (MigrationError, MigrationHandle,
                                   StorePeer, TransferStats,
                                   migrate_instance, receive_bundle)
from repro.cluster.node import Node
from repro.cluster.router import ClusterPolicy, ClusterRouter
from repro.cluster.transport import (AuthError, LoopbackTransport,
                                     SocketTransport, StoreServer,
                                     Transport, TransportError)

__all__ = [
    "ClusterPolicy", "ClusterRouter", "MigrationError", "MigrationHandle",
    "Node", "StorePeer", "TransferStats", "migrate_instance",
    "receive_bundle", "Transport", "LoopbackTransport", "SocketTransport",
    "StoreServer", "TransportError", "AuthError",
]

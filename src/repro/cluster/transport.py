"""Transfer channels behind :class:`~repro.cluster.migrate.StorePeer`.

The migration path speaks one small interface — ``Transport`` — with two
implementations:

* :class:`LoopbackTransport` — two stores in one process; Python objects
  *are* the wire.  This is the original in-process fabric and the
  byte-identity reference the socket path is tested against.
* :class:`SocketTransport` / :class:`StoreServer` — a length-prefixed
  binary protocol over TCP (see :mod:`repro.cluster.wire` for the frame
  and value encodings) with chunked segment transfer, a credit window
  for flow control, and a mutual challenge–response handshake keyed on
  the deployment salt.

Security stance: the per-deployment store salt is the trust boundary.
Digests are salted BLAKE2b, so two deployments can never compare content
addresses; the handshake proves *possession* of the salt on both ends
via keyed-BLAKE2b over fresh nonces — the salt itself never crosses the
wire, and a peer from another deployment (or no deployment) fails auth
before it can name a single digest.  Transport is plaintext TCP for now;
TLS on the channel is a recorded follow-on (see ROADMAP).

Crash story: the server tracks which segments each connection imported.
``BUNDLE`` adopting them ends the transfer; a connection that drops
first gets its never-adopted imports swept on teardown
(:meth:`SwapStore.sweep_orphans`), so a client killed between
``import_segments`` and ``adopt_extents`` cannot leak refcount-zero
payload bytes on the target.
"""
from __future__ import annotations

import hashlib
import hmac
import os
import socket
import struct
import threading
from typing import Callable, List, Optional, Tuple

from repro.cluster import wire
from repro.cluster.wire import (MSG_AUTH, MSG_AUTH_OK, MSG_BUNDLE,
                                MSG_BUNDLE_OK, MSG_BYE, MSG_ERR,
                                MSG_HELLO, MSG_MISSING, MSG_MISSING_OK,
                                MSG_SEGMENTS, MSG_SEGMENTS_OK, MSG_SWEEP,
                                MSG_SWEEP_OK, PROTOCOL_VERSION)


class TransportError(RuntimeError):
    """The channel failed mid-transfer (connection loss, peer error)."""


class ProtocolError(TransportError):
    """The peer spoke garbage (oversized frame, undecodable payload).
    Unlike an application error, the connection cannot be trusted to be
    frame-aligned any more — the only safe handling is to close it."""


class AuthError(TransportError):
    """The peer could not prove possession of the deployment salt."""


def _salt_proof(salt: bytes, *parts: bytes) -> bytes:
    return hashlib.blake2b(b"".join(parts), digest_size=32,
                           key=salt).digest()


class Transport:
    """Abstract one-way transfer channel to a target node's store."""

    #: node id of the far end (forwarding-address bookkeeping), if known
    target_node_id: Optional[str] = None

    def authenticate(self, salt: bytes) -> None:
        """Verify the far end belongs to the deployment ``salt`` names.
        Raises :class:`AuthError` otherwise."""
        raise NotImplementedError

    def missing_digests(self, digests: List[bytes]) -> List[bytes]:
        raise NotImplementedError

    def send_segments(self, items) -> int:
        """Ship one chunk of ``(digest, level, raw, payload)`` tuples;
        returns payload bytes handed to the channel.  May be buffered —
        :meth:`barrier` confirms receipt."""
        raise NotImplementedError

    def barrier(self) -> None:
        """Block until every chunk sent so far is installed remotely."""

    def send_bundle(self, bundle) -> None:
        """Deliver the migration bundle: the far end rebuilds the husk
        and admits it (the transfer's commit on the target side)."""
        raise NotImplementedError

    def sweep_orphans(self, digests: List[bytes]) -> int:
        """Abort path: free never-adopted imports on the target."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class LoopbackTransport(Transport):
    """Two stores in one process — today's fabric, kept as the default.

    Objects cross by reference; there is no encode/decode step, which is
    exactly what makes it the byte-identity reference for the socket
    path (same store mutations, no wire in between)."""

    def __init__(self, dst_store=None, dst_node=None):
        if dst_store is None and dst_node is None:
            raise ValueError("loopback needs a target store or node")
        self.dst_node = dst_node
        self.dst_store = (dst_store if dst_store is not None
                          else dst_node.manager.store)
        self.target_node_id = getattr(dst_node, "node_id", None)

    def authenticate(self, salt: bytes) -> None:
        if not hmac.compare_digest(salt, self.dst_store.salt):
            raise AuthError("peer stores use different deployment "
                            "salts: digests are not comparable")

    def missing_digests(self, digests: List[bytes]) -> List[bytes]:
        return self.dst_store.missing_digests(digests)

    def send_segments(self, items) -> int:
        self.dst_store.import_segments(items)
        return sum(len(p) for _, _, _, p in items)

    def send_bundle(self, bundle) -> None:
        if self.dst_node is None:
            raise TransportError("store-only loopback cannot deliver a "
                                 "bundle (no target node)")
        from repro.cluster.migrate import receive_bundle  # cycle: lazy
        receive_bundle(self.dst_node, bundle)

    def sweep_orphans(self, digests: List[bytes]) -> int:
        return self.dst_store.sweep_orphans(digests)


# --------------------------------------------------------------------------
# socket plumbing
# --------------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError("connection closed mid-frame")
        buf += chunk
    return bytes(buf)


def _read_frame(sock: socket.socket,
                max_bytes: int = wire.MAX_FRAME_BYTES
                ) -> Tuple[int, bytes]:
    try:
        return wire.read_frame(lambda n: _recv_exact(sock, n), max_bytes)
    except wire.WireError as e:
        # a bad length prefix means framing is lost for good
        raise ProtocolError(f"bad frame: {e}") from e
    except (OSError, struct.error) as e:
        raise TransportError(f"recv failed: {e}") from e


def _write_frame(sock: socket.socket, msg_type: int,
                 payload: bytes) -> None:
    try:
        sock.sendall(wire.pack_frame(msg_type, payload))
    except OSError as e:
        raise TransportError(f"send failed: {e}") from e


class SocketTransport(Transport):
    """Length-prefixed binary channel to a :class:`StoreServer`.

    Segment chunks are pipelined under a credit window (at most
    ``window`` un-acked ``SEGMENTS`` frames in flight); every other
    operation is strict request/response, so a :meth:`barrier` drains
    the window first.  One transport serves any number of sequential
    migrations — the server's per-connection import ledger resets at
    each ``BUNDLE``."""

    def __init__(self, sock: socket.socket, *, window: int = 4):
        self.sock = sock
        self.window = max(1, window)
        self._unacked = 0
        self._salt_fp: Optional[bytes] = None   # blake2b(salt) fingerprint
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------ connect
    @classmethod
    def connect(cls, addr: Tuple[str, int], salt: bytes, *,
                node_id: str = "", window: int = 4,
                timeout: float = 30.0,
                io_timeout_s: Optional[float] = None) -> "SocketTransport":
        """Dial a :class:`StoreServer` and run the salt handshake.

        Server sends ``HELLO{proto, node_id, nonce_s}``; we answer
        ``AUTH{node_id, nonce_c, proof}`` where the proof is
        keyed-BLAKE2b(salt, nonce_s‖nonce_c‖"client"); the server's
        ``AUTH_OK`` carries the mirrored proof so auth is mutual.

        ``timeout`` bounds the dial; ``io_timeout_s`` (default: same) is
        the per-recv/send deadline for the channel's lifetime — a hung
        or half-open peer raises :class:`TransportError` instead of
        wedging a wake or migration thread forever."""
        sock = socket.create_connection(addr, timeout=timeout)
        sock.settimeout(timeout if io_timeout_s is None else io_timeout_s)
        try:
            t = cls(sock, window=window)
            mt, payload = _read_frame(sock)
            if mt != MSG_HELLO:
                raise AuthError("peer did not speak HELLO")
            hello = wire.decode_value(payload)
            if hello.get("proto") != PROTOCOL_VERSION:
                raise TransportError(
                    f"protocol {hello.get('proto')} != "
                    f"{PROTOCOL_VERSION}")
            nonce_s = hello["nonce"]
            nonce_c = os.urandom(16)
            _write_frame(sock, MSG_AUTH, wire.encode_value({
                "node_id": node_id, "nonce": nonce_c,
                "proof": _salt_proof(salt, nonce_s, nonce_c, b"client"),
            }))
            mt, payload = _read_frame(sock)
            if mt == MSG_ERR:
                raise AuthError(wire.decode_value(payload).get(
                    "error", "auth rejected"))
            if mt != MSG_AUTH_OK:
                raise AuthError("handshake out of order")
            ok = wire.decode_value(payload)
            want = _salt_proof(salt, nonce_c, nonce_s, b"server")
            if not hmac.compare_digest(ok.get("proof", b""), want):
                raise AuthError("server failed the salt proof — "
                                "different deployment")
            t.target_node_id = hello.get("node_id") or None
            t._salt_fp = hashlib.blake2b(salt, digest_size=16).digest()
            return t
        except BaseException:
            sock.close()
            raise

    # ------------------------------------------------------------ helpers
    def authenticate(self, salt: bytes) -> None:
        fp = hashlib.blake2b(salt, digest_size=16).digest()
        if self._salt_fp is None or not hmac.compare_digest(
                fp, self._salt_fp):
            raise AuthError("channel was authenticated for a different "
                            "deployment salt")

    def _recv_ack(self, expect: int):
        mt, payload = _read_frame(self.sock)
        if mt == MSG_ERR:
            raise TransportError(
                wire.decode_value(payload).get("error", "peer error"))
        if mt != expect:
            raise TransportError(f"unexpected frame 0x{mt:02x} "
                                 f"(wanted 0x{expect:02x})")
        return wire.decode_value(payload)

    def _drain(self, down_to: int = 0) -> None:
        while self._unacked > down_to:
            self._recv_ack(MSG_SEGMENTS_OK)
            self._unacked -= 1

    # ----------------------------------------------------------- Transport
    def missing_digests(self, digests: List[bytes]) -> List[bytes]:
        with self._lock:
            self._drain()
            _write_frame(self.sock, MSG_MISSING,
                         wire.encode_value(list(digests)))
            resp = self._recv_ack(MSG_MISSING_OK)
        out = []
        for d in resp:
            if not isinstance(d, bytes):
                raise TransportError("malformed MISSING_OK")
            out.append(d)
        return out

    def send_segments(self, items) -> int:
        payload = wire.encode_segments(items)
        with self._lock:
            self._drain(self.window - 1)    # credit window
            _write_frame(self.sock, MSG_SEGMENTS, payload)
            self._unacked += 1
        return sum(len(p) for _, _, _, p in items)

    def barrier(self) -> None:
        with self._lock:
            self._drain()

    def send_bundle(self, bundle) -> None:
        with self._lock:
            self._drain()
            _write_frame(self.sock, MSG_BUNDLE, wire.encode_bundle(bundle))
            self._recv_ack(MSG_BUNDLE_OK)

    def sweep_orphans(self, digests: List[bytes]) -> int:
        with self._lock:
            self._drain()
            _write_frame(self.sock, MSG_SWEEP,
                         wire.encode_value(list(digests)))
            resp = self._recv_ack(MSG_SWEEP_OK)
        return int(resp.get("freed", 0))

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            try:
                self._drain()
                _write_frame(self.sock, MSG_BYE, b"")
            except TransportError:
                pass
            finally:
                self.sock.close()


class StoreServer:
    """Accept loop exposing one node's store (and bundle admission) to
    authenticated peers.  One thread per connection; frames within a
    connection are processed strictly in order, which is what makes the
    client's credit window a real backpressure signal (an ack means the
    segments are on disk, not merely buffered)."""

    def __init__(self, store, *, node_id: str = "",
                 bundle_handler: Optional[Callable] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 io_timeout_s: float = 60.0,
                 max_frame_bytes: Optional[int] = None):
        self.store = store
        self.node_id = node_id
        self.bundle_handler = bundle_handler
        #: per-recv/send deadline on every connection: a peer that stops
        #: mid-frame is closed (and its orphan imports swept) instead of
        #: pinning a server thread forever
        self.io_timeout_s = io_timeout_s
        #: bound on the *declared* frame length this server will honour
        #: (clamped to the protocol cap) — rejected before allocation
        self.max_frame_bytes = (wire.MAX_FRAME_BYTES
                                if max_frame_bytes is None
                                else max_frame_bytes)
        self._listener = socket.create_server((host, port))
        self.address: Tuple[str, int] = self._listener.getsockname()[:2]
        self._closing = threading.Event()
        self._conns: List[socket.socket] = []
        self._lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self.auth_failures = 0
        self.transfers = 0
        self.orphans_swept = 0
        self.protocol_errors = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"store-server-{node_id or self.address[1]}")
        self._accept_thread.start()

    # ------------------------------------------------------------- accept
    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return                      # listener closed
            with self._lock:
                if self._closing.is_set():
                    sock.close()
                    return
                self._conns.append(sock)
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 daemon=True, name="store-peer-conn")
            t.start()
            self._threads.append(t)

    def _serve_conn(self, sock: socket.socket) -> None:
        imported: set = set()
        try:
            sock.settimeout(self.io_timeout_s)
            if not self._handshake(sock):
                return
            while True:
                try:
                    mt, payload = _read_frame(sock, self.max_frame_bytes)
                except ProtocolError as e:
                    # oversized/garbled length prefix: framing is gone —
                    # protocol error, close (finally sweeps imports)
                    self._protocol_error(sock, e)
                    return
                except TransportError:
                    return                  # peer vanished: finally sweeps
                if mt == MSG_BYE:
                    return
                try:
                    self._dispatch(sock, mt, payload, imported)
                except (wire.WireError, ProtocolError) as e:
                    # undecodable payload: the stream cannot be trusted
                    # to be frame-aligned — same treatment
                    self._protocol_error(sock, e)
                    return
                except (KeyError, TransportError, RuntimeError) as e:
                    # application error: the frame itself was well-formed,
                    # so reply and keep serving the connection
                    _write_frame(sock, MSG_ERR, wire.encode_value(
                        {"error": f"{type(e).__name__}: {e}"}))
        except (wire.WireError, ProtocolError) as e:
            self._protocol_error(sock, e)   # garbage during handshake
        except (OSError, TransportError):
            pass
        finally:
            # crash consistency: a connection that dies after importing
            # but before its bundle was adopted leaves orphans — reclaim
            if imported:
                self.orphans_swept += len(imported)
                self.store.sweep_orphans(imported)
            sock.close()
            with self._lock:
                if sock in self._conns:
                    self._conns.remove(sock)

    def _protocol_error(self, sock: socket.socket, e: Exception) -> None:
        """Per-connection protocol failure: count it, best-effort tell
        the peer, and let the caller close the connection.  The accept
        loop is untouched — one garbage peer never takes the server
        down."""
        self.protocol_errors += 1
        try:
            _write_frame(sock, MSG_ERR, wire.encode_value(
                {"error": f"protocol error: {e}"}))
        except TransportError:
            pass

    def _handshake(self, sock: socket.socket) -> bool:
        nonce_s = os.urandom(16)
        _write_frame(sock, MSG_HELLO, wire.encode_value({
            "proto": PROTOCOL_VERSION, "node_id": self.node_id,
            "nonce": nonce_s}))
        mt, payload = _read_frame(sock, self.max_frame_bytes)
        if mt != MSG_AUTH:
            self.auth_failures += 1
            _write_frame(sock, MSG_ERR,
                         wire.encode_value({"error": "expected AUTH"}))
            return False
        auth = wire.decode_value(payload)
        nonce_c = auth.get("nonce", b"")
        want = _salt_proof(self.store.salt, nonce_s, nonce_c, b"client")
        if not isinstance(nonce_c, bytes) or not hmac.compare_digest(
                auth.get("proof", b""), want):
            self.auth_failures += 1
            _write_frame(sock, MSG_ERR, wire.encode_value(
                {"error": "salt proof failed: different deployment"}))
            return False
        _write_frame(sock, MSG_AUTH_OK, wire.encode_value({
            "proof": _salt_proof(self.store.salt, nonce_c, nonce_s,
                                 b"server")}))
        return True

    def _dispatch(self, sock, mt: int, payload: bytes,
                  imported: set) -> None:
        if mt == MSG_MISSING:
            digests = wire.decode_value(payload)
            _write_frame(sock, MSG_MISSING_OK, wire.encode_value(
                self.store.missing_digests(digests)))
        elif mt == MSG_SEGMENTS:
            items = wire.decode_segments(payload)
            new = self.store.import_segments(items)
            imported.update(new)
            _write_frame(sock, MSG_SEGMENTS_OK,
                         wire.encode_value({"imported": len(new)}))
        elif mt == MSG_BUNDLE:
            if self.bundle_handler is None:
                raise TransportError("node does not accept migrations")
            bundle = wire.decode_bundle(payload)
            self.bundle_handler(bundle)
            imported.clear()                # adopted: no longer orphans
            self.transfers += 1
            _write_frame(sock, MSG_BUNDLE_OK, wire.encode_value({}))
        elif mt == MSG_SWEEP:
            digests = wire.decode_value(payload)
            freed = self.store.sweep_orphans(digests)
            imported.difference_update(digests)
            _write_frame(sock, MSG_SWEEP_OK,
                         wire.encode_value({"freed": freed}))
        else:
            raise TransportError(f"unknown message 0x{mt:02x}")

    # -------------------------------------------------------------- close
    def close(self) -> None:
        self._closing.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        self._accept_thread.join(timeout=5.0)
        for t in self._threads:
            t.join(timeout=5.0)

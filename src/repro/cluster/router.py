"""Hibernate-aware cluster routing: placement, rebalancing, handoff.

The single-node governor can only deflate tenants, never move them — one
hot node evicts to TERMINATED while a neighbour idles.  The router adds
the missing degrees of freedom:

  * **Placement** — a new tenant lands on the node scoring best on
    ``(headroom + affinity) / (1 + imminent wake burden)``: budget
    headroom keeps hot nodes from collecting more tenants, digest-overlap
    affinity prefers nodes whose CAS store already holds the
    deployment's base-weight segments (wakes read local disk, and a
    later migration of this tenant ships ~zero bytes), and the
    imminent-wake burden (per-rung wake-cost EWMA x predicted-idle EWMA,
    both from the node governors) steers away from nodes about to pay
    wake storms.
  * **Cluster-escalated governor** — each rebalance round runs every
    node's own ladder first; a node still breaching its budget for
    ``sustained_breach_rounds`` consecutive rounds escalates: its most
    idle migratable tenants are shipped to the peer maximising

        bytes_freed * predicted_idle
        / (transfer_bytes_missing / link_bw + wake_cost)

    and only if no peer can take them does the router fall back to
    TERMINATED eviction (the old single-node behaviour, kept as the
    ``migration=False`` baseline).
  * **Handoff** — requests racing a migration block on the transfer
    handle (``ensure_awake`` on a MIGRATING tenant), then reroute to the
    tenant's new node; the async platforms get a ``reroute`` hook so
    queued work follows the tenant too.
  * **Elasticity** — with ``ClusterPolicy.elastic`` and a
    ``node_factory``, the router grows and shrinks the node set:
    scale-out spins up a node when the forecast aggregate demand (bytes
    deflated tenants are predicted to re-occupy within the horizon)
    exceeds cluster headroom, warming its CAS store with the hottest
    deployments' digests so digest-affinity placement lands near-free;
    scale-in drains the emptiest node by mass-migrating its tenants
    through the normal migration path before decommission, fenced by
    the failure detector so drain and dead-node recovery never race.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.cluster.health import (FailureDetector, HealthPolicy,
                                  NodeHealth)
from repro.cluster.migrate import (MigrationError, MigrationHandle,
                                   migrate_instance, receive_bundle,
                                   replicate_instance)
from repro.cluster.node import Node
from repro.core.governor import MIGRATABLE_STATES
from repro.core.prefix import PREFIX_OWNER
from repro.core.state import ContainerState, Rung
from repro.core.store import CorruptSegmentError
from repro.serving.engine import (NodeDownError, Request, Response,
                                  TenantMigrated)
from repro.serving.scheduler import PlatformPolicy

S = ContainerState


@dataclass
class ClusterPolicy:
    """Cluster-tier policy: rebalance escalation, placement weights,
    replication, failure detection, and elasticity knobs.  One instance
    per :class:`ClusterRouter`; every field has a safe default, so
    callers override only what their deployment tunes."""

    #: consecutive rebalance rounds a node must breach before escalation
    sustained_breach_rounds: int = 2
    #: master switch: False reproduces the single-node evict-only world
    #: (the benchmark's no-migration baseline)
    migration: bool = True
    #: cap per (node, round) — a rebalance must not stampede the link
    max_migrations_per_round: int = 2
    #: weight of digest-overlap affinity in placement scoring
    affinity_weight: float = 1.0
    #: weight of resident-prefix affinity in placement scoring: a node
    #: whose registry already serves the deployment's shared prompts lets
    #: new sessions COW-adopt instead of prefilling (TTFT win), so it
    #: outranks an equally-empty node without the prefixes
    prefix_affinity_weight: float = 1.0
    #: weight of zygote affinity in placement scoring: a node holding a
    #: live fork donor of the tenant's family admits it by warm fork
    #: (memcpy + inherited executables) instead of a cold init, so it
    #: outranks an equally-empty node without one
    zygote_affinity_weight: float = 1.0
    #: placement looks this far ahead for imminent wakes (seconds)
    imminent_horizon_s: float = 5.0
    #: after migration fails to clear a sustained breach, TERMINATED
    #: eviction of idle hibernated tenants remains the last resort
    terminate_last_resort: bool = True
    #: per-tenant damping: a tenant that just migrated is not a victim
    #: again for this long — without it two alternating-breach nodes
    #: ping-pong the same idle tenant (each move *causes* the next
    #: breach on the receiver)
    migration_cooldown_s: float = 30.0
    #: breach-streak hysteresis as a fraction of the node budget: a
    #: node's sustained-breach counter only resets once pressure clears
    #: by this margin, so hovering at the budget edge doesn't restart
    #: the streak every other round
    breach_hysteresis: float = 0.0
    #: transfer failures: try the next-best target up to this many more
    #: times before giving up on the victim this round
    migration_retries: int = 2
    #: a target that failed a transfer is skipped for this long
    blacklist_cooldown_s: float = 60.0
    #: each rebalance round sweeps imported-but-never-adopted store
    #: segments older than this (a peer that died mid-transfer without
    #: aborting leaves them; see ``SwapStore.sweep_orphans``)
    orphan_max_age_s: float = 300.0
    #: failure domain: how many stores must hold every hibernated
    #: tenant's digests (home + k-1 replicas); 1 disables replication
    replication_factor: int = 2
    #: anti-entropy cap per round — replication rides the same link the
    #: serve path uses, so it must not stampede either
    max_replications_per_round: int = 4
    #: lease/heartbeat tuning for the failure detector (None = defaults)
    health: Optional[HealthPolicy] = None
    #: master switch for cluster elasticity: with it on (and a
    #: ``node_factory`` wired), :meth:`ClusterRouter.rebalance` runs an
    #: :meth:`ClusterRouter.autoscale` pass each round
    elastic: bool = False
    #: demand window: deflated tenants predicted to wake within this
    #: horizon contribute their inflate-footprint to aggregate demand
    scale_horizon_s: float = 10.0
    #: scale out when demand exceeds cluster headroom by this margin
    #: (bytes); a small positive margin avoids spinning up a node for a
    #: rounding error
    scale_out_margin_bytes: int = 0
    #: scale in only while (headroom - demand - emptiest node's budget)
    #: stays above this reserve — the cluster must still absorb the
    #: forecast after losing the node
    scale_in_reserve_bytes: int = 0
    #: consecutive low-utilization autoscale rounds before a drain
    #: actually starts (scale-in is expensive and hard to undo cheaply,
    #: so it gets the same sustained-signal treatment as migration)
    scale_in_sustained_rounds: int = 3
    #: elasticity floor/ceiling on the node count (0 = unbounded ceiling)
    min_nodes: int = 1
    max_nodes: int = 0
    #: pre-ship the hottest deployments' CAS digests to a fresh node so
    #: digest-affinity placement/migration lands near-free
    warm_on_scale_out: bool = True
    #: cap on warm-shipped stored bytes per scale-out
    warm_bytes_limit: int = 256 << 20


class ClusterRouter:
    """Places tenant requests across N :class:`Node`\\ s and owns the
    cluster tier of the deflation ladder (MIGRATING)."""

    def __init__(self, nodes: Sequence[Node],
                 arch_of: Optional[Dict[str, str]] = None,
                 policy: Optional[ClusterPolicy] = None,
                 node_factory: Optional[Callable[[str], Node]] = None):
        if not nodes:
            raise ValueError("a cluster needs at least one node")
        self.nodes: Dict[str, Node] = {n.node_id: n for n in nodes}
        self.arch_of: Dict[str, str] = dict(arch_of or {})
        self.policy = policy or ClusterPolicy()
        #: builds a fresh :class:`Node` for scale-out (None = no
        #: elasticity even with ``policy.elastic``)
        self.node_factory = node_factory
        #: tenant -> node_id (updated at placement and migration commit)
        self.placement: Dict[str, str] = {}
        self.handles: List[MigrationHandle] = []
        self.log: List[tuple] = []
        #: TERMINATED evictions the cluster tier had to fall back to —
        #: each one is a tenant destroyed (its next request is a cold
        #: start); the migration tier exists to keep this at zero
        self.evictions = 0
        self._breach: Dict[str, int] = {nid: 0 for nid in self.nodes}
        #: tenant -> commit time of its last migration (cooldown damping)
        self._cooldown: Dict[str, float] = {}
        #: node_id -> timestamp until which it is skipped as a target
        self._blacklist: Dict[str, float] = {}
        self.cooldown_skips = 0
        self.migration_retries = 0
        #: failure domain: lease detector over the node set; DEAD
        #: transitions trigger :meth:`recover_node`
        self.detector = FailureDetector(self.nodes,
                                        self.policy.health)
        self.tenants_rehomed = 0
        self.tenants_lost = 0          # no complete replica anywhere
        self.replications = 0
        self.repairs_served = 0        # scrub/read repairs fed from peers
        #: nodes mid-drain: still serving what they have, but excluded
        #: as placement/migration/replication targets
        self._draining: Set[str] = set()
        #: digests warm-shipped to a scale-out node, pinned in its store
        #: until tenants adopt them (node_id -> digests)
        self._warm_pins: Dict[str, Set[bytes]] = {}
        self._scale_seq = 0
        self._low_util_rounds = 0
        self.scale_outs = 0
        self.scale_ins = 0
        self.warm_bytes_shipped = 0
        self._lock = threading.RLock()
        for n in nodes:
            if n.platform is not None:
                n.platform.reroute = self._reroute
            if n.store is not None:
                n.store.repair_source = self._make_repair_source(n)

    # ------------------------------------------------------------ health
    def alive_nodes(self) -> List[Node]:
        """Nodes usable as placement/replication/migration targets:
        detector-ALIVE and actually answering."""
        return [self.nodes[nid] for nid in self.detector.alive_ids()
                if self.nodes[nid].alive]

    def target_nodes(self) -> List[Node]:
        """Alive nodes that may *receive* new work: a draining node keeps
        serving and keeps its replicas readable (recovery still counts
        it as a holder), but takes no new tenants, migrations, or
        replicas — otherwise the drain chases its own tail."""
        return [n for n in self.alive_nodes()
                if n.node_id not in self._draining]

    def check_health(self, now: Optional[float] = None) -> List[tuple]:
        """One heartbeat + lease round: beat every node that answers,
        expire lapsed leases, and run recovery for every node that
        crossed into DEAD.  Returns the detector transitions."""
        now = time.monotonic() if now is None else now
        for nid, node in self.nodes.items():
            if node.ping():
                self.detector.beat(nid, now)
        fired = self.detector.step(now)
        for nid, old, new in fired:
            self.log.append((now, "health", nid, old.value, new.value))
            if new is NodeHealth.DEAD:
                self.recover_node(nid, now)
        return fired

    def _node_down(self, nid: str, now: float) -> None:
        """Direct failure evidence beats the lease timers: walk the
        detector to DEAD and recover immediately."""
        was_dead = self.detector.is_dead(nid)
        state = self.detector.observe_failure(nid, now)
        if state is NodeHealth.DEAD and not was_dead:
            self.log.append((now, "health", nid, "evidence", "dead"))
            self.recover_node(nid, now)

    def _make_repair_source(self, node: Node):
        """Wire a node store's ``repair_source`` hook: fetch a verified
        copy of a digest from any *other* alive node's store.  The
        store re-verifies the content address before installing, so this
        only has to find bytes, not vouch for them."""
        def fetch(digest: bytes):
            for peer in self.alive_nodes():
                if peer is node or peer.store is None:
                    continue
                try:
                    items = peer.store.export_segments([digest])
                except (KeyError, CorruptSegmentError):
                    continue
                if items:
                    self.repairs_served += 1
                    _, level, raw_nbytes, payload = items[0]
                    return level, raw_nbytes, payload
            return None
        return fetch

    # ------------------------------------------------------------ recovery
    def recover_node(self, nid: str, now: Optional[float] = None
                     ) -> List[tuple]:
        """Re-home every tenant the dead node held onto survivors, from
        replicated segments — never from the dead node's own disk.

        For each tenant: the best-scoring alive holder of a *complete*
        replica adopts the bundle through :func:`receive_bundle` (the
        exact path migration commits through, so post-recovery wakes are
        byte-identical to pre-crash wakes), then drops its replica pins —
        the adoption's refcounts carry the segments now.  A tenant with
        no complete replica anywhere is lost: its placement is cleared
        and the next request cold-starts it fresh."""
        now = time.monotonic() if now is None else now
        dead = self.nodes[nid]
        acts: List[tuple] = []
        with self._lock:
            homed = [iid for iid, home in self.placement.items()
                     if home == nid]
        for iid in homed:
            holders: List[Tuple[Node, object]] = []
            for peer in self.alive_nodes():
                rec = peer.replicas.get(iid)
                if rec is None or peer.store is None:
                    continue
                if peer.store.missing_digests(rec.digests):
                    continue               # incomplete/corrupt: not a holder
                holders.append((peer, rec))
            if not holders:
                with self._lock:
                    self.placement.pop(iid, None)
                self.tenants_lost += 1
                acts.append(("lost", iid))
                self.log.append((now, "tenant_lost", iid, nid))
                continue
            arch = self.arch_of.get(iid, "")
            digests = self.deployment_digests(arch)
            pfx = self.deployment_prefix_digests(arch)
            holder, rec = max(
                holders, key=lambda hr: self.placement_score(
                    hr[0], arch, now, digests=digests, prefix_digests=pfx))
            receive_bundle(holder, rec.bundle)
            holder.drop_replica(iid)       # adoption's refs carry it now
            with self._lock:
                self.placement[iid] = holder.node_id
            self.tenants_rehomed += 1
            acts.append(("rehome", iid, holder.node_id))
            self.log.append((now, "rehome", iid, nid, holder.node_id))
        # replicas the dead node held FOR survivors are gone with its
        # disk; the next anti-entropy round re-replicates those tenants
        dead.replicas.clear()
        return acts

    # ------------------------------------------------------------ placement
    def deployment_digests(self, arch_key: str) -> frozenset:
        """Union of CAS digests referenced by every tenant of this
        deployment cluster-wide — the content a new/migrated tenant of
        the same arch will eventually need on its node's disk."""
        out = set()
        for node in self.nodes.values():
            store = node.store
            if store is None or not node.alive:
                continue
            with node.manager._lock:
                iids = list(node.manager.instances)
            for iid in iids:
                if self.arch_of.get(iid) != arch_key:
                    continue
                inst = node.manager.instances.get(iid)
                if inst is None or not hasattr(inst.swap_file, "extents"):
                    continue
                out.update(m.digest
                           for m in store.export_meta(inst.swap_file).values()
                           if getattr(m, "digest", None) is not None)
        return frozenset(out)

    def deployment_prefix_digests(self, arch_key: str) -> frozenset:
        """Union of prefix-registry digests for this arch cluster-wide —
        the shared prompts a new tenant's sessions are likely to reuse."""
        out = set()
        for node in self.nodes.values():
            reg = node.manager.prefix_registry
            if reg is None or not node.alive:
                continue
            for d in reg.digests():
                e = reg.get(d)
                if e is not None and e.arch_key == arch_key:
                    out.add(d)
        return frozenset(out)

    def placement_score(self, node: Node, arch_key: str, now: float,
                        digests: Optional[frozenset] = None,
                        prefix_digests: Optional[frozenset] = None) -> float:
        """Higher is better: budget headroom plus digest-overlap
        affinity plus resident-prefix affinity, discounted by the node's
        imminent wake burden.  ``digests``/``prefix_digests`` let callers
        scoring many nodes compute the cluster-wide inventories once."""
        if digests is None:
            digests = self.deployment_digests(arch_key)
        if prefix_digests is None:
            prefix_digests = self.deployment_prefix_digests(arch_key)
        affinity = node.digest_overlap_bytes(digests)
        prefix_affinity = node.prefix_overlap_bytes(prefix_digests)
        zygote_affinity = node.zygote_bytes(arch_key)
        headroom = max(node.headroom_bytes(), 0)
        burden = node.imminent_wake_burden_s(
            now, self.policy.imminent_horizon_s)
        return (headroom + self.policy.affinity_weight * affinity
                + self.policy.prefix_affinity_weight * prefix_affinity
                + self.policy.zygote_affinity_weight * zygote_affinity) \
            / (1.0 + burden)

    def place(self, instance_id: str, arch_key: str, *,
              shared_paths=None, now: Optional[float] = None) -> Node:
        """Pick a node for a new tenant and admit it there — by warm
        fork when the node holds a live zygote of the family (the
        zygote-affinity term steered placement toward one), by classic
        cold start otherwise."""
        now = time.monotonic() if now is None else now
        with self._lock:
            if instance_id in self.placement:
                return self.nodes[self.placement[instance_id]]
            self.arch_of.setdefault(instance_id, arch_key)
            digests = self.deployment_digests(arch_key)
            pfx = self.deployment_prefix_digests(arch_key)
            candidates = self.target_nodes() or self.alive_nodes() \
                or list(self.nodes.values())
            best = max(candidates,
                       key=lambda n: self.placement_score(
                           n, arch_key, now, digests=digests,
                           prefix_digests=pfx))
            self.placement[instance_id] = best.node_id
        if best.engine.fork_instance(instance_id, arch_key,
                                     shared_paths=shared_paths) is not None:
            self.log.append((now, "place_fork", instance_id, best.node_id))
        else:
            best.engine.start_instance(instance_id, arch_key,
                                       shared_paths=shared_paths)
            self.log.append((now, "place", instance_id, best.node_id))
        return best

    def node_of(self, instance_id: str) -> Optional[Node]:
        """The tenant's current home node (None if never placed)."""
        nid = self.placement.get(instance_id)
        return self.nodes.get(nid) if nid is not None else None

    # ------------------------------------------------------------ serving
    def handle(self, req: Request, now: Optional[float] = None) -> Response:
        """Synchronous serve path (virtual-time benchmarks): route to the
        tenant's node; a request racing a migration blocks on the
        transfer inside the engine, raises :class:`TenantMigrated`, and
        is re-dispatched to the tenant's new node."""
        now = time.monotonic() if now is None else now
        iid = req.instance_id
        observed = False
        for _ in range(len(self.nodes) + 2):
            node = self.node_of(iid)
            if node is None:
                node = self.place(iid, self.arch_of[iid], now=now)
            if not node.alive:
                # direct evidence: the home crashed — recovery re-homes
                # the tenant from a replica (or clears the placement so
                # the next loop iteration cold-starts it on a survivor)
                self._node_down(node.node_id, now)
                continue
            if not observed:
                # exactly once per request: a handoff retry must not
                # re-feed the same arrival (a zero gap would collapse
                # the tenant's inter-arrival EWMA toward "imminent")
                node.manager.governor.observe_arrival(iid, now=now)
                observed = True
            try:
                return node.engine.handle(req)
            except TenantMigrated as e:
                with self._lock:
                    if e.target is not None:
                        self.placement[iid] = e.target
                self.log.append((now, "handoff", iid, e.target))
                continue
        raise RuntimeError(f"request for {iid} chased migrations too long")

    def submit(self, req: Request):
        """Async serve path: enqueue on the tenant's node's platform."""
        for _ in range(len(self.nodes) + 1):
            node = self.node_of(req.instance_id)
            if node is None:
                node = self.place(req.instance_id,
                                  self.arch_of[req.instance_id])
            if not node.alive:
                self._node_down(node.node_id, time.monotonic())
                continue
            if node.platform is None:
                raise RuntimeError(f"node {node.node_id} has no platform "
                                   "(call Node.start_platform)")
            return node.platform.submit(req)
        raise NodeDownError(f"no alive node for {req.instance_id}")

    def _reroute(self, iid: str, reqs, futs) -> bool:
        """AsyncPlatform hook: a worker hit ``TenantMigrated`` — chase
        the tenant to its new node and chain the original futures."""
        node = self.node_of(iid)
        if node is None or node.platform is None:
            return False
        for req, fut in zip(reqs, futs):
            tgt = node.platform.submit(req)

            def _chain(done, fut=fut):
                if fut.done():
                    return
                err = done.exception()
                if err is not None:
                    fut.set_exception(err)
                else:
                    fut.set_result(done.result())
            tgt.add_done_callback(_chain)
        return True

    def start_platforms(self, policy: PlatformPolicy,
                        workers: int = 2) -> None:
        """Run every node event-driven and wire the reroute hooks."""
        for node in self.nodes.values():
            node.start_platform(policy, self.arch_of, workers=workers)
            node.platform.reroute = self._reroute

    # ------------------------------------------------------------ migration
    def migrate(self, instance_id: str, target_node_id: str, *,
                block: bool = True) -> MigrationHandle:
        """Ship one tenant to a named node through the three-phase
        transfer (placement commits with the bundle; see
        :func:`repro.cluster.migrate.migrate_instance`)."""
        src = self.node_of(instance_id)
        if src is None:
            raise MigrationError(f"{instance_id}: unknown tenant")
        dst = self.nodes[target_node_id]
        if dst is src:
            raise MigrationError(f"{instance_id}: already on "
                                 f"{target_node_id}")

        def commit():
            with self._lock:
                self.placement[instance_id] = target_node_id

        h = migrate_instance(
            src, dst, instance_id, self.arch_of[instance_id],
            link_bw_bytes_s=min(src.link_bw_bytes_s, dst.link_bw_bytes_s),
            on_commit=commit, block=block)
        self.handles.append(h)
        self.log.append((time.monotonic(), "migrate", instance_id,
                         src.node_id, target_node_id))
        return h

    def _tenant_digests(self, node: Node, inst) -> frozenset:
        if node.store is None or not hasattr(inst.swap_file, "extents"):
            return frozenset()
        out = {m.digest
               for m in node.store.export_meta(inst.swap_file).values()
               if m.digest is not None}
        # prefix segments the tenant's sessions share travel with the
        # bundle (write-through content-addressed them at registration) —
        # a target already holding them receives metadata only, so the
        # dedup-aware transfer scoring must see their digests too
        reg = node.manager.prefix_registry
        if reg is not None:
            wanted = set(reg.digests_for_instance(inst.instance_id))
            if wanted:
                client = node.store.client(PREFIX_OWNER)
                out.update(
                    m.digest
                    for k, m in node.store.export_meta(client).items()
                    if k[1] in wanted and m.digest is not None)
        return frozenset(out)

    def _best_target(self, src: Node, inst, freed: int, idle: float,
                     now: float, exclude=()) -> Optional[Tuple[Node, float]]:
        """Highest migration score among peers with room for the husk.
        Blacklisted targets (recent transfer failures) and ``exclude``
        (targets already tried for this victim) are skipped."""
        gov = src.governor
        digests = self._tenant_digests(src, inst)
        stored = src.store.stored_bytes_of(digests) if src.store else 0
        # anon bytes still resident (MMAP_CLEAN/PARTIAL sources) are not
        # content-addressed yet: assume they ship (conservative) — for
        # the typical HIBERNATED victim this term is zero
        unstored = gov._anon_resident_bytes(inst)
        best: Optional[Tuple[Node, float]] = None
        for node in self.target_nodes():
            if node is src or node.node_id in exclude:
                continue
            if self._blacklist.get(node.node_id, -1e18) > now:
                continue
            # the husk lands hibernated: the target pays its metadata now
            if node.headroom_bytes() < inst.metadata_bytes():
                continue
            overlap = node.digest_overlap_bytes(digests)
            missing = max(stored - overlap, 0) + unstored
            score = gov.migration_score(
                freed, idle, missing,
                min(src.link_bw_bytes_s, node.link_bw_bytes_s))
            if best is None or score > best[1]:
                best = (node, score)
        return best

    # ------------------------------------------------------------ elasticity
    def add_node(self, node: Node, now: Optional[float] = None) -> None:
        """Admit a node into the fabric: failure-detector lease (starts
        ALIVE, fresh), breach counter, reroute + repair-source hooks.
        Used by scale-out, and directly by operators pre-provisioning
        capacity."""
        now = time.monotonic() if now is None else now
        with self._lock:
            if node.node_id in self.nodes:
                raise ValueError(f"node {node.node_id!r} already in "
                                 "cluster")
            self.nodes[node.node_id] = node
            self._breach[node.node_id] = 0
            self.detector.add_node(node.node_id, now)
        if node.platform is not None:
            node.platform.reroute = self._reroute
        if node.store is not None:
            node.store.repair_source = self._make_repair_source(node)
        self.log.append((now, "add_node", node.node_id))

    def scale_out(self, now: Optional[float] = None) -> Optional[Node]:
        """Spin up one node through ``node_factory`` and admit it; with
        ``warm_on_scale_out`` its CAS store is pre-shipped the hottest
        deployments' digests so digest-affinity placement/migration to
        it is near-free.  Returns None when no factory is wired or the
        ``max_nodes`` ceiling is hit."""
        now = time.monotonic() if now is None else now
        if self.node_factory is None:
            return None
        if self.policy.max_nodes and len(self.nodes) >= \
                self.policy.max_nodes:
            return None
        with self._lock:
            while True:
                self._scale_seq += 1
                nid = f"scale{self._scale_seq}"
                if nid not in self.nodes:
                    break
        node = self.node_factory(nid)
        self.add_node(node, now)
        # match the fleet: if peers run event-driven, the new node must
        # too, or submit() to a tenant placed there has no queue
        peer = next((n for n in self.nodes.values()
                     if n is not node and n.platform is not None), None)
        if peer is not None and node.platform is None:
            node.start_platform(peer.platform.policy, self.arch_of)
            node.platform.reroute = self._reroute
        if self.policy.warm_on_scale_out:
            self._warm_node(node, now)
        self.scale_outs += 1
        self.log.append((now, "scale_out", nid))
        return node

    def _warm_node(self, node: Node, now: float) -> int:
        """CAS-warm a fresh node: ship every deployment's segments from
        whichever peers hold them (capped by ``warm_bytes_limit``) and
        pin them as replicas — pins survive GC until tenants adopt the
        content, so the orphan sweeper never undoes the warm-up."""
        if node.store is None:
            return 0
        budget = self.policy.warm_bytes_limit
        shipped = 0
        pins = self._warm_pins.setdefault(node.node_id, set())
        for arch in sorted(set(self.arch_of.values())):
            if budget <= 0:
                break
            missing = set(node.store.missing_digests(
                self.deployment_digests(arch)))
            for peer in self.alive_nodes():
                if not missing or budget <= 0:
                    break
                if peer is node or peer.store is None:
                    continue
                have = missing - set(peer.store.missing_digests(missing))
                take: List[bytes] = []
                for d in sorted(have):
                    nb = peer.store.stored_bytes_of([d])
                    if nb > budget:
                        continue
                    take.append(d)
                    budget -= nb
                if not take:
                    continue
                try:
                    items = peer.store.export_segments(take)
                except (KeyError, CorruptSegmentError):
                    continue
                installed = node.store.import_segments(items)
                node.store.pin_replicas(installed)
                pins.update(installed)
                nb = node.store.stored_bytes_of(installed)
                shipped += nb
                self.warm_bytes_shipped += nb
                missing -= set(installed)
        if shipped:
            self.log.append((now, "warm", node.node_id, shipped))
        return shipped

    def forecast_demand_bytes(self, now: Optional[float] = None,
                              horizon_s: Optional[float] = None) -> int:
        """Aggregate inflate demand: bytes the cluster's deflated
        tenants are predicted to bring back resident within the horizon
        (each tenant's wake footprint, gated on its predicted gap — with
        forecasting on, the gap is the seasonal/flash-crowd blend, so a
        learned burst shows up here *before* its requests arrive)."""
        now = time.monotonic() if now is None else now
        horizon = self.policy.scale_horizon_s if horizon_s is None \
            else horizon_s
        demand = 0
        for node in self.alive_nodes():
            gov = node.governor
            with node.manager._lock:
                insts = list(node.manager.instances.values())
            for inst in insts:
                if inst.state not in MIGRATABLE_STATES:
                    continue
                gap = gov.predicted_gap(inst.instance_id, now,
                                        last_used=inst.last_used)
                if gap <= horizon:
                    demand += gov.inflate_bytes_estimate(inst.instance_id)
        return demand

    def cluster_headroom_bytes(self) -> int:
        """Spare budget across nodes still accepting work."""
        return sum(max(n.headroom_bytes(), 0) for n in self.target_nodes())

    def autoscale(self, now: Optional[float] = None) -> List[tuple]:
        """One elasticity decision, run from :meth:`rebalance` when
        ``policy.elastic``: scale out when forecast demand exceeds
        cluster headroom (plus margin), drain the emptiest node after
        ``scale_in_sustained_rounds`` consecutive rounds in which the
        cluster could lose it and still hold the forecast plus reserve.
        At most one scale action per round — elasticity must never
        thrash."""
        now = time.monotonic() if now is None else now
        acts: List[tuple] = []
        if not self.policy.elastic or self._draining:
            return acts
        demand = self.forecast_demand_bytes(now)
        headroom = self.cluster_headroom_bytes()
        if demand > headroom + self.policy.scale_out_margin_bytes:
            node = self.scale_out(now)
            if node is not None:
                self._low_util_rounds = 0
                acts.append(("scale_out", node.node_id))
                return acts
        candidates = self.target_nodes()
        if len(candidates) <= max(1, self.policy.min_nodes) or \
                any(n.governor.budget_bytes is None for n in candidates):
            return acts

        def _used(n: Node) -> int:
            return (n.governor.budget_bytes or 0) - n.headroom_bytes()

        emptiest = min(candidates, key=_used)
        spare_after = headroom - demand \
            - (emptiest.governor.budget_bytes or 0)
        if spare_after >= self.policy.scale_in_reserve_bytes:
            self._low_util_rounds += 1
            if self._low_util_rounds >= \
                    self.policy.scale_in_sustained_rounds:
                self._low_util_rounds = 0
                acts += self.drain_node(emptiest.node_id, now)
        else:
            self._low_util_rounds = 0
        return acts

    def drain_node(self, node_id: str,
                   now: Optional[float] = None) -> List[tuple]:
        """Scale-in: re-heal the replicas this node holds for peers,
        mass-migrate every tenant homed here through the normal
        migration path, verify nothing is left, then decommission.

        Fencing against dead-node recovery: drain starts only on a
        detector-ALIVE node, the node is marked draining (no new
        placements/migrations/replicas land on it), and every step
        re-checks liveness — if the node dies mid-drain the drain stops
        immediately, walks the detector to DEAD, and lets
        :meth:`recover_node` re-home the remainder from replicas.  Each
        tenant is also re-checked against ``placement`` before moving,
        so the two paths can never both ship the same tenant."""
        now = time.monotonic() if now is None else now
        node = self.nodes[node_id]
        with self._lock:
            if node_id in self._draining:
                raise MigrationError(f"drain {node_id}: already draining")
            if self.detector.state(node_id) is not NodeHealth.ALIVE \
                    or not node.alive:
                raise MigrationError(f"drain {node_id}: node is not ALIVE")
            if not [n for n in self.target_nodes() if n is not node]:
                raise MigrationError(f"drain {node_id}: no other node "
                                     "can absorb its tenants")
            self._draining.add(node_id)
        acts: List[tuple] = [("drain_start", node_id)]
        self.log.append((now, "drain_start", node_id))
        try:
            # replicas held FOR peers go first: drop + re-heal elsewhere,
            # so the failure domain never thins out mid-drain
            held = sorted(node.replicas)
            for iid in held:
                node.drop_replica(iid)
            per_round = max(1, self.policy.max_replications_per_round)
            for _ in range(len(held) // per_round + 1):
                if not self.anti_entropy(now):
                    break
            with self._lock:
                homed = [iid for iid, h in self.placement.items()
                         if h == node_id]
            for iid in homed:
                if not node.alive or self.detector.is_dead(node_id):
                    self._node_down(node_id, now)
                    acts.append(("drain_aborted", node_id))
                    return acts
                if self.placement.get(iid) != node_id:
                    continue         # recovery/handoff already moved it
                inst = node.manager.instances.get(iid)
                if inst is None:
                    with self._lock:
                        self.placement.pop(iid, None)
                    continue
                gov = node.governor
                if inst.state is S.WARM:
                    # drain is deliberate: block on the tenant lock and
                    # walk it down to a migratable (content-addressed)
                    # rung so the transfer is dedup-aware
                    with node.engine.instance_lock(iid):
                        if inst.state is S.WARM:
                            node.manager.descend(iid, Rung.HIBERNATED)
                if inst.state not in MIGRATABLE_STATES:
                    acts.append(("drain_stuck", iid))
                    continue
                freed = (gov._anon_resident_bytes(inst)
                         + gov._mmap_benefit(inst)
                         + inst.metadata_bytes())
                idle = gov.predicted_gap(iid, now,
                                         last_used=inst.last_used)
                tried: set = set()
                moved = False
                for _attempt in range(self.policy.migration_retries + 1):
                    pick = self._best_target(node, inst, freed, idle,
                                             now, exclude=tried)
                    if pick is None:
                        break
                    target, _score = pick
                    try:
                        h = self.migrate(iid, target.node_id, block=True)
                    except MigrationError as e:
                        if not node.alive:
                            break     # source died: loop head aborts
                        if getattr(e, "handle", None) is None:
                            break     # raced a request: retry next drain
                        self._blacklist[target.node_id] = \
                            now + self.policy.blacklist_cooldown_s
                        tried.add(target.node_id)
                        self.migration_retries += 1
                        continue
                    if h.ok or h.committed:
                        moved = True
                        acts.append(("drain_migrate", iid, node_id,
                                     target.node_id))
                    break
                if not moved and node.alive:
                    acts.append(("drain_stuck", iid))
            if not node.alive or self.detector.is_dead(node_id):
                self._node_down(node_id, now)
                acts.append(("drain_aborted", node_id))
                return acts
            with self._lock:
                left = [iid for iid, h in self.placement.items()
                        if h == node_id]
            if left:
                # stuck tenants keep the node up; the next autoscale
                # round (or the operator) retries the drain
                acts.append(("drain_incomplete", node_id, len(left)))
                self.log.append((now, "drain_incomplete", node_id,
                                 len(left)))
                return acts
            self.decommission_node(node_id, now)
            self.scale_ins += 1
            acts.append(("scale_in", node_id))
            return acts
        finally:
            self._draining.discard(node_id)

    def decommission_node(self, node_id: str,
                          now: Optional[float] = None) -> None:
        """Remove a fully-drained node from the fabric and release its
        resources.  Refuses while any tenant is still homed there —
        decommission never loses data; that is what makes scale-in safe
        to automate."""
        now = time.monotonic() if now is None else now
        with self._lock:
            if any(h == node_id for h in self.placement.values()):
                raise MigrationError(
                    f"decommission {node_id}: still homes tenants")
            node = self.nodes.pop(node_id)
            self._breach.pop(node_id, None)
            self._blacklist.pop(node_id, None)
            self._warm_pins.pop(node_id, None)
            self._draining.discard(node_id)
        self.detector.remove_node(node_id)
        node.close()
        self.log.append((now, "decommission", node_id))

    # ------------------------------------------------------------ rebalance
    def rebalance(self, now: Optional[float] = None) -> List[tuple]:
        """One cluster governor round per node.

        The sustained-breach signal is the *residual* pressure after the
        node's own rung ladder has done all it can: what remains is
        structural — the husk load (plus anything pinned by in-flight
        serves) exceeds the budget, and no amount of local deflation
        fixes that.  A residual sustained for ``sustained_breach_rounds``
        escalates to migration (most-idle victims to the best-scoring
        peers); TERMINATED eviction runs only when migration is off or
        found no (victim, target) pair this round — strictly the last
        resort, exactly one rung below MIGRATING."""
        now = time.monotonic() if now is None else now
        actions: List[tuple] = []
        for nid, _old, new in self.check_health(now):
            actions.append(("health", nid, new.value))
        for nid, node in self.nodes.items():
            if not node.alive or self.detector.is_dead(nid):
                continue
            gov = node.governor
            if node.store is not None:
                node.store.sweep_orphans(
                    max_age_s=self.policy.orphan_max_age_s)
            gov.step(now=now, try_lock=node.engine.instance_lock)
            pressure = gov.pressure_bytes()
            if pressure <= 0:
                # hysteresis: only a clear with margin resets the streak —
                # a node hovering at the budget edge stays "hot" and
                # escalates on its next breach instead of re-counting
                budget = gov.budget_bytes or 0
                if pressure <= -int(self.policy.breach_hysteresis * budget):
                    self._breach[nid] = 0
                continue
            self._breach[nid] += 1
            if self._breach[nid] < self.policy.sustained_breach_rounds:
                continue
            migrated: List[tuple] = []
            if self.policy.migration:
                migrated = self._migrate_for_pressure(node, now)
                actions += migrated
            if not migrated and gov.pressure_bytes() > 0 \
                    and self.policy.terminate_last_resort:
                actions += self._terminate_for_pressure(node, now)
        actions += self.anti_entropy(now)
        if self.policy.elastic:
            actions += self.autoscale(now)
        if actions:
            self.log.append((now, "rebalance", tuple(actions)))
        return actions

    # ---------------------------------------------------------- replication
    def _replica_holders(self, iid: str, home: Node) -> List[Node]:
        """Alive peers currently holding a complete, verified replica of
        the tenant (incomplete or quarantined copies don't count)."""
        out = []
        for peer in self.alive_nodes():
            if peer is home:
                continue
            rec = peer.replicas.get(iid)
            if rec is None or peer.store is None:
                continue
            if peer.store.missing_digests(rec.digests):
                continue
            out.append(peer)
        return out

    def anti_entropy(self, now: Optional[float] = None) -> List[tuple]:
        """Replication repair round: every alive node's HIBERNATE
        tenants end with >= ``replication_factor - 1`` complete replicas
        on other alive stores.  Runs as part of :meth:`rebalance`, so a
        holder dying is healed on the next round — and because holders
        are re-verified (missing/corrupt digests disqualify), a replica
        rotting on disk is re-shipped the same way.  Capped per round;
        the sustained rounds finish the job."""
        k = self.policy.replication_factor
        if k <= 1:
            return []
        now = time.monotonic() if now is None else now
        acts: List[tuple] = []
        budget = self.policy.max_replications_per_round
        for home in self.alive_nodes():
            if budget <= 0:
                break
            with home.manager._lock:
                tenants = [iid for iid, inst
                           in home.manager.instances.items()
                           if inst.state == S.HIBERNATE]
            # stale replica GC: drop records for tenants that no longer
            # exist anywhere, or that this node is now the home of
            for iid, rec in list(home.replicas.items()):
                if self.placement.get(iid) == home.node_id or \
                        iid not in self.placement:
                    home.drop_replica(iid)
            for iid in tenants:
                if budget <= 0:
                    break
                if self.placement.get(iid) != home.node_id:
                    continue
                holders = self._replica_holders(iid, home)
                need = (k - 1) - len(holders)
                if need <= 0:
                    continue
                arch = self.arch_of.get(iid, "")
                digests = self.deployment_digests(arch)
                pfx = self.deployment_prefix_digests(arch)
                taken = {h.node_id for h in holders}
                targets = sorted(
                    (n for n in self.target_nodes()
                     if n is not home and n.node_id not in taken
                     and n.store is not None),
                    key=lambda n: self.placement_score(
                        n, arch, now, digests=digests,
                        prefix_digests=pfx),
                    reverse=True)
                for tgt in targets[:need]:
                    if budget <= 0:
                        break
                    try:
                        replicate_instance(home, tgt, iid, arch)
                    except MigrationError:
                        continue          # busy serving / state changed
                    self.replications += 1
                    budget -= 1
                    acts.append(("replicate", iid, home.node_id,
                                 tgt.node_id))
                    self.log.append((now, "replicate", iid,
                                     home.node_id, tgt.node_id))
        return acts

    def _migrate_for_pressure(self, node: Node, now: float) -> List[tuple]:
        gov = node.governor
        acts: List[tuple] = []
        # a couple of victims per round (the link must not stampede);
        # the sustained streak keeps rounds coming until the residual
        # pressure clears
        for inst, freed, idle in gov.migration_candidates(now):
            if len(acts) >= self.policy.max_migrations_per_round \
                    or gov.pressure_bytes() <= 0:
                break
            iid = inst.instance_id
            if now - self._cooldown.get(iid, -1e18) \
                    < self.policy.migration_cooldown_s:
                self.cooldown_skips += 1
                continue
            # bounded retry: a failed transfer blacklists its target and
            # moves on to the next-best peer (capped), so one sick node
            # can't absorb every rebalance round
            tried: set = set()
            for _attempt in range(self.policy.migration_retries + 1):
                pick = self._best_target(node, inst, freed, idle, now,
                                         exclude=tried)
                if pick is None:
                    break
                target, score = pick
                try:
                    h = self.migrate(iid, target.node_id, block=True)
                except MigrationError as e:
                    if getattr(e, "handle", None) is None:
                        break             # raced a request: next victim
                    # the transfer itself failed: target's fault until
                    # proven otherwise — blacklist and try the next peer
                    self._blacklist[target.node_id] = \
                        now + self.policy.blacklist_cooldown_s
                    tried.add(target.node_id)
                    self.migration_retries += 1
                    continue
                if h.ok or h.committed:
                    self._cooldown[iid] = now
                    acts.append(("migrate", iid, node.node_id,
                                 target.node_id, score))
                break
        return acts

    def _terminate_for_pressure(self, node: Node, now: float) -> List[tuple]:
        """Last resort, unchanged single-node semantics: evict idle
        hibernated tenants, most idle first, until pressure clears."""
        gov = node.governor
        acts: List[tuple] = []
        for inst, _freed, _idle in gov.migration_candidates(now):
            if gov.pressure_bytes() <= 0:
                break
            if inst.state != S.HIBERNATE:
                continue
            lock = node.engine.instance_lock(inst.instance_id)
            if not lock.acquire(blocking=False):
                continue
            try:
                if inst.state != S.HIBERNATE:
                    continue
                node.manager.evict(inst.instance_id)
            finally:
                lock.release()
            with self._lock:
                self.placement.pop(inst.instance_id, None)
            self.evictions += 1
            acts.append(("terminate", inst.instance_id, node.node_id))
        return acts

    # ------------------------------------------------------------ accounting
    def migration_stats(self) -> Dict[str, float]:
        """Cluster-tier counters: migrations, replication, recovery,
        elasticity, and wire accounting (one flat dict for benchmark
        tables)."""
        done = [h for h in self.handles if h.ok]
        now = time.monotonic()
        return {
            "migrations": len(done),
            "aborted": sum(1 for h in self.handles
                           if h.done and not h.ok and not h.committed),
            "migration_cooldown_s": self.policy.migration_cooldown_s,
            "breach_hysteresis": self.policy.breach_hysteresis,
            "cooldown_skips": self.cooldown_skips,
            "retries": self.migration_retries,
            "tenants_in_cooldown": len(self._cooldown),
            "blacklisted_targets": sum(
                1 for until in self._blacklist.values() if until > now),
            "bytes_shipped": sum(h.stats.bytes_shipped for h in done),
            "meta_bytes": sum(h.stats.meta_bytes for h in done),
            "wire_bytes": sum(h.stats.wire_bytes for h in done),
            "bytes_dedup": sum(h.stats.bytes_dedup for h in done),
            "full_snapshot_bytes": sum(h.stats.full_snapshot_bytes
                                       for h in done),
            "link_seconds": sum(h.stats.link_seconds for h in done),
            "tenants_rehomed": self.tenants_rehomed,
            "tenants_lost": self.tenants_lost,
            "replications": self.replications,
            "repairs_served": self.repairs_served,
            "nodes_dead": sum(
                1 for nid in self.nodes
                if self.detector.state(nid) == NodeHealth.DEAD),
            "nodes_suspect": sum(
                1 for nid in self.nodes
                if self.detector.state(nid) == NodeHealth.SUSPECT),
            "nodes": len(self.nodes),
            "nodes_draining": len(self._draining),
            "scale_outs": self.scale_outs,
            "scale_ins": self.scale_ins,
            "warm_bytes_shipped": self.warm_bytes_shipped,
        }

    def close(self) -> None:
        """Tear down every node (platforms, peer servers, spools)."""
        for node in self.nodes.values():
            node.close()

"""One simulated cluster node: manager + governor + store + engine.

A :class:`Node` is the single-node stack the rest of the repo built —
``InstanceManager`` (with its ``MemoryGovernor`` and ``SwapStore``),
``ServingEngine``, optionally an ``AsyncPlatform`` — plus the cluster-
facing surface the router scores placement and migration against:
governed-bytes headroom, digest inventory, and imminent-wake burden.

Every node of one cluster shares the deployment's store salt (the
router seeds it), so content digests are comparable across nodes and a
``StorePeer`` transfer can dedup against whatever the target already
holds.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional

from repro.core.governor import GovernorConfig
from repro.core.manager import InstanceManager, ManagerConfig
from repro.core.state import RUNG_OF
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import AsyncPlatform, PlatformPolicy


class Node:
    def __init__(self, node_id: str, factory: Callable, *,
                 spool_dir: str,
                 shared_loader: Optional[Callable] = None,
                 budget_bytes: Optional[int] = None,
                 salt: Optional[bytes] = None,
                 governor_cfg: Optional[GovernorConfig] = None,
                 manager_cfg: Optional[ManagerConfig] = None,
                 link_bw_bytes_s: float = 4 << 30):
        self.node_id = node_id
        self.factory = factory
        #: modelled node-to-node link bandwidth (transfer accounting)
        self.link_bw_bytes_s = link_bw_bytes_s
        if manager_cfg is None:
            manager_cfg = ManagerConfig(
                spool_dir=os.path.join(spool_dir, node_id),
                memory_budget_bytes=budget_bytes,
                store_salt=salt,
                governor_policy=governor_cfg)
        self.cfg = manager_cfg
        self.manager = InstanceManager(manager_cfg, factory,
                                       shared_loader=shared_loader)
        self.engine = ServingEngine(self.manager)
        self.platform: Optional[AsyncPlatform] = None
        self.peer_server = None
        #: liveness: flipped by :meth:`kill` (crash simulation) — the
        #: router's failure detector turns missed :meth:`ping` beats into
        #: SUSPECT/DEAD and triggers recovery
        self.alive = True
        #: tenant replicas this node holds for OTHER nodes:
        #: instance_id -> :class:`~repro.cluster.migrate.ReplicaRecord`
        #: (digests pinned in this node's store)
        self.replicas: Dict[str, object] = {}

    # ------------------------------------------------------------- surface
    @property
    def governor(self):
        return self.manager.governor

    @property
    def store(self):
        return self.manager.store

    def governed_bytes(self) -> int:
        return self.governor.governed_bytes()

    def pressure_bytes(self) -> int:
        return self.governor.pressure_bytes()

    def headroom_bytes(self) -> int:
        """Budget minus governed bytes (can be negative under breach);
        an unbudgeted node reports unbounded headroom."""
        budget = self.governor.budget_bytes
        if budget is None:
            return 1 << 62
        return budget - self.governed_bytes()

    def digest_overlap_bytes(self, digests) -> int:
        """On-disk bytes of ``digests`` this node's store already holds —
        the affinity term of placement/migration scoring: a tenant whose
        base weights are parked here wakes from local disk."""
        if self.store is None or not digests:
            return 0
        return self.store.stored_bytes_of(digests)

    def prefix_inventory(self) -> Dict[bytes, int]:
        """digest -> shareable bytes of every prefix this node's registry
        can serve (resident or revivable-by-digest) — what the node
        advertises to the router's prefix-affinity placement term."""
        reg = self.manager.prefix_registry
        return reg.inventory() if reg is not None else {}

    def prefix_overlap_bytes(self, digests) -> int:
        """Shareable bytes of ``digests`` already registered here: a new
        tenant of the deployment placed on this node COW-adopts these
        prompts instead of prefilling them."""
        if not digests:
            return 0
        inv = self.prefix_inventory()
        return sum(inv.get(d, 0) for d in digests)

    def zygote_families(self) -> Dict[str, int]:
        """``{family: live zygote count}`` this node can fork from — the
        node's advertisement to the router's zygote-affinity placement
        term (empty when the manager runs without a pool)."""
        zp = self.manager.zygotes
        return zp.families() if zp is not None else {}

    def zygote_bytes(self, arch_key: str) -> int:
        """Init bytes a new tenant of ``arch_key`` placed here would
        avoid by forking a live zygote instead of cold-starting (0
        without a pool or a live donor of the family)."""
        zp = self.manager.zygotes
        return zp.zygote_bytes(arch_key) if zp is not None else 0

    def imminent_wake_burden_s(self, now: float,
                               horizon_s: float = 5.0) -> float:
        """Summed predicted wake cost (seconds) of this node's deflated
        tenants whose next request is expected within ``horizon_s`` —
        placement steers new tenants away from nodes about to pay wakes."""
        gov = self.governor
        burden = 0.0
        with self.manager._lock:
            insts = list(self.manager.instances.values())
        for inst in insts:
            rung = RUNG_OF[inst.state]
            cost = gov.wake_cost(rung)
            if cost <= 0:
                continue
            gap = gov.predicted_gap(inst.instance_id, now,
                                    last_used=inst.last_used)
            if gap <= horizon_s:
                burden += cost
        return burden

    def states(self) -> Dict[str, str]:
        return self.manager.states()

    # ------------------------------------------------------------- platform
    def start_platform(self, policy: PlatformPolicy,
                       arch_of: Dict[str, str],
                       workers: int = 2) -> AsyncPlatform:
        """Run this node event-driven: per-tenant queues, worker pool,
        policy daemon — the router installs its reroute hook on it."""
        self.platform = AsyncPlatform(self.engine, policy, arch_of,
                                      workers=workers).start()
        return self.platform

    def stop(self) -> None:
        if self.platform is not None:
            self.platform.stop()
            self.platform = None

    # ------------------------------------------------------------- network
    def start_peer_server(self, host: str = "127.0.0.1", port: int = 0):
        """Expose this node's store + bundle admission to authenticated
        peers over the binary wire protocol; returns ``(host, port)``.
        Peers dial it with ``SocketTransport.connect(addr, salt)`` —
        the handshake proves the shared deployment salt, never ships it."""
        from repro.cluster.migrate import receive_bundle
        from repro.cluster.transport import StoreServer
        if self.store is None:
            raise RuntimeError("peer server requires the dedup store "
                               "(ManagerConfig.dedup_store)")
        if self.peer_server is None:
            self.peer_server = StoreServer(
                self.store, node_id=self.node_id,
                bundle_handler=lambda b: receive_bundle(self, b),
                host=host, port=port)
        return self.peer_server.address

    # ------------------------------------------------------------- liveness
    def ping(self) -> bool:
        """Heartbeat probe: does the node answer?  In-process stand-in
        for the node-agent's lease renewal RPC."""
        return self.alive

    def kill(self) -> None:
        """Crash simulation: the node stops answering *now*.

        Everything in flight dies the way a real crash kills it — queued
        and executing requests fail with ``NodeDownError`` (the gateway's
        idempotent re-dispatch picks them up), the peer server stops
        accepting, and the platform is stopped without drain.  The
        node's disk state is left exactly as the crash found it; only
        :meth:`ClusterRouter.recover_node` may touch it after this."""
        if not self.alive:
            return
        self.alive = False
        from repro.serving.engine import NodeDownError
        if self.platform is not None:
            self.platform.fail_pending(
                NodeDownError(f"node {self.node_id} crashed"))
            self.platform.stop(drain=False)
            self.platform = None
        if self.peer_server is not None:
            self.peer_server.close()
            self.peer_server = None

    def drop_replica(self, instance_id: str) -> int:
        """Forget a replica held for another node (tenant terminated,
        holder rotated out, or the replica was just promoted by
        adoption): unpin its digests so GC can reclaim whatever no local
        tenant references.  Returns bytes reclaimed."""
        rec = self.replicas.pop(instance_id, None)
        if rec is None or self.store is None:
            return 0
        return self.store.unpin_replicas(rec.digests)

    def close(self) -> None:
        self.stop()
        if self.peer_server is not None:
            self.peer_server.close()
            self.peer_server = None
        if self.store is not None:
            self.store.close()

    def __repr__(self) -> str:            # pragma: no cover - debug aid
        return (f"Node({self.node_id}, tenants={len(self.manager.instances)}, "
                f"governed={self.governed_bytes()})")

"""Serving launcher: a hibernating multi-tenant node under a request trace.

Two modes:
  * ``--dry-run``: lower+compile serve_step (decode_32k) for the
    production mesh via launch.dryrun.
  * default: run a REAL trace on CPU (tiny configs): Poisson-ish arrivals
    over N tenants served by the AsyncPlatform worker pool (bursts of
    ``--burst`` requests run concurrently), keep-alive deflation, REAP or
    pagefault wakes.  Reports per-state latency percentiles and final
    memory per tenant.

  PYTHONPATH=src python -m repro.launch.serve --tenants 4 --requests 24
"""
from __future__ import annotations

import argparse
import shutil
import subprocess
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--wake-mode", choices=("reap", "pagefault"),
                    default="reap")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--burst", type=int, default=3,
                    help="requests submitted concurrently between policy "
                         "passes")
    ap.add_argument("--keep-warm-s", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spool", default="/tmp/repro_launch_serve")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args(argv)

    if args.dry_run:
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             args.arch, "--shape", "decode_32k", "--mesh", args.mesh])

    import numpy as np
    import jax

    from repro.configs import get_config, tiny_config
    from repro.core.manager import InstanceManager, ManagerConfig
    from repro.core.metrics import memory_report
    from repro.models import model
    from repro.serving import (AsyncPlatform, PlatformPolicy, Request,
                               ServingEngine)

    shutil.rmtree(args.spool, ignore_errors=True)

    def factory(arch):
        cfg = tiny_config(get_config(arch))
        return cfg, model.init_params(jax.random.PRNGKey(0), cfg)

    mgr = InstanceManager(
        ManagerConfig(spool_dir=args.spool, wake_mode=args.wake_mode),
        factory)
    eng = ServingEngine(mgr)
    tenants = {f"fn{i}": args.arch for i in range(args.tenants)}
    # the driver runs the policy pass between bursts; idle the daemon
    plat = AsyncPlatform(eng, PlatformPolicy(keep_warm_s=args.keep_warm_s,
                                             tick_interval_s=3600.0),
                         tenants, workers=args.workers)

    rng = np.random.default_rng(args.seed)
    lat_by_state: dict = {}
    with plat:
        for b0 in range(0, args.requests, args.burst):
            burst = []
            for r_i in range(b0, min(b0 + args.burst, args.requests)):
                tenant = f"fn{rng.integers(args.tenants)}"
                fut = plat.submit(Request(
                    tenant, f"s{r_i}",
                    rng.integers(0, 256, 6).astype(np.int32),
                    max_new_tokens=4, close_session=True))
                burst.append((r_i, tenant, fut))
            for r_i, tenant, fut in burst:
                resp = fut.result()
                lat_by_state.setdefault(resp.state_before, []).append(
                    resp.spans["e2e"])
                print(f"  req{r_i:03d} {tenant:5s} {resp.state_before:9s}->"
                      f"{resp.state_after:6s} "
                      f"{resp.spans['e2e'] * 1e3:7.0f}ms "
                      f"faults={resp.faults}", flush=True)
            for iid in plat.policy_pass():
                print(f"    [policy] deflated {iid}")
            # REAP-record each tenant once it has served
            for _, tenant, _ in burst:
                inst = mgr.instances.get(tenant)
                if inst is not None and not inst.recorder.working_set:
                    eng.record_sample(tenant, Request(
                        tenant, "probe",
                        rng.integers(0, 256, 4).astype(np.int32),
                        max_new_tokens=2, close_session=True))

    print("\nper-state latency (ms):")
    for st, xs in sorted(lat_by_state.items()):
        xs = sorted(xs)
        print(f"  {st:9s} n={len(xs):3d} p50={xs[len(xs) // 2] * 1e3:7.0f} "
              f"max={xs[-1] * 1e3:7.0f}")
    print("tenant memory:")
    for iid, inst in mgr.instances.items():
        rep = memory_report(inst, mgr.shared)
        print(f"  {iid:5s} state={rep.state:9s} "
              f"pss={rep.pss_total / 2**20:7.2f} MB")
    return 0


if __name__ == "__main__":
    sys.exit(main())

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) case.

The two lines above MUST precede any other import (jax locks the device
count at first init), which is why this module sets XLA_FLAGS before its
docstring.  Do not import this module from tests or benchmarks — they are
supposed to see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape decode_32k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape
from repro.launch import analysis, sharding as shd, specs
from repro.launch.mesh import make_production_mesh, refine_mesh
from repro.utils.dist import ShardingRules, use_rules


def run_case(arch: str, shape_name: str, mesh_name: str,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not specs.applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": cfg.long_context_mode}
    prod = make_production_mesh(multi_pod=(mesh_name == "multi"))
    mesh = refine_mesh(prod, cfg.tp, cfg.sp)
    chips = mesh.devices.size

    t0 = time.monotonic()
    fn, args, donate = specs.build_case(cfg, shape, mesh)
    mode = {"train": "train", "prefill": "prefill",
            "decode": "decode"}[shape.kind]
    rules = ShardingRules(mesh, shd.activation_rules(
        cfg, mode, mesh, shape.global_batch))
    with use_rules(rules):
        jitted = jax.jit(fn, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    roof = analysis.analyse(compiled, cfg, shape, mesh_name, chips)
    mem = compiled.memory_analysis()
    out = roof.to_dict()
    out.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
    })
    if verbose:
        gb = (out["bytes_per_device"] or 0) / 2**30
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"chips={chips} bytes/dev={gb:.2f}GiB "
              f"flops/dev={out['device_flops']:.3e} "
              f"compute={out['compute_s']*1e3:.2f}ms "
              f"memory={out['memory_s']*1e3:.2f}ms "
              f"collective={out['collective_s']*1e3:.2f}ms "
              f"bottleneck={out['bottleneck']} "
              f"useful={out['useful_flops_frac']:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) for --mesh")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    cases = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cases.append((a, s, args.mesh))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        cases.append((args.arch, args.shape, args.mesh))

    failures = 0
    for a, s, m in cases:
        try:
            res = run_case(a, s, m)
        except Exception as e:
            failures += 1
            res = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[{a} x {s} x {m}] FAILED: {e}", flush=True)
            traceback.print_exc()
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Training launcher.

Two modes:
  * ``--dry-run``: delegate to launch.dryrun for the production mesh
    (lower + compile only; needs no hardware).
  * default: run REAL steps at a CPU-feasible scale (tiny/scaled variant
    of the selected arch) with the full substrate: synthetic pipeline,
    AdamW + schedule, remat, checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --dry-run
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--scale", choices=("tiny", "scaled"), default="scaled")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train/ckpt")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile train_4k on the production mesh")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args(argv)

    if args.dry_run:
        # dryrun.py must own the process (XLA_FLAGS before jax import)
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             args.arch, "--shape", "train_4k", "--mesh", args.mesh])

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, scaled_config, tiny_config
    from repro.data import DataConfig, SyntheticPipeline
    from repro.models import model
    from repro.training import (AdamWConfig, checkpoint, init_state,
                                make_train_step)

    cfg = get_config(args.arch)
    cfg = tiny_config(cfg) if args.scale == "tiny" else scaled_config(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    opt = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    pipe = SyntheticPipeline(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0),
        frontend=cfg.frontend)

    t0 = time.monotonic()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}", flush=True)
    checkpoint.save(args.ckpt, params, step=args.steps)
    dt = time.monotonic() - t0
    print(f"{args.steps} steps in {dt:.1f}s -> {args.ckpt}.npz")
    return 0


if __name__ == "__main__":
    sys.exit(main())

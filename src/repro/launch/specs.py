"""Abstract input builders: ShapeDtypeStruct stand-ins for every model
input, weight-correct and sharding-attached — no device allocation.

``build_case(cfg, shape, mesh)`` returns
  (step_fn, in_args: tuple of SDS pytrees, donate: tuple[int, ...])
for the shape's kind:
  train   -> train_step(params, opt_state, batch)
  prefill -> prefill_step(params, batch)            (logits + full KV cache)
  decode  -> serve_step(params, tokens, cache)      (ONE token, cached seq)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.launch import sharding as shd
from repro.models import model
from repro.training import AdamWConfig, init_state, make_train_step


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _abstract_params(cfg, mesh, mode):
    tree = jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = shd.params_specs(tree, cfg, mode, mesh)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), tree, specs)


def decode_window(cfg, shape: InputShape) -> Tuple[Optional[int], int]:
    """(window, cache_len) for a decode shape.  long_500k on dense archs
    runs the sliding-window variant (ring cache of window slots)."""
    if shape.name == "long_500k" and cfg.attention != "none":
        if cfg.long_context_mode == "skip":
            raise ValueError(f"{cfg.arch_id}: long_500k skipped by design")
        w = cfg.sliding_window
        return w, min(shape.seq_len, w)
    return None, shape.seq_len


def applicable(cfg, shape: InputShape) -> bool:
    if shape.name == "long_500k" and cfg.long_context_mode == "skip":
        return False
    return True


def _batch_sds(cfg, shape: InputShape, mesh, specs, *, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32, mesh, specs["tokens"])}
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32, mesh, specs["labels"])
    fe = cfg.frontend
    if fe.kind == "vision":
        batch["embeds"] = _sds((B, fe.num_embeddings, fe.embed_dim),
                               jnp.bfloat16, mesh, specs["embeds"])
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((B, fe.num_embeddings, fe.embed_dim),
                               jnp.bfloat16, mesh, specs["frames"])
    return batch


# ---------------------------------------------------------------------------
# per-kind builders
# ---------------------------------------------------------------------------

def build_train(cfg, shape: InputShape, mesh: Mesh):
    params = _abstract_params(cfg, mesh, "train")
    opt_cfg = AdamWConfig()
    opt_tree = jax.eval_shape(init_state, params)
    pspecs = shd.params_specs(params, cfg, "train", mesh)
    opt = type(opt_tree)(
        step=_sds((), jnp.int32, mesh, P()),
        m=jax.tree.map(lambda s, sp: _sds(s.shape, jnp.float32, mesh, sp),
                       opt_tree.m, pspecs),
        v=jax.tree.map(lambda s, sp: _sds(s.shape, jnp.float32, mesh, sp),
                       opt_tree.v, pspecs),
    )
    bspecs = shd.train_batch_specs(cfg, mesh, shape.global_batch)
    batch = _batch_sds(cfg, shape, mesh, bspecs, with_labels=True)
    fn = make_train_step(cfg, opt_cfg, remat=True)
    return fn, (params, opt, batch), (0, 1)


def build_prefill(cfg, shape: InputShape, mesh: Mesh):
    params = _abstract_params(cfg, mesh, "prefill")
    bspecs = shd.prefill_specs(cfg, mesh, shape.global_batch)
    batch = _batch_sds(cfg, shape, mesh, bspecs, with_labels=False)

    def prefill_step(params, batch):
        x, caches, _ = model.forward_hidden(
            params, cfg, batch["tokens"], embeds=batch.get("embeds"),
            enc_frames=batch.get("frames"), collect_cache=True)
        return model.unembed(params, cfg, x[:, -1]), caches

    return prefill_step, (params, batch), ()


def build_decode(cfg, shape: InputShape, mesh: Mesh):
    params = _abstract_params(cfg, mesh, "decode")
    window, cache_len = decode_window(cfg, shape)
    B = shape.global_batch
    cache_tree = jax.eval_shape(
        functools.partial(model.init_cache, cfg, B, cache_len,
                          enc_len=cfg.encoder_max_len or None))
    cspecs = shd.cache_specs(cfg, mesh, B)
    cache = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
        {"layers": cache_tree["layers"]}, {"layers": cspecs["layers"]})
    cache["lengths"] = _sds((B,), jnp.int32, mesh, cspecs["lengths"])
    cache["kv_positions"] = _sds((B, cache_len), jnp.int32,
                                 mesh, cspecs["kv_positions"])
    b = shd.batch_axes(mesh, B, include_sp=False)
    tokens = _sds((B,), jnp.int32, mesh, P(b))

    def serve_step(params, tokens, cache):
        return model.decode_step(params, cfg, tokens, cache, window=window)

    return serve_step, (params, tokens, cache), (2,)


def build_case(cfg, shape: InputShape, mesh: Mesh):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)

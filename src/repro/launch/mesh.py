"""Production meshes.

``make_production_mesh`` is the brief's canonical mesh: (16, 16) =
("data", "model") for one v5e pod of 256 chips, or (2, 16, 16) =
("pod", "data", "model") for two pods.  Defined as a *function* so that
importing this module never touches jax device state.

``refine_mesh`` re-views the same devices with the model axis split into
(tp, sp) — each architecture's head count dictates its tp (DESIGN.md §4),
so the refined mesh is per-arch while the device set (and therefore the
physical topology) is exactly the production mesh's.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

MODEL_AXIS = 16
DATA_AXIS = 16
PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (PODS, DATA_AXIS, MODEL_AXIS) if multi_pod \
        else (DATA_AXIS, MODEL_AXIS)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def refine_mesh(mesh: Mesh, tp: int, sp: int) -> Mesh:
    """Split the trailing "model" axis of a production mesh into
    ("tp", "sp").  tp * sp must equal MODEL_AXIS."""
    if tp * sp != MODEL_AXIS:
        raise ValueError(f"tp*sp = {tp}*{sp} != {MODEL_AXIS}")
    devs = mesh.devices
    new_shape = devs.shape[:-1] + (tp, sp)
    names = mesh.axis_names[:-1] + ("tp", "sp")
    return Mesh(devs.reshape(new_shape), names)


def make_refined_mesh(cfg, *, multi_pod: bool = False) -> Mesh:
    return refine_mesh(make_production_mesh(multi_pod=multi_pod),
                       cfg.tp, cfg.sp)

"""Partition-spec factory: params, optimizer state, batches, caches,
activation rules — per (architecture x mode x mesh).

Axis vocabulary (after mesh refinement, launch/mesh.py):
  pod   — pods (multi-pod only); extends data parallelism / FSDP
  data  — within-pod data parallelism; also the **expert-parallel** axis
          (MoE expert tensors shard E over "data" in *both* modes: a
          236/480B expert bank cannot replicate across data groups)
  tp    — tensor parallelism (attention heads, FFN hidden)
  sp    — sequence parallelism (decode KV cache sequence dim); joins
          batch-parallelism when the batch allows and joins tp on the FFN
          hidden dim (every assigned arch has d_ff % 16 == 0)

Modes: "train" (adds FSDP: non-expert weight matrices shard their
d_model-ish dim over data; optimizer state mirrors params), "prefill",
"decode".
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.instance import _path_str

FFN = ("tp", "sp")                     # full model axis for FFN hidden


def _leaf(path: str) -> str:
    return path.rsplit("/", 1)[-1]


def batch_axes(mesh: Mesh, global_batch: int, *, include_sp: bool = True):
    """Largest prefix of (pod, data, sp) whose product divides the batch."""
    order = [a for a in ("pod", "data") if a in mesh.shape]
    if include_sp:
        order.append("sp")
    axes, prod = [], 1
    for a in order:
        n = mesh.shape.get(a, 1)
        if global_batch % (prod * n) == 0 and n > 1:
            axes.append(a)
            prod *= n
        elif a != "sp":
            break                      # keep the prefix contiguous
    return tuple(axes) if axes else None


def fsdp_axes(mesh: Mesh, mode: str):
    """Weight-matrix FSDP axes for training (ZeRO-3 style)."""
    if mode != "train":
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes or None


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding axes that do not evenly divide their dimension."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            n = mesh.shape.get(a, 1)
            if dim % (prod * n) == 0:
                kept.append(a)
                prod *= n
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    return P(*out)


def param_spec(path: str, ndim: int, cfg, mode: str, mesh: Mesh) -> P:
    """Spec for one parameter leaf (path uses the instance catalog scheme;
    stacked layer leaves carry a leading L dim)."""
    leaf = _leaf(path)
    fsdp = fsdp_axes(mesh, mode)
    stacked = path.startswith("layers/") or "/layers/" in path
    tp = "tp" if cfg.tp > 1 else None

    def wrap(spec: Tuple) -> P:
        if stacked:
            spec = (None,) + spec            # leading num_layers axis
        assert len(spec) == ndim, (path, spec, ndim)
        return P(*spec)

    if leaf in ("w_gate", "w_up", "w_down") and "/moe/" in path and \
            "/shared/" not in path and "/dense/" not in path:
        # (E, d, f) / (E, f, d) expert banks: E over data (expert parallel)
        if leaf == "w_down":
            return wrap(("data", FFN, "pod" if fsdp and "pod" in fsdp
                         else None))
        return wrap(("data", "pod" if fsdp and "pod" in fsdp else None, FFN))
    if leaf == "router":
        return wrap((None, None))
    if path == "embed":
        return P(FFN, fsdp)                  # (Vp, d): vocab over model axis
    if path == "lm_head":
        return P(fsdp, FFN)
    if path == "pos_embed" or leaf == "pos_embed":
        spec = (FFN, None)
        return wrap(spec) if stacked else P(*spec)
    if path == "frontend_proj":
        return P(None, fsdp)
    if leaf in ("wq", "wk", "wv"):
        return wrap((fsdp, tp))
    if leaf == "wo":
        return wrap((tp, fsdp))
    if leaf in ("wq_a", "wkv_a"):
        return wrap((fsdp, None))
    if leaf in ("wq_b", "wkv_b"):
        return wrap((None, tp))
    if leaf in ("w_gate", "w_up"):           # dense MLP / shared experts
        return wrap((fsdp, FFN))
    if leaf == "w_down":
        return wrap((FFN, fsdp))
    if leaf == "in_proj":
        return wrap((fsdp, None))
    if leaf == "out_proj":
        return wrap((None, fsdp))
    # norms, biases, conv, A_log, D, dt_bias, scales ...
    return wrap((None,) * (ndim - (1 if stacked else 0)))


def params_specs(params_tree, cfg, mode: str, mesh: Mesh):
    """Pytree of PartitionSpec matching a params (shape-)pytree."""
    flat = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = [sanitize_spec(
        param_spec(_path_str(p), v.ndim, cfg, mode, mesh), v.shape, mesh)
        for p, v in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def train_batch_specs(cfg, mesh: Mesh, global_batch: int):
    b = batch_axes(mesh, global_batch)
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.frontend.kind == "vision":
        out["embeds"] = P(b, None, None)
    if cfg.is_encoder_decoder:
        out["frames"] = P(b, None, None)
    return out


def prefill_specs(cfg, mesh: Mesh, global_batch: int):
    return train_batch_specs(cfg, mesh, global_batch)


def cache_specs(cfg, mesh: Mesh, global_batch: int) -> Dict:
    """Decode-cache specs: B over (pod, data), S over sp, kv-heads over tp."""
    b = batch_axes(mesh, global_batch, include_sp=False)
    tp = "tp" if cfg.tp > 1 else None
    sp = "sp" if cfg.sp > 1 else None
    layers = {}
    if cfg.attention == "mla":
        layers["ckv"] = P(None, b, sp, None)
        layers["krope"] = P(None, b, sp, None)
    elif cfg.attention == "gqa":
        kv_tp = tp if tp and cfg.num_kv_heads % cfg.tp == 0 else None
        layers["k"] = P(None, b, sp, kv_tp, None)
        layers["v"] = P(None, b, sp, kv_tp, None)
    if cfg.ssm is not None:
        layers["state"] = P(None, b, None, None, None)
        layers["conv"] = P(None, b, None, None)
    if cfg.is_encoder_decoder:
        kv_tp = tp if tp and cfg.num_kv_heads % cfg.tp == 0 else None
        layers["cross_k"] = P(None, b, None, kv_tp, None)
        layers["cross_v"] = P(None, b, None, kv_tp, None)
    return {"layers": layers,
            "lengths": P(b),
            "kv_positions": P(b, sp)}


# ---------------------------------------------------------------------------
# activation rules (consumed by utils.dist.constrain)
# ---------------------------------------------------------------------------

def activation_rules(cfg, mode: str, mesh: Mesh, global_batch: int) -> Dict:
    b = batch_axes(mesh, global_batch,
                   include_sp=(mode != "decode"))
    tp = "tp" if cfg.tp > 1 else None
    kv_tp = tp if tp and cfg.num_kv_heads and \
        cfg.num_kv_heads % max(cfg.tp, 1) == 0 else None
    # when the batch consumed "sp", activations can't also shard on it
    ffn_act = ("tp",) if (b and "sp" in b) else FFN
    if cfg.tp == 1 and ffn_act == ("tp",):
        ffn_act = None
    return {
        "act_btd": P(b, None, None),
        "act_btf": P(b, None, ffn_act),
        "act_bshd": P(b, None, tp, None),
        "act_bskd": P(b, None, kv_tp, None),
        "logits_btv": P(b, None, ffn_act),
        "moe_ecd": P("data", None, None),
        "moe_ecf": P("data", None, ffn_act),
        "ssm_bshp": P(b, None, None, None),
    }

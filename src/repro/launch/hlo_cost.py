"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count — a jax ``lax.scan`` over 60 layers therefore under-reports
FLOPs/bytes/collectives by ~60x (verified experimentally; see
EXPERIMENTS.md §Roofline methodology).  This module re-derives the three
roofline quantities from the *partitioned* HLO text, scaling every
computation by the product of the known trip counts above it:

  * flops            — from ``dot`` ops (2 * out_elems * contracted dim);
                       matmuls are >99% of FLOPs in these models
  * traffic bytes    — fusion-boundary operand/output bytes, with
                       slice-semantics corrections (a dynamic-slice fusion
                       reads its slice, not the whole operand; a
                       dynamic-update-slice writes its update region, not
                       the whole aliased buffer)
  * collective bytes — output bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

Trip counts come from the ``backend_config={"known_trip_count":{"n":..}}``
annotation XLA attaches to loops it has analysed (every jax scan).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"?(\d+)"?')
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elems, bytes) over every array in a (possibly tuple) type."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


def _first_shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                     # operand list + attributes (raw)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in COLLECTIVES:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()})


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        op = parsed
        cur.ops.append(op)
        cur.types[op.name] = op.type_str
    return comps


def _parse_op_line(line: str) -> Optional[Op]:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # result type: balanced (...) tuple (may contain /*index=N*/ comments)
    # or a single token like bf16[8,3072]{1,0}
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str, rest = rest[:end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    opcode = m2.group(1)
    tail = rest[m2.end():]                  # inside the operand parens
    depth, i, args = 1, 0, ""
    while i < len(tail) and depth:
        ch = tail[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if depth:
            args += ch
        i += 1
    return Op(name, type_str.strip(), opcode, tail,
              _OPERAND_RE.findall(args))


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "while", "conditional", "call",
               "custom-call", "partition-id", "replica-id", "rng-state",
               "opt-barrier", "add-dependency", "domain",
               # TPU-native-dtype model: XLA:CPU legalises bf16 compute by
               # materialising f32 converts; the MXU takes bf16 natively,
               # so converts/copies are not HBM traffic on the target
               "convert", "copy"}
_SLICE_LIKE = {"dynamic-slice", "gather", "slice"}
_DUS_LIKE = {"dynamic-update-slice", "scatter", "select-and-scatter"}
_PURE_MOVE = {"convert", "bitcast", "copy", "parameter", "tuple",
              "get-tuple-element", "constant", "broadcast", "reshape",
              "transpose"}


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_dims = _first_shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rest)
    if not m or not op.operands:
        return 0.0
    lhs_type = comp.types.get(op.operands[0], "")
    _, lhs_dims = _first_shape_dims(lhs_type)
    k = 1
    for idx in (int(x) for x in m.group(1).split(",") if x):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _body_opcodes(comp: Computation, comps, seen=None) -> set:
    seen = seen or set()
    out = set()
    for op in comp.ops:
        out.add(op.opcode)
        if op.opcode == "fusion":
            for cal in _CALL_ATTR_RE.findall(op.rest):
                if cal in comps and cal not in seen:
                    seen.add(cal)
                    out |= _body_opcodes(comps[cal], comps, seen)
    return out


def _min_elem_bytes(type_str: str) -> int:
    """Bytes if every array used its narrowest-seen dtype (>= bf16=2)."""
    e, _ = shape_elems_bytes(type_str)
    return e * 2


def _is_move_fusion(comp: Computation) -> bool:
    return all(op.opcode in _PURE_MOVE for op in comp.ops)


def _inner_update_bytes(comp: Computation, comps, seen=None) -> Optional[int]:
    """Bytes of the update operand of a dynamic-update-slice/scatter inside
    a fused computation (the true in-place write size)."""
    seen = seen or set()
    for op in comp.ops:
        if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
            _, b = shape_elems_bytes(comp.types.get(op.operands[1], ""))
            if b:
                return b
        if op.opcode == "scatter" and len(op.operands) >= 3:
            _, b = shape_elems_bytes(comp.types.get(op.operands[2], ""))
            if b:
                return b
        if op.opcode == "fusion":
            for cal in _CALL_ATTR_RE.findall(op.rest):
                if cal in comps and cal not in seen:
                    seen.add(cal)
                    r = _inner_update_bytes(comps[cal], comps, seen)
                    if r is not None:
                        return r
    return None


def _operand_bytes(name: str, comp: Computation, comps) -> int:
    """Operand traffic with the TPU-native-dtype correction: values that
    are (transitively) converts of narrower tensors count at the source
    width — the MXU reads bf16 directly, the f32 copy is CPU legalisation."""
    t = comp.types.get(name, "")
    _, b = shape_elems_bytes(t)
    if "f32" in t and b:
        return min(b, _min_elem_bytes(t)) if _converts_from_narrow(
            name, comp, comps) else b
    return b


def _converts_from_narrow(name: str, comp: Computation, comps) -> bool:
    for op in comp.ops:
        if op.name != name:
            continue
        if op.opcode == "convert":
            return True
        if op.opcode == "fusion":
            for cal in _CALL_ATTR_RE.findall(op.rest):
                c = comps.get(cal)
                if c is not None and any(o.opcode == "convert"
                                         for o in c.ops):
                    return True
        return False
    return False


def _op_bytes(op: Op, comp: Computation, comps) -> float:
    _, out_b = shape_elems_bytes(op.type_str)
    kinds = {op.opcode}
    called = []
    if op.opcode == "fusion":
        for cal in _CALL_ATTR_RE.findall(op.rest):
            if cal in comps:
                called.append(comps[cal])
                kinds |= _body_opcodes(comps[cal], comps)
        if called and all(_is_move_fusion(c) for c in called):
            return 0.0              # convert/copy-only fusion: CPU artifact
    if kinds & _DUS_LIKE:
        # in-place update: traffic = read + write of the update region
        for c in called:
            ub = _inner_update_bytes(c, comps)
            if ub is not None:
                return 2.0 * ub
        if op.opcode in _DUS_LIKE and len(op.operands) >= 2:
            _, b = shape_elems_bytes(comp.types.get(op.operands[1], ""))
            if b:
                return 2.0 * b
        cand = [shape_elems_bytes(comp.types.get(o, ""))[1]
                for o in op.operands]
        cand = [b for b in cand if b > 4]
        return 2.0 * (min(cand) if cand else out_b)
    if kinds & _SLICE_LIKE:
        # a slice fuses into its consumer on TPU: one read of the sliced
        # region at its narrowest dtype (the f32 width is CPU legalisation)
        return float(min(out_b, _min_elem_bytes(op.type_str)))
    tot = out_b
    for o in op.operands:
        tot += _operand_bytes(o, comp, comps)
    return tot


def _trip_count(op: Op) -> int:
    m = _TRIP_RE.search(op.rest)
    return int(m.group(1)) if m else 1


class Analyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else next(iter(self.comps), "")

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()          # cycle guard
        comp = self.comps.get(comp_name)
        if comp is None:
            return self._memo[comp_name]
        total = Cost()
        for op in comp.ops:
            if op.opcode == "while":
                trips = _trip_count(op)
                for cal in _CALL_ATTR_RE.findall(op.rest):
                    total += self.cost_of(cal).scaled(trips)
                continue
            if op.opcode in ("fusion", "call", "conditional", "async-start"):
                for cal in _CALL_ATTR_RE.findall(op.rest):
                    sub = self.cost_of(cal)
                    total.flops += sub.flops        # dots inside fusions
                    for k in COLLECTIVES:
                        total.coll[k] += sub.coll[k]
                total.bytes += _op_bytes(op, comp, self.comps)
                continue
            if op.opcode == "dot":
                total.flops += _dot_flops(op, comp)
                total.bytes += _op_bytes(op, comp, self.comps)
                continue
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                _, b = shape_elems_bytes(op.type_str)
                total.coll[base] += b
                continue
            if op.opcode in _SKIP_BYTES:
                continue
            total.bytes += _op_bytes(op, comp, self.comps)
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyse_text(text: str) -> Cost:
    return Analyzer(text).entry_cost()

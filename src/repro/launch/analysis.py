"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip):
  peak bf16 compute 197 TFLOP/s; HBM bandwidth 819 GB/s; ICI ~50 GB/s/link.

``compiled.cost_analysis()`` yields HLO FLOPs and bytes for the *per-device*
(post-SPMD) module; collective traffic is not in cost_analysis, so we parse
the partitioned HLO text and sum the output bytes of every collective op
(shapes in that module are already per-device, so the resulting byte count
is per-chip traffic):

  compute term    = device_flops / peak_flops
  memory term     = device_bytes / hbm_bw
  collective term = device_collective_bytes / ici_bw
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    tot = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        tot += n * _DTYPE_BYTES[dt]
    return tot


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes from a partitioned HLO module."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, start = m.group(1), m.group(2), m.group(3)
        # `-done` ops would double-count their `-start`
        out[kind] += _shape_bytes(type_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    device_flops: float = 0.0
    device_bytes: float = 0.0
    coll_bytes: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: Optional[float] = None      # memory_analysis temp+args
    model_flops: float = 0.0                      # 6*N*D useful flops (global)
    xla_flops: float = 0.0                        # raw cost_analysis (no trips)

    @property
    def compute_s(self) -> float:
        return self.device_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        tot = self.device_flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "device_flops": self.device_flops,
            "device_bytes": self.device_bytes,
            "coll_bytes": dict(self.coll_bytes),
            "bytes_per_device": self.bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "xla_flops": self.xla_flops,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) useful-FLOP model; N = active
    params for MoE."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def xla_cost_dict(compiled) -> dict:
    """Normalise ``compiled.cost_analysis()`` across jax versions: older
    releases return a dict, newer ones a one-element list of dicts (one
    per device), and either may be empty/None."""
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c or {}


def analyse(compiled, cfg, shape, mesh_name: str, chips: int) -> Roofline:
    """Roofline terms from the partitioned module via the trip-count-aware
    HLO cost model (launch/hlo_cost.py).  ``compiled.cost_analysis()`` is
    recorded too, but it counts while bodies once — a 28-60 layer scan
    under-reports by ~L (verified; EXPERIMENTS.md §Roofline methodology)."""
    from repro.launch import hlo_cost

    xla_cost = xla_cost_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        bpd = (getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        bpd = None
    c = hlo_cost.analyse_text(compiled.as_text())
    roof = Roofline(
        arch=cfg.arch_id, shape=shape.name, mesh=mesh_name, chips=chips,
        device_flops=c.flops,
        device_bytes=c.bytes,
        coll_bytes={k: int(v) for k, v in c.coll.items()},
        bytes_per_device=bpd,
        model_flops=model_flops(cfg, shape),
    )
    roof.xla_flops = float(xla_cost.get("flops", 0.0))
    return roof

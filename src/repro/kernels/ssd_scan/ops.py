"""jit'd public wrapper for the SSD scan kernel.

Takes the same (B, S, H, P) sequence-major arguments as the reference
``ssd_chunked`` and handles chunk padding, the (dt*A, dt*x) pre-scaling,
chunk-major re-layout, and the D skip connection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel


@functools.partial(jax.jit, static_argnames=("chunk_size", "interpret"))
def ssd(x, dt, A, Bm, Cm, D, *, chunk_size: int = 256, h0=None,
        interpret: bool = True):
    """SSD forward.  x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm,Cm: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk_size, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    dt32 = dt.astype(jnp.float32)
    logdec = dt32 * A[None, None, :]                       # (B,Sp,H)
    dtx = x.astype(jnp.float32) * dt32[..., None]          # (B,Sp,H,P)

    # chunk-major layouts
    logdec = logdec.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)
    dtx = dtx.reshape(B, nc, Q, H, P).transpose(0, 3, 1, 2, 4)
    Bmc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cmc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    y, h_final = kernel.ssd_scan(logdec, dtx, Bmc, Cmc, h0,
                                 interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(B, Sp, H, P)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final

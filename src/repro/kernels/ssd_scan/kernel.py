"""ssd_scan Pallas kernel: Mamba2 state-space-duality chunked scan.

Grid = (batch, ssd_heads, chunks) with the chunk axis innermost and
*sequential*: the inter-chunk recurrent state h (N, P) lives in VMEM
scratch and is carried across chunk steps — the TPU-native shape of the
SSD algorithm (arXiv:2405.21060): the intra-chunk part is the quadratic
dual form (three MXU matmuls per chunk), the inter-chunk part is a scalar-
decay rank-N update.

Inputs are pre-arranged by ops.py into chunk-major layouts so every block
is a contiguous lane-aligned tile:

  logdec: (B, H, nc, Q)        dt * A      (decay log per step)
  dtx:    (B, H, nc, Q, P)     dt * x      (pre-scaled inputs)
  Bm/Cm:  (B, nc, Q, N)        shared across heads (single SSD group)
  h0:     (B, H, N, P)         initial state
  -> y:   (B, H, nc, Q, P), h_final: (B, H, N, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(logdec_ref, dtx_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, h_ref):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    ld = logdec_ref[0, 0].astype(jnp.float32)          # (1, Q)
    a_cum = jnp.cumsum(ld, axis=-1)                    # (1, Q)
    a_tot = a_cum[0, -1]                               # ()
    Bq = b_ref[0, 0].astype(jnp.float32)               # (Q, N)
    Cq = c_ref[0, 0].astype(jnp.float32)               # (Q, N)
    xq = dtx_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    h = h_ref[...]                                     # (N, P)

    # intra-chunk: masked decay kernel in the quadratic dual form
    CB = jax.lax.dot_general(Cq, Bq, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    seg = a_cum.T - a_cum                              # (Q, Q) a_i - a_j
    Q = seg.shape[0]
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(causal, CB * jnp.exp(seg), 0.0)
    y_intra = jax.lax.dot_general(M, xq, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    y_inter = jax.lax.dot_general(Cq, h, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(a_cum).T               # (Q, P)

    # chunk-final state update
    w = jnp.exp(a_tot - a_cum).T                       # (Q, 1) decay to end
    S_chunk = jax.lax.dot_general(Bq, xq * w, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(a_tot) + S_chunk

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan(logdec, dtx, Bm, Cm, h0, *, interpret: bool = True):
    """See module docstring for shapes.  Returns (y, h_final)."""
    B, H, nc, Q = logdec.shape
    P = dtx.shape[-1]
    N = Bm.shape[-1]
    grid = (B, H, nc)
    out = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), dtx.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(logdec, dtx, Bm, Cm, h0)
    return out[0], out[1]

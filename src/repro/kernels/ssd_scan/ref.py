"""Pure-jnp oracle: the framework's reference SSD implementation."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd(x, dt, A, Bm, Cm, D, *, chunk_size: int, h0=None):
    """x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm,Cm: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk_size=chunk_size, h0=h0)

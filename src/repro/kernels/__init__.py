"""Pallas TPU kernels (validated with interpret=True on CPU).

  page_copy       — batched page gather/scatter (the pwritev/preadv analogue)
  paged_attention — GQA decode over bitmap-allocated KV pages
  ssd_scan        — Mamba2 SSD chunked scan with VMEM-resident state
"""
from repro.kernels import page_copy, paged_attention, ssd_scan

__all__ = ["page_copy", "paged_attention", "ssd_scan"]

"""jit'd public wrappers for the page_copy kernel.

``as_pages`` reshapes a flat (P, page_elems) pool into the lane-aligned
(P, R, 128) tile layout the kernel requires (page_elems % 128 == 0 is the
pool's alignment contract on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.page_copy import kernel, ref

LANE = kernel.LANE


def as_pages(pool_flat: jax.Array) -> jax.Array:
    P, E = pool_flat.shape
    if E % LANE:
        raise ValueError(f"page_elems {E} not a multiple of {LANE}")
    return pool_flat.reshape(P, E // LANE, LANE)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_pages(pool: jax.Array, idx: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """pool: (P, R, 128) or (P, E); idx: (n,) -> (n, ...) page batch."""
    flat = pool.ndim == 2
    if flat:
        pool = as_pages(pool)
    out = kernel.gather_pages(pool, idx.astype(jnp.int32),
                              interpret=interpret)
    return out.reshape(out.shape[0], -1) if flat else out


@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnames=("pool",))
def scatter_pages(pool: jax.Array, idx: jax.Array, buf: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    flat = pool.ndim == 2
    if flat:
        P, E = pool.shape
        pool = as_pages(pool)
        buf = buf.reshape(buf.shape[0], E // LANE, LANE)
    out = kernel.scatter_pages(pool, idx.astype(jnp.int32), buf,
                               interpret=interpret)
    return out.reshape(out.shape[0], -1) if flat else out

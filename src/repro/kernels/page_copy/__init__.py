from repro.kernels.page_copy import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]

"""Pure-jnp oracle for page gather/scatter."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(pool: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(pool, idx, axis=0)


def scatter_pages(pool: jax.Array, idx: jax.Array,
                  buf: jax.Array) -> jax.Array:
    return pool.at[idx].set(buf)

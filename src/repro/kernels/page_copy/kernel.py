"""page_copy Pallas kernel: batched page gather / scatter.

The TPU-native analogue of the paper's ``pwritev``/``preadv`` insight
(§3.4.2): a *scattered* set of pool pages is converted to/from one
*contiguous* buffer, so host<->device IO for deflate/inflate is a single
sequential DMA stream instead of per-page random access.

  gather : out[i]          = pool[idx[i]]   (deflate compaction, pre-D2H)
  scatter: pool[idx[i]]    = buf[i]         (inflate distribution, post-H2D)

The page indices are *scalar-prefetched* (``PrefetchScalarGridSpec``) so
Mosaic knows every block address before the grid runs — the DMA schedule
is fully static, exactly the io-vector batching of the paper.

Pages are viewed as (rows, 128) lane-aligned tiles; one grid step copies
one page through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _copy_kernel(idx_ref, src_ref, dst_ref):
    del idx_ref                      # consumed by the index maps
    dst_ref[...] = src_ref[...]


def _scatter_kernel(idx_ref, buf_ref, pool_ref, out_ref):
    del idx_ref, pool_ref            # pool is aliased into out
    out_ref[...] = buf_ref[...]


def gather_pages(pool: jax.Array, idx: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """pool: (P, R, 128); idx: (n,) int32 -> (n, R, 128)."""
    P, R, L = pool.shape
    assert L == LANE, f"last dim must be {LANE}"
    n = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, R, LANE),
                               lambda i, idx_ref: (idx_ref[i], 0, 0))],
        out_specs=pl.BlockSpec((1, R, LANE),
                               lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, R, LANE), pool.dtype),
        interpret=interpret,
    )(idx, pool)


def scatter_pages(pool: jax.Array, idx: jax.Array, buf: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """pool[idx[i]] = buf[i].  pool: (P, R, 128); buf: (n, R, 128).

    The pool is aliased in-place (donated) — the kernel only touches the
    pages named in ``idx``; every other page passes through untouched.
    """
    P, R, L = pool.shape
    n = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, R, LANE), lambda i, idx_ref: (i, 0, 0)),      # buf
            pl.BlockSpec((1, R, LANE),
                         lambda i, idx_ref: (idx_ref[i], 0, 0)),           # pool
        ],
        out_specs=pl.BlockSpec((1, R, LANE),
                               lambda i, idx_ref: (idx_ref[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, R, LANE), pool.dtype),
        input_output_aliases={2: 0},       # pool (after the scalar operand)
        interpret=interpret,
    )(idx, buf, pool)

"""Pure-jnp oracle for paged decode attention: gather pages to a dense
cache, then run the framework's reference ``decode_attention``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           scale=None, window: int = 0):
    B, H, D = q.shape
    Hkv, P, T, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    S = pages_per_seq * T
    # dense (B, S, Hkv, D) via page gather
    k_d = k_pages[:, page_table]        # (Hkv, B, pages, T, D)
    v_d = v_pages[:, page_table]
    k_d = k_d.transpose(1, 2, 3, 0, 4).reshape(B, S, Hkv, D)
    v_d = v_d.transpose(1, 2, 3, 0, 4).reshape(B, S, Hkv, D)
    kv_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return decode_attention(q, k_d, v_d, kv_positions, lengths,
                            window=window if window > 0 else None,
                            scale=scale)

from repro.kernels.paged_attention import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]

"""paged_attention Pallas kernel: GQA decode over bitmap-allocated KV pages.

The serving hot loop of the framework: one query token per sequence attends
to a KV cache that lives in *pool pages* (the Bitmap Page Allocator's unit),
reached through a per-sequence page table — compute never needs the cache
to be contiguous, which is what makes deflate/inflate cheap.

TPU mapping (DESIGN.md §6):
  * grid = (batch, kv_heads, pages_per_seq); the page dimension is the
    innermost (sequential) axis, so the online-softmax state for one
    (b, kv_head) lives in VMEM scratch across page steps;
  * the page table and sequence lengths are **scalar-prefetched** so Mosaic
    resolves every K/V block address before the grid starts (static DMA
    schedule, the paper's batched-io insight applied to HBM->VMEM);
  * all G = H/Hkv query heads of one kv head are processed together, so the
    MXU sees a (G, D) x (D, T) matmul per page;
  * K and V pages are (T, D) lane-aligned tiles (T = tokens/page, D = 128).

Out-of-range pages (beyond a sequence's length) are masked via the
position iota; a fully-masked page contributes nothing (the m/l state is
clamped, never NaN).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _decode_kernel(pt_ref, len_ref,            # scalar-prefetched
                   q_ref, k_ref, v_ref,        # VMEM blocks
                   out_ref,                    # VMEM output block
                   m_ref, l_ref, acc_ref,      # VMEM scratch
                   *, page_tokens: int, scale: float, window: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (T, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (T, D)
    length = len_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = p * page_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_tokens), 1)            # (1, T) global positions
    valid = pos < length
    if window > 0:
        valid &= pos > length - 1 - window
    s = jnp.where(valid, s, NEG)                   # (G, T)

    m_prev = m_ref[...]                            # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    pw = jnp.exp(s - m_new)
    pw = jnp.where(valid, pw, 0.0)
    corr = jnp.exp(m_prev - m_new)                 # (G, 1)
    l_ref[...] = l_ref[...] * corr + pw.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pw, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           scale: float | None = None, window: int = 0,
                           interpret: bool = True):
    """q: (B, H, D); k_pages/v_pages: (Hkv, P, T, D);
    page_table: (B, pages_per_seq) int32 (entries past the sequence end may
    be any valid page id — they are masked); lengths: (B,) int32.
    Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv, P, T, _ = k_pages.shape
    G = H // Hkv
    pages_per_seq = page_table.shape[1]
    scale = float(scale if scale is not None else 1.0 / (D ** 0.5))
    qg = q.reshape(B, Hkv, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, p, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, p, pt, ln: (h, pt[b, p], 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda b, h, p, pt, ln: (h, pt[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # m
            pltpu.VMEM((G, 1), jnp.float32),       # l
            pltpu.VMEM((G, D), jnp.float32),       # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, page_tokens=T, scale=scale,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)

"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           scale=None, window: int = 0,
                           interpret: bool = True):
    """GQA decode over paged KV.  See kernel.py for shapes."""
    B, H, D = q.shape
    Hkv, P, T, Dk = k_pages.shape
    if D != Dk:
        raise ValueError(f"head_dim mismatch {D} != {Dk}")
    if H % Hkv:
        raise ValueError(f"q heads {H} not a multiple of kv heads {Hkv}")
    if page_table.ndim != 2 or page_table.shape[0] != B:
        raise ValueError(f"bad page_table shape {page_table.shape}")
    return kernel.paged_decode_attention(
        q, k_pages, v_pages, page_table, lengths,
        scale=scale, window=window, interpret=interpret)

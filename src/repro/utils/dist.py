"""Sharding context: model code stays pure; distribution is injected.

``ShardingRules`` maps *logical* activation names to ``PartitionSpec``s over
the (possibly arch-refined) mesh.  Model code calls ``constrain(x, name)`` at
a handful of cut points; outside a rules context this is the identity, so
unit tests and the CPU serving engine never touch jax device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingRules:
    def __init__(self, mesh: Mesh, specs: dict):
        self.mesh = mesh
        self.specs = dict(specs)

    def spec(self, name: str) -> Optional[P]:
        return self.specs.get(name)

    def sharding(self, name: str) -> Optional[NamedSharding]:
        s = self.spec(name)
        return NamedSharding(self.mesh, s) if s is not None else None


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x, name: str):
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(name)
    if spec is None:
        return x
    if len(spec) == x.ndim + 1:
        # decode-path activations drop the sequence axis (axis 1):
        # (B, S, ...) names apply to (B, ...) values with S removed
        spec = P(*((spec[0],) + tuple(spec[2:])))
    if len(spec) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def axis_size(name: str) -> int:
    """Size of a mesh axis under the current rules (1 when absent)."""
    rules = current_rules()
    if rules is None or name not in rules.mesh.shape:
        return 1
    return rules.mesh.shape[name]

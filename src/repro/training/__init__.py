from repro.training import checkpoint, optim
from repro.training.optim import AdamWConfig, AdamWState, init_state
from repro.training.train import make_eval_step, make_loss_fn, make_train_step

__all__ = ["checkpoint", "optim", "AdamWConfig", "AdamWState", "init_state",
           "make_eval_step", "make_loss_fn", "make_train_step"]

"""Checkpointing: flat path-keyed npz save/restore.

The same path scheme (``layers/attn/wq`` …) is used by the instance's
weight-unit catalog and the shared-weights registry loader, so a saved
checkpoint doubles as the "backing file" for file-backed (shared) weights
(§3.5 of the paper: clean pages are re-read from their file, never swapped).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.instance import _path_str


def flatten_params(params) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return {_path_str(p): np.asarray(v) for p, v in flat}


def save(path: str, params, step: int = 0, extra: Optional[dict] = None
         ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = flatten_params(params)
    np.savez(path, **{k: v for k, v in flat.items()})
    meta = {"step": step, "paths": sorted(flat),
            "extra": extra or {}}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_flat(path: str) -> Dict[str, np.ndarray]:
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def restore(path: str, like_params) -> Tuple[Any, int]:
    """Restore into the structure of ``like_params`` (paths must match)."""
    flat = load_flat(path)
    leaves_like = jax.tree_util.tree_flatten_with_path(like_params)
    paths = [_path_str(p) for p, _ in leaves_like[0]]
    missing = [p for p in paths if p not in flat]
    if missing:
        raise KeyError(f"checkpoint missing paths: {missing[:5]}...")
    leaves = [flat[p] for p in paths]
    params = jax.tree_util.tree_unflatten(leaves_like[1], leaves)
    base = path[:-4] if path.endswith(".npz") else path
    meta_path = base + ".meta.json"
    step = 0
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            step = json.load(f).get("step", 0)
    return params, step

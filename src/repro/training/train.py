"""Training step: loss -> grads -> AdamW update, remat-aware, pjit-ready.

``make_train_step(cfg, opt_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with explicit in/out shardings (launch/dryrun.py) or for plain
CPU execution in the smoke tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.training import optim


def make_loss_fn(cfg, *, remat: bool = True):
    def loss(params, batch):
        return model.loss_fn(
            params, cfg, batch["tokens"], batch["labels"],
            embeds=batch.get("embeds"), enc_frames=batch.get("frames"),
            remat=remat)
    return loss


def make_train_step(cfg, opt_cfg: optim.AdamWConfig, *, remat: bool = True):
    loss_fn = make_loss_fn(cfg, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = optim.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "ce": aux["ce"], "lb_loss": aux["lb_loss"],
                   **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    loss_fn = make_loss_fn(cfg, remat=False)

    def eval_step(params, batch):
        loss, aux = loss_fn(params, batch)
        return {"loss": loss, **aux}

    return eval_step

"""AdamW optimizer (pure-jax, pytree-native) with global-norm clipping.

State is a pytree mirroring the params (m, v in f32) — shardable with the
same partition specs as the parameters (ZeRO/FSDP-style when the specs
shard over the data axis; see launch/sharding.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    m: Any                     # pytree like params, f32
    v: Any                     # pytree like params, f32


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}

"""Unified transformer block + layer-scanned stack for every assigned family.

Blocks are pure functions over explicit parameter pytrees.  The stack scans
over layers with stacked parameters (leading ``num_layers`` axis) so a
60-layer model lowers to a compact HLO.  Decode carries per-layer caches as
scan xs/ys.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_norm, init_norm, init_mlp, apply_mlp, rmsnorm
from repro.utils.dist import constrain


def _has_attn(cfg) -> bool:
    return cfg.attention != "none"


def _has_ssm(cfg) -> bool:
    return cfg.ssm is not None


def _has_mlp(cfg) -> bool:
    return cfg.d_ff > 0 and cfg.moe is None


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg, *, cross: bool = False, is_encoder: bool = False):
    ks = jax.random.split(key, 8)
    p = {"ln1": init_norm(ks[0], cfg)}
    if _has_attn(cfg):
        p["attn"] = (attn.init_mla(ks[1], cfg) if cfg.attention == "mla"
                     else attn.init_gqa(ks[1], cfg))
    if _has_ssm(cfg) and not is_encoder:
        p["ssm"] = ssm_mod.init_ssm(ks[2], cfg)
        if cfg.hybrid_parallel_ssm:
            p["attn_out_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["ssm_out_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cross:
        p["ln_x"] = init_norm(ks[3], cfg)
        p["xattn"] = attn.init_gqa(ks[4], cfg, cross=True)
    if cfg.moe is not None and not is_encoder:
        p["ln2"] = init_norm(ks[5], cfg)
        p["moe"] = moe_mod.init_moe(ks[6], cfg)
    elif _has_mlp(cfg):
        p["ln2"] = init_norm(ks[5], cfg)
        p["mlp"] = init_mlp(ks[6], cfg)
    return p


def _mix_full(p, h, cfg, positions, *, causal, window):
    """Sequence-mixing sublayer on normed input h (full-sequence path)."""
    cache = {}
    outs = []
    if _has_attn(cfg):
        if cfg.attention == "mla":
            a_out, (ckv, krope) = attn.mla_forward(
                p["attn"], h, cfg, positions, causal=causal, window=window)
            cache["ckv"], cache["krope"] = ckv, krope
        else:
            a_out, (k, v) = attn.gqa_forward(
                p["attn"], h, cfg, positions, causal=causal, window=window)
            cache["k"], cache["v"] = k, v
        outs.append(("attn", a_out))
    if _has_ssm(cfg):
        s_out, s_cache = ssm_mod.ssm_forward(p["ssm"], h, cfg)
        cache["state"], cache["conv"] = s_cache["state"], s_cache["conv"]
        outs.append(("ssm", s_out))
    if len(outs) == 2:    # hymba: mean of per-branch-normalised outputs
        a = rmsnorm(outs[0][1], p["attn_out_norm"])
        s = rmsnorm(outs[1][1], p["ssm_out_norm"])
        return 0.5 * (a + s), cache
    return outs[0][1], cache


def block_forward(p, x, cfg, positions, *, causal: bool = True,
                  window: Optional[int] = None, enc_out=None):
    """x: (B,S,d).  Returns (x', cache, aux)."""
    h = apply_norm(p["ln1"], x, cfg)
    mix, cache = _mix_full(p, h, cfg, positions, causal=causal, window=window)
    x = x + mix
    if "xattn" in p:
        B, Se, _ = enc_out.shape
        Hkv, D = cfg.num_kv_heads, cfg.head_dim
        ck = (enc_out @ p["xattn"]["wk"]).reshape(B, Se, Hkv, D)
        cv = (enc_out @ p["xattn"]["wv"]).reshape(B, Se, Hkv, D)
        cache["cross_k"], cache["cross_v"] = ck, cv
        h = apply_norm(p["ln_x"], x, cfg)
        xa, _ = attn.gqa_forward(p["xattn"], h, cfg, positions,
                                 causal=False, kv=(ck, cv))
        x = x + xa
    aux = {"lb_loss": jnp.float32(0.0)}
    if "moe" in p:
        h = apply_norm(p["ln2"], x, cfg)
        m_out, m_aux = moe_mod.apply_moe(p["moe"], h, cfg)
        x = x + m_out
        aux["lb_loss"] = m_aux["lb_loss"]
        aux["expert_counts"] = m_aux["expert_counts"]
    elif "mlp" in p:
        h = apply_norm(p["ln2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    x = constrain(x, "act_btd")
    return x, cache, aux


def block_decode(p, x, cfg, cache, lengths, kv_positions, *,
                 window: Optional[int] = None, axis_name=None):
    """Single-token step.  x: (B,d).  cache: the FULL stacked cache dict
    (leaves (L, B, ...)); ``layer_idx`` selects this block's slice.

    In-place cache discipline (§Perf P3): the new token's K/V (or SSD
    state) is scattered into the *carried* stacked cache — a few KB of
    writes — and attention reads the layer slice.  The earlier design
    emitted whole per-layer caches as scan ys, rewriting the entire KV
    cache every decode step (~2x cache bytes/token of pure overhead).
    """
    cache, layer_idx = cache
    B = x.shape[0]
    h = apply_norm(p["ln1"], x, cfg)
    outs = []
    li = layer_idx

    def _layer(leaf):
        return jax.lax.dynamic_index_in_dim(leaf, li, 0, keepdims=False)

    if _has_attn(cfg):
        Smax = (cache["ckv"] if cfg.attention == "mla"
                else cache["k"]).shape[2]
        slot = (lengths - 1) % Smax
        bidx = jnp.arange(B)
        if cfg.attention == "mla":
            ckv_new, krope_new = attn.mla_new_latent(p["attn"], h, cfg,
                                                     lengths)
            cache["ckv"] = cache["ckv"].at[li, bidx, slot].set(
                ckv_new.astype(cache["ckv"].dtype))
            cache["krope"] = cache["krope"].at[li, bidx, slot].set(
                krope_new.astype(cache["krope"].dtype))
            a_out = attn.mla_decode(
                p["attn"], h, cfg, _layer(cache["ckv"]),
                _layer(cache["krope"]),
                kv_positions, lengths, window=window, axis_name=axis_name)
        else:
            k_new, v_new = attn.gqa_new_kv(p["attn"], h, cfg, lengths)
            cache["k"] = cache["k"].at[li, bidx, slot].set(
                k_new.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[li, bidx, slot].set(
                v_new.astype(cache["v"].dtype))
            a_out = attn.gqa_decode(
                p["attn"], h, cfg, _layer(cache["k"]), _layer(cache["v"]),
                kv_positions, lengths, window=window, axis_name=axis_name)
        outs.append(("attn", a_out))
    if _has_ssm(cfg):
        s_out, s_cache = ssm_mod.ssm_decode(
            p["ssm"], h, cfg, {"state": _layer(cache["state"]),
                               "conv": _layer(cache["conv"])})
        cache["state"] = cache["state"].at[li].set(s_cache["state"])
        cache["conv"] = cache["conv"].at[li].set(
            s_cache["conv"].astype(cache["conv"].dtype))
        outs.append(("ssm", s_out))
    if len(outs) == 2:
        a = rmsnorm(outs[0][1], p["attn_out_norm"])
        s = rmsnorm(outs[1][1], p["ssm_out_norm"])
        mix = 0.5 * (a + s)
    else:
        mix = outs[0][1]
    x = x + mix
    if "xattn" in p:
        h = apply_norm(p["ln_x"], x, cfg)
        cross_k, cross_v = _layer(cache["cross_k"]), _layer(cache["cross_v"])
        enc_len = cross_k.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(enc_len)[None], (B, enc_len))
        xa = attn.gqa_decode(
            p["xattn"], h, cfg, cross_k, cross_v,
            enc_pos, jnp.full((B,), enc_len, lengths.dtype), cross=True)
        x = x + xa
    aux = {}
    if "moe" in p:
        h = apply_norm(p["ln2"], x, cfg)
        m_out, m_aux = moe_mod.apply_moe(p["moe"], h[:, None], cfg)
        x = x + m_out[:, 0]
        aux["expert_counts"] = m_aux["expert_counts"]
    elif "mlp" in p:
        h = apply_norm(p["ln2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, cache, aux


# ---------------------------------------------------------------------------
# layer-scanned stack
# ---------------------------------------------------------------------------

def init_stack(key, cfg, num_layers: int, *, cross: bool = False,
               is_encoder: bool = False):
    keys = jax.random.split(key, num_layers)
    blocks = [init_block(k, cfg, cross=cross, is_encoder=is_encoder)
              for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def stack_forward(stacked, x, cfg, positions, *, causal=True, window=None,
                  enc_out=None, collect_cache: bool = False,
                  remat: bool = False):
    """Scan the block over stacked layer params.  Returns (x, caches, aux).

    ``aux["expert_counts"]``: (L, E) per-layer router usage for MoE archs —
    consumed by the REAP working-set recorder.
    """
    has_moe = cfg.moe is not None
    E = cfg.moe.num_experts if has_moe else 0

    def body(carry, layer_p):
        x, lb = carry
        x, cache, aux = block_forward(layer_p, x, cfg, positions,
                                      causal=causal, window=window,
                                      enc_out=enc_out)
        lb = lb + aux["lb_loss"]
        counts = aux.get("expert_counts",
                         jnp.zeros((E,), jnp.int32)) if has_moe else None
        out = (cache if collect_cache else None, counts)
        return (x, lb), out

    step = jax.checkpoint(body) if remat else body
    (x, lb), (caches, counts) = jax.lax.scan(
        step, (x, jnp.float32(0.0)), stacked)
    aux = {"lb_loss": lb / max(cfg.num_layers, 1)}
    if has_moe:
        aux["expert_counts"] = counts
    return x, caches, aux


def stack_decode(stacked, x, cfg, caches, lengths, kv_positions, *,
                 window=None, axis_name=None):
    has_moe = cfg.moe is not None
    E = cfg.moe.num_experts if has_moe else 0

    L = cfg.num_layers

    def step(carry, xs):
        x, caches = carry
        layer_p, li = xs
        x, caches, aux = block_decode(layer_p, x, cfg, (caches, li),
                                      lengths, kv_positions, window=window,
                                      axis_name=axis_name)
        counts = aux.get("expert_counts",
                         jnp.zeros((E,), jnp.int32)) if has_moe else None
        return (x, caches), counts

    (x, new_caches), counts = jax.lax.scan(
        step, (x, caches), (stacked, jnp.arange(L)))
    aux = {"expert_counts": counts} if has_moe else {}
    return x, new_caches, aux

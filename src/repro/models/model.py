"""Top-level model API: init / forward / loss / prefill / decode_step.

Covers all assigned families behind one interface:
  * dense / MoE / MLA decoders (tokens),
  * VLM (tokens + stub patch embeddings, prepended llava-style),
  * audio enc-dec (stub frame embeddings -> encoder -> cross-attn decoder),
  * SSM / hybrid (state caches instead of / alongside KV).

The decode cache is a pytree:
  {"layers": {leaf: (L, B, ...)}, "lengths": (B,), "kv_positions": (B, Smax)}
Slot writes use ``(pos % Smax)`` so a sliding-window cache is a ring buffer
(long_500k dense variant), and ``kv_positions`` keeps the *global* position
per slot for exact masking.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import apply_norm, embed_init, init_norm, dense_init
from repro.utils.dist import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng, cfg):
    ks = jax.random.split(rng, 8)
    dtype = jnp.dtype(cfg.dtype)
    Vp, d = cfg.padded_vocab, cfg.d_model
    p = {"embed": embed_init(ks[0], (Vp, d), dtype),
         "layers": tfm.init_stack(ks[1], cfg, cfg.num_layers,
                                  cross=cfg.is_encoder_decoder),
         "final_norm": init_norm(ks[2], cfg)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[3], (d, Vp), 0, dtype)
    fe = cfg.frontend
    if fe.kind != "none" and fe.embed_dim and fe.embed_dim != d:
        p["frontend_proj"] = dense_init(ks[4], (fe.embed_dim, d), 0, dtype)
    if cfg.is_encoder_decoder:
        p["pos_embed"] = embed_init(ks[5], (cfg.max_position, d), dtype)
        p["enc"] = {
            "pos_embed": embed_init(ks[6], (cfg.encoder_max_len, d), dtype),
            "layers": tfm.init_stack(ks[7], cfg, cfg.encoder_layers,
                                     is_encoder=True),
            "final_norm": init_norm(ks[2], cfg),
        }
    return p


# ---------------------------------------------------------------------------
# input embedding
# ---------------------------------------------------------------------------

def _tok_embed(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def embed_inputs(params, cfg, tokens, embeds: Optional[jax.Array] = None,
                 position_offset: int = 0):
    """tokens: (B, S_txt); embeds: optional (B, S_fe, fe_dim) stub frontend
    output, prepended (llava-style).  Returns (x (B,S,d), positions (B,S))."""
    x = _tok_embed(params, cfg, tokens)
    if embeds is not None and not cfg.is_encoder_decoder:
        e = embeds
        if "frontend_proj" in params:
            e = e @ params["frontend_proj"]
        x = jnp.concatenate([e.astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = position_offset + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.is_encoder_decoder:
        x = x + jnp.take(params["pos_embed"],
                         jnp.minimum(positions, cfg.max_position - 1), axis=0)
    return x, positions


def encode(params, cfg, frames):
    """Whisper encoder on stub frame embeddings (B, S_enc, d)."""
    enc = params["enc"]
    if "frontend_proj" in params:
        frames = frames @ params["frontend_proj"].astype(frames.dtype)
    B, Se, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    x = frames.astype(jnp.dtype(cfg.dtype)) + jnp.take(enc["pos_embed"],
                                                       pos, axis=0)
    x, _, _ = tfm.stack_forward(enc["layers"], x, cfg, pos, causal=False)
    return apply_norm(enc["final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# full-sequence forward
# ---------------------------------------------------------------------------

def forward_hidden(params, cfg, tokens, embeds=None, enc_frames=None,
                   window: Optional[int] = None, collect_cache: bool = False,
                   remat: bool = False):
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, enc_frames)
    x, positions = embed_inputs(params, cfg, tokens, embeds)
    x = constrain(x, "act_btd")
    x, caches, aux = tfm.stack_forward(
        params["layers"], x, cfg, positions, causal=True, window=window,
        enc_out=enc_out, collect_cache=collect_cache, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg)
    return x, caches, aux


def unembed(params, cfg, x):
    """x: (..., d) -> logits (..., Vp) in f32."""
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    return constrain(logits, "logits_btv")


def logits_full(params, cfg, tokens, embeds=None, enc_frames=None):
    """Small-scale convenience path (tests): full (B,S,Vp) logits."""
    x, _, aux = forward_hidden(params, cfg, tokens, embeds, enc_frames)
    return unembed(params, cfg, x), aux


def loss_fn(params, cfg, tokens, labels, embeds=None, enc_frames=None,
            loss_chunk: int = 1024, remat: bool = False):
    """Chunked cross-entropy: never materialises (B,S,V) logits.

    labels: (B, S_txt) with -1 = masked.  When embeds are prepended, hidden
    states are sliced back to the text region before the LM head.
    """
    x, _, aux = forward_hidden(params, cfg, tokens, embeds, enc_frames,
                               remat=remat)
    if embeds is not None and not cfg.is_encoder_decoder:
        x = x[:, -tokens.shape[1]:]
    B, S, d = x.shape
    C = min(loss_chunk, S)
    if S % C:
        C = S
    nc = S // C
    xs = (x.reshape(B, nc, C, d).transpose(1, 0, 2, 3),
          labels.reshape(B, nc, C).transpose(1, 0, 2))

    def step(carry, xs_c):
        tot, cnt = carry
        xc, yc = xs_c
        logits = unembed(params, cfg, xc)                  # (B,C,Vp) f32
        mask = (yc >= 0) & (yc < cfg.vocab_size)
        y = jnp.where(mask, yc, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], -1)[..., 0]
        nll = (lse - gold) * mask
        return (tot + nll.sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)), xs)
    ce = tot / jnp.maximum(cnt, 1.0)
    lb_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    loss = ce + lb_w * aux["lb_loss"]
    return loss, {"ce": ce, "lb_loss": aux["lb_loss"]}


# ---------------------------------------------------------------------------
# decode cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: Optional[int] = None):
    """Allocate an empty decode cache (dense slot layout; ring-buffered)."""
    L, B, Smax = cfg.num_layers, batch, max_len
    dtype = jnp.dtype(cfg.dtype)
    layers = {}
    if cfg.attention == "mla":
        m = cfg.mla
        layers["ckv"] = jnp.zeros((L, B, Smax, m.kv_lora_rank), dtype)
        layers["krope"] = jnp.zeros((L, B, Smax, m.qk_rope_head_dim), dtype)
    elif cfg.attention == "gqa":
        layers["k"] = jnp.zeros((L, B, Smax, cfg.num_kv_heads, cfg.head_dim),
                                dtype)
        layers["v"] = jnp.zeros_like(layers["k"])
    if cfg.ssm is not None:
        s = cfg.ssm
        layers["state"] = jnp.zeros(
            (L, B, cfg.ssm_heads, s.state_dim, s.head_dim), jnp.float32)
        layers["conv"] = jnp.zeros(
            (L, B, s.conv_width - 1, cfg.d_inner + 2 * s.state_dim), dtype)
    if cfg.is_encoder_decoder:
        e = enc_len or cfg.encoder_max_len
        layers["cross_k"] = jnp.zeros((L, B, e, cfg.num_kv_heads,
                                       cfg.head_dim), dtype)
        layers["cross_v"] = jnp.zeros_like(layers["cross_k"])
    return {"layers": layers,
            "lengths": jnp.zeros((B,), jnp.int32),
            "kv_positions": jnp.full((B, Smax), -1, jnp.int32)}


_SLOT_LEAVES = ("k", "v", "ckv", "krope")


def prefill(params, cfg, tokens, max_len: int, embeds=None, enc_frames=None,
            window: Optional[int] = None, with_aux: bool = False):
    """Full-sequence prefill.  Returns (last-token logits (B,Vp), cache)."""
    x, caches, aux = forward_hidden(params, cfg, tokens, embeds, enc_frames,
                                  window=window, collect_cache=True)
    B, S = x.shape[0], x.shape[1]
    assert S <= max_len, "prefill longer than cache"
    layers = {}
    for k, vv in (caches or {}).items():
        if k in _SLOT_LEAVES:
            pad = [(0, 0)] * vv.ndim
            pad[2] = (0, max_len - S)
            layers[k] = jnp.pad(vv, pad)
        else:
            layers[k] = vv
    kv_positions = jnp.where(jnp.arange(max_len)[None] < S,
                             jnp.arange(max_len)[None], -1)
    kv_positions = jnp.broadcast_to(kv_positions, (B, max_len)).astype(jnp.int32)
    cache = {"layers": layers,
             "lengths": jnp.full((B,), S, jnp.int32),
             "kv_positions": kv_positions}
    logits = unembed(params, cfg, x[:, -1])
    if with_aux:
        return logits, cache, aux
    return logits, cache


def decode_step(params, cfg, tokens, cache, *,
                window: Optional[int] = None, axis_name=None,
                with_aux: bool = False):
    """One decode step.  tokens: (B,) int32.  Returns (logits (B,Vp), cache)."""
    lengths = cache["lengths"] + 1
    x = _tok_embed(params, cfg, tokens)                   # (B, d)
    if cfg.is_encoder_decoder:
        pos = jnp.minimum(lengths - 1, cfg.max_position - 1)
        x = x + jnp.take(params["pos_embed"], pos, axis=0)
    kv_positions = cache["kv_positions"]
    if kv_positions.shape[1] > 0 and cfg.attention != "none":
        Smax = kv_positions.shape[1]
        slot = (lengths - 1) % Smax
        kv_positions = kv_positions.at[jnp.arange(x.shape[0]), slot].set(
            lengths - 1)
    x, new_layers, aux = tfm.stack_decode(
        params["layers"], x, cfg, cache["layers"], lengths, kv_positions,
        window=window, axis_name=axis_name)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params, cfg, x)
    new_cache = {"layers": new_layers, "lengths": lengths,
                 "kv_positions": kv_positions}
    if with_aux:
        return logits, new_cache, aux
    return logits, new_cache

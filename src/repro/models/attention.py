"""Attention: blocked-flash GQA (causal / sliding-window), MLA, decode paths.

Prefill/train use a pure-jnp blocked flash attention (two-level ``lax.scan``
with online softmax) so a 32 k-token prefill never materialises an S×S score
matrix.  Decode uses a partial-softmax formulation that composes with
sequence-parallel KV shards via an optional ``axis_name`` (flash-decode
logsumexp combine — DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rmsnorm
from repro.utils.dist import constrain

_NEG = -1e30


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, cross: bool = False):
    d, H, Hkv, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    return {"wq": dense_init(ks[0], (d, H * D), 0, dtype),
            "wk": dense_init(ks[1], (d, Hkv * D), 0, dtype),
            "wv": dense_init(ks[2], (d, Hkv * D), 0, dtype),
            "wo": dense_init(ks[3], (H * D, d), 0, dtype)}


def init_mla(key, cfg):
    m, d, H = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), 0, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk), 0, dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            0, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    H * (m.qk_nope_head_dim + m.v_head_dim)),
                            0, dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), 0, dtype),
    }


# ---------------------------------------------------------------------------
# blocked flash attention (pure jnp oracle-grade, used for train/prefill)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    q_block: int = 512, kv_block: int = 1024,
                    scale: Optional[float] = None):
    """q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D) with H % Hkv == 0.  Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    nq = -(-Sq // qb)
    nk = -(-Sk // kb)
    qp, kp = nq * qb - Sq, nk * kb - Sk
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))

    # (n, B, blk, ...) so both levels scan over the leading axis
    qs = q.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    ks_ = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, xs):
        qblk, qi = xs                                     # (B,qb,Hkv,G,D)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, kxs):
            m, l, acc = carry
            kblk, vblk, ki = kxs
            kpos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < Sk
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qb), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks_, vs, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,Hkv,G,qb,D)
        return None, out.transpose(0, 3, 1, 2, 4)         # (B,qb,Hkv,G,D)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, H, D)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention with partial-softmax combine (flash-decode)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, kv_positions, lengths, *,
                     window: Optional[int] = None,
                     axis_name: Optional[str] = None,
                     scale: Optional[float] = None):
    """Single-token decode.

    q: (B, H, D); k,v: (B, S_local, Hkv, D) — a (possibly sequence-sharded)
    slice of the cache.  kv_positions: (B, S_local) global token positions of
    each cache slot (-1 for empty).  lengths: (B,) current sequence lengths.
    When ``axis_name`` is given the caches of all shards on that mesh axis
    are combined exactly via logsumexp (psum of corrected partial sums).
    """
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    valid = (kv_positions >= 0) & (kv_positions < lengths[:, None])
    if window is not None:
        valid = valid & (kv_positions > lengths[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = s.max(-1)                                          # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(-1)
    # accumulate in f32 WITHOUT materialising an f32 copy of the V cache
    # (§Perf P3: v.astype(f32) doubled decode HBM traffic)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    if axis_name is not None:
        m_g = jax.lax.pmax(m, axis_name)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, axis_name)
        o = jax.lax.psum(o * corr[..., None], axis_name)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block forward
# ---------------------------------------------------------------------------

def gqa_project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (x @ p["wk"]).reshape(B, S, Hkv, D)
    v = (x @ p["wv"]).reshape(B, S, Hkv, D)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_mode)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_mode)
    q = constrain(q, "act_bshd")
    k = constrain(k, "act_bskd")
    v = constrain(v, "act_bskd")
    return q, k, v


def gqa_forward(p, x, cfg, positions, *, causal=True, window=None,
                kv=None):
    """Full-sequence attention.  kv: optional external (k, v) for cross-attn."""
    B, S, _ = x.shape
    if kv is None:
        q, k, v = gqa_project_qkv(p, x, cfg, positions)
    else:
        H, D = cfg.num_heads, cfg.head_dim
        q = (x @ p["wq"]).reshape(B, S, H, D)
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_mode)
        k, v = kv
    out = flash_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(B, S, -1)
    return out @ p["wo"], (k, v)


def gqa_decode(p, x, cfg, cache_k, cache_v, kv_positions, lengths, *,
               window=None, axis_name=None, cross=False):
    """x: (B, d).  cache_k/v: (B, S_local, Hkv, D).  Returns (B, d)."""
    B = x.shape[0]
    H, D = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, H, D)
    if not cross and cfg.rope_mode != "none":
        pos = (lengths - 1)[:, None]
        q = apply_rope(q[:, None], pos, cfg.rope_theta, cfg.rope_mode)[:, 0]
    out = decode_attention(q, cache_k, cache_v, kv_positions, lengths,
                           window=window, axis_name=axis_name)
    return out.reshape(B, H * D) @ p["wo"]


def gqa_new_kv(p, x, cfg, lengths):
    """Project this step's token into (k, v) cache entries.  x: (B, d)."""
    B = x.shape[0]
    Hkv, D = cfg.num_kv_heads, cfg.head_dim
    k = (x @ p["wk"]).reshape(B, Hkv, D)
    v = (x @ p["wv"]).reshape(B, Hkv, D)
    if cfg.rope_mode != "none":
        pos = (lengths - 1)[:, None]
        k = apply_rope(k[:, None], pos, cfg.rope_theta, cfg.rope_mode)[:, 0]
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent cache + absorbed decode
# ---------------------------------------------------------------------------

def _mla_q(p, x, cfg, positions):
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rmsnorm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latent(p, x, cfg, positions):
    """Compress x into the cached latent: (ckv (B,S,r), k_rope (B,S,rd))."""
    m = cfg.mla
    ckv_full = x @ p["wkv_a"]
    ckv = rmsnorm(ckv_full[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = ckv_full[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None], positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_forward(p, x, cfg, positions, *, causal=True, window=None):
    """Prefill/train path: expand latent to per-head K/V, flash attention."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    nd, vd = m.qk_nope_head_dim, m.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, k_rope = mla_latent(p, x, cfg, positions)
    kv = (ckv @ p["wkv_b"]).reshape(B, S, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None], (B, S, H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    scale = 1.0 / math.sqrt(nd + m.qk_rope_head_dim)
    # pad v head_dim up to qk dim so flash can run one fused pass
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, k.shape[-1] - vd)))
    out = flash_attention(q, k, v_pad, causal=causal, window=window,
                          scale=scale)[..., :vd]
    out = out.reshape(B, S, H * vd)
    return out @ p["wo"], (ckv, k_rope)


def mla_decode(p, x, cfg, cache_ckv, cache_krope, kv_positions, lengths, *,
               window=None, axis_name=None):
    """Absorbed decode: score and value in latent space (never expand cache).

    cache_ckv: (B, S_local, r); cache_krope: (B, S_local, rd).
    """
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    nd, vd, r = m.qk_nope_head_dim, m.v_head_dim, m.kv_lora_rank
    pos = (lengths - 1)[:, None]
    q_nope, q_rope = _mla_q(p, x[:, None], cfg, pos)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]           # (B,H,·)
    wkv = p["wkv_b"].reshape(r, H, nd + vd)
    w_k, w_v = wkv[..., :nd], wkv[..., nd:]               # (r,H,nd),(r,H,vd)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_k,
                       preferred_element_type=jnp.float32)  # absorb W_UK
    # latent-cache dots accumulate in f32 without f32 cache copies (P3)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(cache_ckv.dtype),
                    cache_ckv, preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bsd->bhs", q_rope, cache_krope,
                      preferred_element_type=jnp.float32))
    s = s * (1.0 / math.sqrt(nd + m.qk_rope_head_dim))
    valid = (kv_positions >= 0) & (kv_positions < lengths[:, None])
    if window is not None:
        valid = valid & (kv_positions > lengths[:, None] - 1 - window)
    s = jnp.where(valid[:, None, :], s, _NEG)
    mx = s.max(-1)
    pw = jnp.where(valid[:, None, :], jnp.exp(s - mx[..., None]), 0.0)
    l = pw.sum(-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pw.astype(cache_ckv.dtype),
                       cache_ckv, preferred_element_type=jnp.float32)
    if axis_name is not None:
        m_g = jax.lax.pmax(mx, axis_name)
        corr = jnp.exp(mx - m_g)
        l = jax.lax.psum(l * corr, axis_name)
        o_lat = jax.lax.psum(o_lat * corr[..., None], axis_name)
    o_lat = o_lat / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.einsum("bhr,rhv->bhv", o_lat, w_v.astype(jnp.float32))
    return out.reshape(B, H * vd).astype(x.dtype) @ p["wo"]


def mla_new_latent(p, x, cfg, lengths):
    pos = (lengths - 1)[:, None]
    ckv, k_rope = mla_latent(p, x[:, None], cfg, pos)
    return ckv[:, 0], k_rope[:, 0]

"""Mixture-of-experts: scatter-based GShard-style dispatch, expert-parallel.

Dispatch path (DESIGN.md §4): tokens are processed in fixed-size chunks
(``lax.scan``) so the (E, C, d) capacity buffer stays small; positions within
an expert are computed with a cumulative one-hot (no sort); over-capacity
assignments fall into a sacrificial slot that is sliced off (token dropping,
standard GShard semantics).  Under distribution the buffer's expert axis is
sharding-constrained to the model axis, which lowers to an all-to-all.

Supports DeepSeek-style shared experts (always-on) and Arctic's dense
residual MLP in parallel with the routed experts.

Router decisions are also *recorded* (``expert_counts`` aux) — this feeds
the REAP working-set recorder: only experts that actually fired for a
sample request are prefetched on wake-up (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, apply_mlp
from repro.utils.dist import constrain


def init_moe(key, cfg):
    mo, d = cfg.moe, cfg.d_model
    f = mo.expert_d_ff
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    E = mo.num_experts

    def ew(k, shape, in_axis):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, shape, in_axis, dtype)
                          for kk in keys])

    p = {"router": dense_init(ks[0], (d, E), 0, jnp.float32),
         "w_gate": ew(ks[1], (d, f), 0),
         "w_up": ew(ks[2], (d, f), 0),
         "w_down": ew(ks[3], (f, d), 1)}
    if mo.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, d_ff=f * mo.num_shared_experts)
        p["shared"] = init_mlp(ks[4], shared_cfg)
    if mo.dense_residual:
        p["dense"] = init_mlp(ks[5], cfg)
    return p


def _route_chunk(p, xc, cfg):
    """xc: (T, d) -> (out (T, d), aux dict)."""
    mo = cfg.moe
    T, d = xc.shape
    E, K = mo.num_experts, mo.top_k
    C = max(4, int(T * K / E * mo.capacity_factor + 0.999))
    C = min(C, T)

    logits = (xc.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                 # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # position of each assignment inside its expert: cumulative one-hot over
    # the flattened (T*K) assignment stream (row-major: token-major order)
    flat_e = top_e.reshape(-1)                             # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*K, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1               # position per expert
    flat_pos = jnp.take_along_axis(pos_all, flat_e[:, None], 1)[:, 0]

    keep = flat_pos < C
    slot = jnp.where(keep, flat_pos, C)                    # sacrificial slot C

    # dispatch: scatter tokens into (E, C+1, d)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E, C + 1, d), xc.dtype)
    buf = buf.at[flat_e, slot].add(xc[tok_idx])
    buf = buf[:, :C]
    buf = constrain(buf, "moe_ecd")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = constrain(h, "moe_ecf")
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    eout = constrain(eout, "moe_ecd")

    # combine: gather each kept assignment's expert output, weighted
    safe_slot = jnp.minimum(slot, C - 1)
    gathered = eout[flat_e, safe_slot]                     # (T*K, d)
    w = (top_w.reshape(-1) * keep).astype(jnp.float32)
    out = jnp.zeros((T, d), jnp.float32)
    out = out.at[tok_idx].add(gathered.astype(jnp.float32) * w[:, None])

    # aux: load-balance loss terms + per-expert counts (REAP recorder)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                           axis=(0, 1)) * K
    mean_prob = probs.mean(0)
    lb = E * jnp.sum(frac_tokens * mean_prob)
    counts = onehot.sum(0)                                 # (E,) int32
    dropped = jnp.sum(~keep)
    return out.astype(xc.dtype), {"lb_loss": lb, "expert_counts": counts,
                                  "dropped": dropped}


def _route_and_ffn(p_moe, xf, cfg, *, chunk_tokens: int):
    """Chunk-scanned routed-expert path on (T, d) tokens."""
    T, d = xf.shape
    TC = min(chunk_tokens, T)
    if T % TC:
        TC = T                        # fall back to one chunk (small inputs)
    nchunk = T // TC
    xs = xf.reshape(nchunk, TC, d)

    def step(_, xc):
        return None, _route_chunk(p_moe, xc, cfg)

    _, (outs, auxs) = jax.lax.scan(step, None, xs)
    aux = {"lb_loss": auxs["lb_loss"].mean(),
           "expert_counts": auxs["expert_counts"].sum(0),
           "dropped": auxs["dropped"].sum()}
    return outs.reshape(T, d), aux


# ---------------------------------------------------------------------------
# expert parallelism: explicit all-to-all over the "data" mesh axis
# ---------------------------------------------------------------------------

def _ep_inner(p, xl, cfg, D: int, chunk_tokens: int, ep_axes=("data",)):
    """Per-data-shard body (§Perf P1): local routing -> capacity buffer ->
    all_to_all to the expert owners -> local expert FFN -> all_to_all back
    -> local combine.  Collective cost: 2 x K x cf x d bytes per token,
    vs the scatter path's GSPMD lowering which all-reduces the whole
    (E, C, d) buffer per chunk per layer."""
    mo = cfg.moe
    E, K = mo.num_experts, mo.top_k
    E_loc = E // D
    Bl, S, d = xl.shape
    T = Bl * S
    xf = xl.reshape(T, d)
    TC = min(chunk_tokens, T)
    if T % TC:
        TC = T
    nchunk = T // TC

    def chunk(xc):
        Tc = xc.shape[0]
        C = max(4, int(Tc * K / E * mo.capacity_factor + 0.999))
        C = min(C, Tc)
        logits = (xc.astype(jnp.float32) @ p["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - 1
        flat_pos = jnp.take_along_axis(pos_all, flat_e[:, None], 1)[:, 0]
        keep = flat_pos < C
        slot = jnp.where(keep, flat_pos, C)
        tok_idx = jnp.repeat(jnp.arange(Tc), K)
        buf = jnp.zeros((E, C + 1, d), xc.dtype)
        buf = buf.at[flat_e, slot].add(xc[tok_idx])      # local scatter
        buf = buf[:, :C]

        # (P1 iter 3 + P5, both refuted: constraining the capacity dim over
        # sp, or the payload d over tp, adds resharding collectives around
        # the manual all_to_all that exceed the redundancy they remove —
        # see EXPERIMENTS.md §Perf.)
        # ship each expert's rows to its owner: (E, C, d) -> (E_loc, D*C, d)
        recv = jax.lax.all_to_all(buf, ep_axes, split_axis=0, concat_axis=1,
                                  tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", recv, p["w_up"])
        eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
        # results return to the token owners: (E_loc, D*C, d) -> (E, C, d)
        back = jax.lax.all_to_all(eout, ep_axes, split_axis=1, concat_axis=0,
                                  tiled=True)

        safe_slot = jnp.minimum(slot, C - 1)
        gathered = back[flat_e, safe_slot]
        w = (top_w.reshape(-1) * keep).astype(jnp.float32)
        out = jnp.zeros((Tc, d), jnp.float32)
        out = out.at[tok_idx].add(gathered.astype(jnp.float32) * w[:, None])

        frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                        axis=(0, 1)) * K
        lb = E * jnp.sum(frac * probs.mean(0))
        return out.astype(xc.dtype), lb, onehot.sum(0), jnp.sum(~keep)

    def step(_, xc):
        return None, chunk(xc)

    _, (outs, lbs, counts, dropped) = jax.lax.scan(
        step, None, xf.reshape(nchunk, TC, d))
    out = outs.reshape(Bl, S, d)
    # globalise the aux stats (REAP needs global router counts)
    lb = jax.lax.pmean(lbs.mean(), ep_axes)
    counts = jax.lax.psum(counts.sum(0), ep_axes)
    dropped = jax.lax.psum(dropped.sum(), ep_axes)
    return out, lb, counts, dropped


def _ep_axes(mesh):
    """Expert-parallel axes: pod + data (cross-pod EP on the multi-pod
    mesh — leaving pod automatic re-creates the scatter pathology as
    pod-axis all-reduces of the dispatch buffers)."""
    return tuple(a for a in ("pod", "data")
                 if mesh.shape.get(a, 1) > 1)


def _apply_moe_ep(p, x, cfg, mesh, *, chunk_tokens: int):
    from jax.sharding import PartitionSpec as P

    axes = _ep_axes(mesh)
    D = 1
    for a in axes:
        D *= mesh.shape[a]
    # manual over the EP axes only; tp/sp stay automatic (GSPMD keeps
    # sharding the expert FFN hidden dim and the batch residue)
    fn = jax.shard_map(
        functools.partial(_ep_inner, cfg=cfg, D=D, chunk_tokens=chunk_tokens,
                          ep_axes=axes),
        mesh=mesh,
        in_specs=({"router": P(None, None), "w_gate": P(axes, None, None),
                   "w_up": P(axes, None, None),
                   "w_down": P(axes, None, None)},
                  P(axes, None, None)),
        out_specs=(P(axes, None, None), P(), P(), P()),
        axis_names=set(axes), check_vma=False)
    p_routed = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}
    out, lb, counts, dropped = fn(p_routed, x)
    return out, {"lb_loss": lb, "expert_counts": counts, "dropped": dropped}


def _ep_applicable(x, cfg, rules) -> bool:
    if rules is None:
        return False
    axes = _ep_axes(rules.mesh)
    D = 1
    for a in axes:
        D *= rules.mesh.shape[a]
    return (D > 1 and x.shape[0] % D == 0
            and cfg.moe.num_experts % D == 0
            and x.shape[0] * x.shape[1] >= 4 * D)


def apply_moe(p, x, cfg, *, chunk_tokens: int = 4096):
    """x: (B, S, d) -> (out, aux).

    Distributed (dry-run / production) path: explicit expert parallelism
    over the "data" axis via shard_map + all_to_all when the batch shards
    evenly (train/prefill); otherwise (single host, tiny batches, decode)
    the GSPMD scatter path with the moe_ecd sharding constraint.
    """
    from repro.utils.dist import current_rules

    mo = cfg.moe
    B, S, d = x.shape
    rules = current_rules()
    if _ep_applicable(x, cfg, rules):
        out, aux = _apply_moe_ep(p, x, cfg, rules.mesh,
                                 chunk_tokens=chunk_tokens)
    else:
        outf, aux = _route_and_ffn(
            {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")},
            x.reshape(B * S, d), cfg, chunk_tokens=chunk_tokens)
        out = outf.reshape(B, S, d)
    if mo.num_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg)
    if mo.dense_residual:
        out = out + apply_mlp(p["dense"], x, cfg)
    return out, aux

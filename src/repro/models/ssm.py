"""Mamba2 SSD (state-space duality) block — chunked dual-form scan.

Follows arXiv:2405.21060: per-head scalar decay A, shared B/C projections
(single group), depthwise causal conv on (x|B|C), gated RMSNorm output.
The chunked algorithm computes intra-chunk outputs in the quadratic dual
form (MXU-friendly matmuls) and carries inter-chunk states with a
``lax.scan`` — O(S·N·P) instead of O(S²).

``ssd_chunked`` here is the reference math; the Pallas kernel in
``repro.kernels.ssd_scan`` implements the same contraction with explicit
VMEM tiling and is validated against ``repro.kernels.ssd_scan.ref`` (which
calls back into this module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm
from repro.utils.dist import constrain


def init_ssm(key, cfg):
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H, N = cfg.ssm_heads, s.state_dim
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), 0, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d), 0, dtype),
    }


def _split_in_proj(p, u, cfg):
    s, di, H, N = cfg.ssm, cfg.d_inner, cfg.ssm_heads, cfg.ssm.state_dim
    proj = u @ p["in_proj"]
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    return z, xBC, dt


def ssd_chunked(x, dt, A, B, C, D, *, chunk_size: int, h0=None):
    """SSD forward.

    x: (B,S,H,P) inputs; dt: (B,S,H) positive step sizes; A: (H,) negative;
    B,C: (B,S,N) (shared across heads); D: (H,) skip.
    Returns y: (B,S,H,P) and final state (B,H,N,P).
    """
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk_size, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    xc = x.reshape(Bb, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bb, nc, Q, H).astype(jnp.float32)
    Bc = B.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cc = C.reshape(Bb, nc, Q, N).astype(jnp.float32)

    logdec = dtc * A[None, None, None, :]                 # (B,nc,Q,H) ≤ 0
    a_cum = jnp.cumsum(logdec, axis=2)                    # within-chunk cumsum
    a_tot = a_cum[:, :, -1]                               # (B,nc,H)

    # intra-chunk (dual quadratic form)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)            # (B,nc,Q,Q)
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    M = CB[..., None] * decay                             # (B,nc,Q,Q,H)
    xdt = xc * dtc[..., None]                             # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xdt)

    # chunk-final states
    dec_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)    # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, dec_to_end, xdt)

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((Bb, H, N, P), jnp.float32)

    def step(h, xs):
        a_t, s_c = xs                                     # (B,H), (B,H,N,P)
        h_new = h * jnp.exp(a_t)[:, :, None, None] + s_c
        return h_new, h                                   # emit state *before* chunk

    a_sw = a_tot.transpose(1, 0, 2)                       # (nc,B,H)
    s_sw = S_chunk.transpose(1, 0, 2, 3, 4)
    h_final, h_prev = jax.lax.scan(step, h0.astype(jnp.float32), (a_sw, s_sw))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", Cc, h_prev) \
        * jnp.exp(a_cum)[..., None]
    y = y_intra + y_inter + xc * D[None, None, None, :, None]
    y = y.reshape(Bb, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv.  xBC: (B,S,C); conv_w: (W,C).

    Returns (out (B,S,C), new_conv_state (B,W-1,C)).
    """
    W = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]),
                               xBC.dtype)
    xp = jnp.concatenate([conv_state, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i][None, None]
              for i in range(W))
    out = jax.nn.silu(out + conv_b[None, None])
    new_state = xp[:, -(W - 1):] if W > 1 else conv_state
    return out, new_state


def ssm_forward(p, u, cfg, *, conv_state=None, h0=None):
    """Full-sequence SSD block.  u: (B,S,d) -> (B,S,d), cache."""
    s = cfg.ssm
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, s.state_dim, s.head_dim
    Bb, S, _ = u.shape
    z, xBC, dt = _split_in_proj(p, u, cfg)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x = xBC[..., :di].reshape(Bb, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    x = constrain(x, "ssm_bshp")
    dt_a = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_chunked(x, dt_a, A, Bm, Cm, p["D"],
                       chunk_size=s.chunk_size, h0=h0)
    y = y.reshape(Bb, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], {"state": h, "conv": new_conv}


def ssm_decode(p, u, cfg, cache):
    """Single-token SSD step.  u: (B,d); cache: {"state","conv"}."""
    s = cfg.ssm
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, s.state_dim, s.head_dim
    Bb = u.shape[0]
    z, xBC, dt = _split_in_proj(p, u[:, None], cfg)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                 cache["conv"])
    xBC, z, dt = xBC[:, 0], z[:, 0], dt[:, 0]
    x = xBC[..., :di].reshape(Bb, H, P).astype(jnp.float32)
    Bm = xBC[..., di:di + N].astype(jnp.float32)
    Cm = xBC[..., di + N:].astype(jnp.float32)
    dt_a = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    h = cache["state"]
    dA = jnp.exp(dt_a * A[None])                          # (B,H)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm, dt_a, x)
    h = h * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm, h) + x * p["D"][None, :, None]
    y = y.reshape(Bb, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return (y @ p["out_proj"]).astype(u.dtype), {"state": h, "conv": new_conv}

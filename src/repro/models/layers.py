"""Shared layers: norms, rotary embeddings, MLPs, parameter init."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils.dist import constrain


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def init_norm(key, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, x, cfg):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, rotary_dim=None):
    rotary_dim = rotary_dim or head_dim
    exponent = jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim
    return 1.0 / (theta ** exponent)                      # (rotary_dim/2,)


def apply_rope(x, positions, theta: float, mode: str = "full"):
    """x: (B, S, H, D); positions: (B, S) int32.

    mode "full": rotate all D dims.  mode "2d": ChatGLM-style partial rotary
    — rotate only the first half of D, pass the rest through.
    """
    if mode == "none":
        return x
    D = x.shape[-1]
    rot = D if mode == "full" else D // 2
    inv = rope_freqs(D, theta, rot)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]                     # (B,S,1,rot/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    if rot < D:
        out = jnp.concatenate([out, x[..., rot:].astype(jnp.float32)], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    if cfg.activation == "swiglu":
        return {"w_gate": dense_init(ks[0], (d, f), 0, dtype),
                "w_up": dense_init(ks[1], (d, f), 0, dtype),
                "w_down": dense_init(ks[2], (f, d), 0, dtype)}
    return {"w_up": dense_init(ks[0], (d, f), 0, dtype),
            "w_down": dense_init(ks[1], (f, d), 0, dtype)}


def apply_mlp(p, x, cfg):
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = constrain(h, "act_btf")
    return h @ p["w_down"]

"""repro: Hibernate Container reproduced as a JAX/TPU serving framework."""
__version__ = "0.1.0"

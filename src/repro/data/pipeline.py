"""Deterministic synthetic data pipeline.

Seeded, host-side (numpy) token stream generator with a Zipfian unigram
distribution plus injected copy patterns, so the LM loss has learnable
structure (the copy spans give an in-context signal that a training run
can visibly reduce).  Batches are yielded as the pytrees consumed by
``train_step``: ``{"tokens": (B, S), "labels": (B, S)}`` (+ stub frontend
embeddings for VLM/audio archs).

Fully deterministic given (seed, step): batches can be re-generated for
any step, which makes checkpoint-resume bit-exact without storing data
state beyond the step counter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2
    copy_span: int = 16          # length of injected copy patterns
    copy_prob: float = 0.5       # fraction of rows with a copy pattern


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, frontend=None):
        self.cfg = cfg
        self.frontend = frontend       # FrontendConfig or None
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_alpha)
        self._probs = w / w.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S), p=self._probs)
        toks = toks.astype(np.int32)
        # inject copy patterns: span repeated later in the row
        n_copy = int(B * cfg.copy_prob)
        L = min(cfg.copy_span, S // 4)
        if L > 1 and n_copy:
            rows = rng.choice(B, n_copy, replace=False)
            for r in rows:
                src = rng.integers(0, S // 2 - L)
                dst = rng.integers(S // 2, S - L)
                toks[r, dst:dst + L] = toks[r, src:src + L]
        labels = np.concatenate(
            [toks[:, 1:], np.full((B, 1), -1, np.int32)], axis=1)
        out = {"tokens": toks, "labels": labels}
        if self.frontend is not None and self.frontend.kind != "none":
            out["embeds" if self.frontend.kind == "vision" else "frames"] = \
                rng.standard_normal(
                    (B, self.frontend.num_embeddings,
                     self.frontend.embed_dim or 1)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

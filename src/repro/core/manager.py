"""Multi-tenant InstanceManager: the "Serverless Platform" control plane.

Implements the platform-side behaviours of the paper:
  * cold start (①): init/load weights + compile — the expensive path;
  * keep-alive with *deflate-instead-of-evict* under memory pressure;
  * predictive wake (⑤) and request-driven wake (⑦), with a wake-storm
    guard: concurrent requests racing to inflate the same hibernating
    tenant share a single batched inflate (`ensure_awake`);
  * shared base-weight registry (§3.5): refcounted "file-backed" leaves,
    re-read from the checkpoint at refcount 0->1.

The manager is thread-safe for the AsyncPlatform's worker pool: the
instance table is lock-guarded and each instance has a wake lock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.governor import GovernorConfig, MemoryGovernor
from repro.core.hibernate import HibernationManager
from repro.core.inflate import InflatorPool
from repro.core.instance import ModelInstance
from repro.core.pool import PagePool
from repro.core.state import (DEFLATE_EVENT_FOR, ContainerState, Event,
                              Rung)
from repro.core.store import StorePolicy, SwapStore
from repro.core.prefix import PREFIX_OWNER, PrefixRegistry
from repro.core.zygote import ZygoteConfig, ZygotePool, is_zygote_id

#: ladder states a wake (request-driven or predictive) climbs out of
WAKEABLE_STATES = (ContainerState.HIBERNATE, ContainerState.PARTIAL,
                   ContainerState.MMAP_CLEAN)


class SharedWeightsRegistry:
    """Refcounted shared base weights (the runtime-binary mmap analogue).

    ``loader(base_id) -> {path: np.ndarray}`` plays the role of the backing
    file: dropping the weights at refcount zero costs nothing to write
    (file-backed pages are clean) but re-acquiring re-reads the checkpoint.
    """

    def __init__(self, loader: Callable[[str], Dict[str, np.ndarray]]):
        self.loader = loader
        self._weights: Dict[str, Dict[str, np.ndarray]] = {}
        self._refs: Dict[str, int] = {}
        self.reload_count = 0

    def acquire(self, base_id: str, inst: Optional[ModelInstance] = None
                ) -> Dict[str, np.ndarray]:
        """Incref ``base_id`` (loading at 0->1) and, when ``inst`` is
        given, map the shared buffers into its weight table — every
        sharer sees the *same* ndarrays, the mmap analogue."""
        if base_id not in self._weights:
            self._weights[base_id] = self.loader(base_id)
            self.reload_count += 1
        self._refs[base_id] = self._refs.get(base_id, 0) + 1
        w = self._weights[base_id]
        if inst is not None:
            for path, arr in w.items():
                inst.weights[path] = arr        # share the same buffers
        return w

    def release(self, base_id: str) -> int:
        """Decref; drop at zero.  Returns bytes released (0 if still shared)."""
        self._refs[base_id] -= 1
        if self._refs[base_id] > 0:
            return 0
        w = self._weights.pop(base_id, {})
        return sum(a.nbytes for a in w.values())

    def refcount(self, base_id: str) -> int:
        """Current sharer count for ``base_id`` (0 if never acquired)."""
        return self._refs.get(base_id, 0)

    def is_loaded(self, base_id: str) -> bool:
        """True while the shared buffers are resident (refcount > 0)."""
        return base_id in self._weights


@dataclass
class ManagerConfig:
    """Per-node sizing and policy for one :class:`InstanceManager`."""

    spool_dir: str = "/tmp/repro_spool"
    pool_capacity_pages: int = 1 << 15
    pool_page_elems: int = 16384
    keep_alive_s: float = 600.0          # warm keep-alive window
    memory_limit_bytes: Optional[int] = None
    share_base_weights: bool = True      # §3.5 policy knob
    wake_mode: str = "reap"              # "reap" | "pagefault"
    #: content-addressed swap tier (§3.4 de-dup table, cross-tenant).
    #: False falls back to PR-1 private per-sandbox SwapFiles.
    dedup_store: bool = True
    #: per-deployment hash salt; None generates a fresh random one
    store_salt: Optional[bytes] = None
    store_policy: Optional[StorePolicy] = None
    #: streamed wake pipeline (repro.core.inflate): ``ensure_awake``
    #: returns at the prefill-critical prefix while the tail inflates in
    #: the background.  False restores the fully-synchronous REAP wake.
    pipelined_wake: bool = True
    #: pipeline chunk size: one vectored read / one install per chunk —
    #: small enough that the critical prefix is not diluted by tail
    #: neighbours sharing its chunks, large enough to amortize syscalls
    wake_chunk_bytes: int = 256 << 10
    #: per-deployment inflator worker threads (read double-buffering +
    #: background lookahead fetches)
    inflate_workers: int = 3
    #: turn serviced faults into asynchronous next-layer prefetch
    lookahead: bool = True
    #: node-wide memory budget the :class:`~repro.core.governor.
    #: MemoryGovernor` enforces over ALL tenants (None = no budget: the
    #: governor only acts when a pressure target is passed explicitly)
    memory_budget_bytes: Optional[int] = None
    #: governor knobs (headroom, rung thresholds, terminate policy);
    #: None uses :class:`~repro.core.governor.GovernorConfig` defaults
    governor_policy: Optional[GovernorConfig] = None
    #: kept-alive metadata a hibernated husk is charged for (page tables,
    #: compiled handles).  The default is deliberately tiny; cluster
    #: benchmarks raise it to paper-realistic husk/warm ratios so the
    #: TERMINATED/MIGRATING economics have teeth.
    husk_metadata_bytes: int = 1 << 16
    #: deployment-wide resident KV prefix registry
    #: (:mod:`repro.core.prefix`): sessions whose prompt token-hash is
    #: registered COW-adopt the resident pages instead of prefilling
    prefix_sharing: bool = True
    #: prompts shorter than this never enter the registry
    prefix_min_tokens: int = 4
    #: background store-scrub cadence: every ``scrub_interval_s`` the
    #: store CRC-verifies up to ``scrub_bytes_per_round`` of segments,
    #: quarantining corruption (and repairing it from replica peers when
    #: the cluster router has installed a ``repair_source``).  None
    #: disables the daemon; requires ``dedup_store``.
    scrub_interval_s: Optional[float] = None
    scrub_bytes_per_round: int = 64 << 20
    #: zygote fork donors (:mod:`repro.core.zygote`): a
    #: :class:`~repro.core.zygote.ZygoteConfig` keeps a pool of
    #: pre-initialized per-family instances so a brand-new tenant is
    #: admitted by warm fork instead of cold init; None disables the pool
    #: (``fork_start`` then always falls back to ``cold_start``)
    zygote_pool: Optional[ZygoteConfig] = None


class InstanceManager:
    """The per-node "Serverless Platform" control plane: owns the
    instance table, the shared-weight registry, the swap/CAS tier, the
    wake pipeline, and the memory governor.  Tenants enter via
    ``cold_start`` or ``fork_start``, descend the deflation ladder via
    ``descend``, and wake via ``ensure_awake``; all entry points are
    safe under the AsyncPlatform's worker pool."""

    def __init__(self, cfg: ManagerConfig,
                 factory: Callable[[str], tuple],
                 shared_loader: Optional[Callable] = None):
        """``factory(arch_key) -> (model_cfg, params_pytree)`` builds a cold
        instance (init or checkpoint load) — the expensive cold-start work."""
        self.cfg = cfg
        self.factory = factory
        self.pool = PagePool(cfg.pool_page_elems, np.float32,
                             cfg.pool_capacity_pages)
        self.shared = (SharedWeightsRegistry(shared_loader)
                       if (shared_loader and cfg.share_base_weights) else None)
        self.store = (SwapStore(f"{cfg.spool_dir}/store.cas",
                                salt=cfg.store_salt,
                                policy=cfg.store_policy)
                      if cfg.dedup_store else None)
        if self.store is not None and cfg.scrub_interval_s is not None:
            self.store.start_scrubber(
                interval_s=cfg.scrub_interval_s,
                bytes_per_round=cfg.scrub_bytes_per_round)
        self.inflator = InflatorPool(cfg.inflate_workers)
        self.prefix_registry = (PrefixRegistry(
            self.pool, self.store, salt=cfg.store_salt,
            min_tokens=cfg.prefix_min_tokens)
            if cfg.prefix_sharing else None)
        self.hib = HibernationManager(self.shared, inflator=self.inflator,
                                      wake_chunk_bytes=cfg.wake_chunk_bytes)
        self.instances: Dict[str, ModelInstance] = {}
        self.governor = MemoryGovernor(
            self, budget_bytes=cfg.memory_budget_bytes,
            cfg=cfg.governor_policy)
        self.events: List[tuple] = []
        self._lock = threading.RLock()                 # instance table
        self._wake_locks: Dict[str, threading.Lock] = {}
        #: tenants migrated off this node -> target node id, so straggler
        #: requests raise ``TenantMigrated`` (rerouted by the cluster
        #: router) instead of cold-starting a duplicate here.  Entries are
        #: dropped if the tenant ever migrates back (``admit``).
        self.migrated: Dict[str, str] = {}
        #: wake-storm accounting: inflates actually performed vs callers
        #: that arrived wanting one and found it already done/in flight
        self.wakes_performed = 0
        self.wakes_deduped = 0
        #: zygote fork donors; None when the pool is not configured
        self.zygotes: Optional[ZygotePool] = \
            ZygotePool(self, cfg.zygote_pool) \
            if cfg.zygote_pool is not None else None
        #: fork-storm accounting, mirroring the wake counters: forks
        #: actually performed vs callers that found the tenant already
        #: admitted by a concurrent fork
        self.forks_performed = 0
        self.forks_deduped = 0
        #: eviction hook the platform layer registers so governor-driven
        #: TERMINATED descents also drop its per-tenant state (request
        #: queue entry, engine serve lock) — without it, tenant churn
        #: under terminate_idle_s grows those tables unboundedly
        self.on_evict: Optional[Callable[[str], None]] = None

    def _wake_lock(self, instance_id: str) -> threading.Lock:
        with self._lock:
            lock = self._wake_locks.get(instance_id)
            if lock is None:
                lock = self._wake_locks[instance_id] = threading.Lock()
            return lock

    # ------------------------------------------------------------- lifecycle
    def cold_start(self, instance_id: str, arch_key: str,
                   shared_paths=None) -> ModelInstance:
        """① Admit a tenant the expensive way: run the factory (init or
        checkpoint load), acquire the shared base weights, and enter the
        state graph through ``COLD_START`` — the path ``fork_start``
        exists to avoid.  Returns the installed instance."""
        model_cfg, params = self.factory(arch_key)
        inst = ModelInstance(
            instance_id, model_cfg, params, pool=self.pool,
            spool_dir=self.cfg.spool_dir,
            shared_paths=shared_paths if self.shared else None,
            base_id=arch_key if self.shared else None,
            store=self.store,
            metadata_bytes=self.cfg.husk_metadata_bytes,
            arch_key=arch_key)
        if self.shared and inst.base_id and inst.shared_paths:
            self.shared.acquire(inst.base_id, inst)
        inst.sm.fire(Event.COLD_START)
        with self._lock:
            self.instances[instance_id] = inst
        if self.zygotes is not None and not is_zygote_id(instance_id):
            # a cold start IS a new-tenant admission the pool missed —
            # it trains the same fork-avoidance signal a fork does
            self.zygotes.note_admission(arch_key)
        self.events.append((time.monotonic(), "cold_start", instance_id))
        return inst

    def fork_start(self, instance_id: str, arch_key: str,
                   shared_paths=None) -> Optional[ModelInstance]:
        """Admit a brand-new tenant by specializing a zygote (warm fork).

        Returns None when no pool is configured or no live zygote of the
        family exists — the caller falls back to ``cold_start``.  The
        fork order is refcount-safe: the tenant acquires its own
        shared-registry ref *before* the donor's is released, so the
        shared base never dips to refcount 0 (no checkpoint re-read, and
        retiring the donor can never free a forked tenant's pages).  The
        tenant inherits the donor's compiled executables (same family ⇒
        same model config object from the factory cache) and copies its
        anonymous weights — a memcpy, not an init.  Concurrent callers
        for one tenant dedup on the per-instance wake lock: exactly one
        fork happens, late arrivals get the installed instance
        (``forks_deduped``).
        """
        if self.zygotes is None:
            return None
        with self._wake_lock(instance_id):
            with self._lock:
                existing = self.instances.get(instance_id)
            if existing is not None:
                self.forks_deduped += 1
                return existing
            zyg = self.zygotes.take(arch_key)
            if zyg is None:
                return None
            inst = ModelInstance(
                instance_id, zyg.cfg, zyg.params_pytree(), pool=self.pool,
                spool_dir=self.cfg.spool_dir,
                shared_paths=shared_paths if self.shared else None,
                base_id=arch_key if self.shared else None,
                store=self.store,
                metadata_bytes=self.cfg.husk_metadata_bytes,
                arch_key=arch_key)
            if self.shared and inst.base_id and inst.shared_paths:
                self.shared.acquire(inst.base_id, inst)
            inst.compiled = zyg.compiled
            inst.sm.fire(Event.FORK)
            with self._lock:
                self.instances[instance_id] = inst
            self._consume_zygote(zyg)
            self.zygotes.note_admission(arch_key)
            self.zygotes.forked += 1
            self.forks_performed += 1
            self.events.append((time.monotonic(), "fork", instance_id,
                                zyg.instance_id))
            return inst

    def _consume_zygote(self, zyg: ModelInstance) -> None:
        # the donor dies by being forked: (ZYGOTE, FORK) -> DEAD.  Its
        # shared ref is released AFTER the tenant took one (fork_start
        # ordering), so release never drops the base to zero here.
        zid = zyg.instance_id
        with self._lock:
            self.instances.pop(zid, None)
            self._wake_locks.pop(zid, None)
        self.hib._release_mmap(zyg)
        zyg.sm.fire(Event.FORK)
        zyg.terminate()
        self.governor.forget(zid)
        if self.zygotes is not None:
            self.zygotes.note_evicted(zid)
        self.events.append((time.monotonic(), "zygote_consumed", zid))

    def descend(self, instance_id: str, rung, *, keys=None):
        """Walk one tenant down the deflation ladder to ``rung``.

        The single rung-addressed entry point the governor, router, and
        gateway all speak — ``rung`` is a :class:`~repro.core.state.Rung`
        and dispatch is validated against ``DEFLATE_EVENT_FOR`` (an
        unreachable rung for the tenant's current state raises
        ``InvalidTransition`` from the state machine, exactly like the
        underlying event would).

        * ``Rung.MMAP_CLEAN`` — drop the clean file-backed mmap bytes.
        * ``Rung.PARTIAL`` — swap out ``keys`` (cold unit keys); when
          ``keys`` is None the governor's partial-victim scan picks the
          coldest units, so callers without their own victim policy get
          the ladder's.
        * ``Rung.HIBERNATED`` — full deflate (working set to REAP +
          store, host state dropped).
        * ``Rung.TERMINATED`` — evict: the container is destroyed.

        Returns the rung's ``DeflateStats`` (``None`` for TERMINATED).
        """
        rung = Rung(rung)
        if rung not in DEFLATE_EVENT_FOR:
            raise ValueError(f"{rung!r} is not a deflation target")
        inst = self.instances[instance_id]
        if rung == Rung.TERMINATED:
            self.evict(instance_id)
            return None
        if rung == Rung.MMAP_CLEAN:
            st = self.hib.deflate_mmap(inst)
        elif rung == Rung.PARTIAL:
            if keys is None:
                keys = [k for _, _, k in
                        self.governor._partial_candidates(inst)]
            st = self.hib.deflate_partial(inst, keys)
        else:
            st = self.hib.deflate(inst)
        # every descent path (governor pressure, keep-alive, router)
        # accumulates the tenant's wake footprint — what a pre-inflate
        # or the elasticity demand model expects the wake to re-occupy;
        # observe_wake resets it when the bytes come back
        gov = self.governor
        gov.footprint[instance_id] = gov.footprint.get(instance_id, 0) \
            + st.swap_bytes + st.shared_bytes_released
        return st

    def ensure_awake(self, instance_id: str, trigger: str = "request",
                     priority: Optional[str] = None):
        """Inflate a hibernating instance exactly once per storm.

        Any number of threads may call this concurrently for the same
        instance (request-driven ⑦ and predictive ⑤ wakes both route
        here); the per-instance wake lock guarantees a single batched
        inflate, and late arrivals are counted in ``wakes_deduped``.
        Returns the :class:`WakeStats` for the thread that performed the
        inflate, ``None`` for everyone else.

        With the pipelined wake the performer returns as soon as the
        prefill-critical prefix is resident; late arrivals (and the
        engine's fault path) find the in-flight stream handle on
        ``inst.wake_pipeline`` and demand-pull from it rather than issuing
        their own reads.  Anticipatory wakes (``trigger="sigcont"``) run
        the same pipeline at low priority unless overridden.
        """
        inst = self.instances.get(instance_id)
        if inst is not None and inst.state == ContainerState.MIGRATING:
            # in-flight-request handoff: block on the transfer handle the
            # way late wake arrivals block on the shared wake pipeline.
            # When it completes the tenant lives on the target node (or
            # aborted back to HIBERNATE) — the caller re-resolves.
            handle = inst.migration
            self.wakes_deduped += 1
            if handle is not None:
                handle.wait()
            return None
        if inst is None or inst.state not in WAKEABLE_STATES:
            return None
        if priority is None:
            priority = "low" if trigger == "sigcont" else "high"
        with self._wake_lock(instance_id):
            state = inst.state
            if state not in WAKEABLE_STATES:
                self.wakes_deduped += 1        # someone else woke it first
                return None
            if state in (ContainerState.HIBERNATE, ContainerState.PARTIAL) \
                    and inst.inflated:
                self.wakes_deduped += 1        # someone else inflated first
                return None
            if state == ContainerState.MMAP_CLEAN and not inst.mmap_dropped:
                self.wakes_deduped += 1        # someone else re-mapped first
                return None
            if trigger == "request" and state == ContainerState.HIBERNATE \
                    and self.cfg.wake_mode != "reap":
                # pagefault mode: units fault in lazily.  Still mark the
                # cycle as woken under the wake lock, or a racing sigcont
                # wake could fire after the engine's REQUEST transition.
                inst.inflated = True
                return None
            self.wakes_performed += 1
            st = self.hib.wake(inst, mode=self.cfg.wake_mode,
                               trigger=trigger,
                               pipelined=self.cfg.pipelined_wake,
                               priority=priority)
            # the governor learns measured per-rung wake costs from here
            self.governor.observe_wake(instance_id, st)
            return st

    def predictive_wake(self, instance_id: str, priority: str = "low"):
        """⑤ control-plane wake in anticipation of a request — the
        streamed pipeline at low priority (no read double-buffering,
        yields between chunks): a real request arriving mid-stream is
        absorbed by the same pipeline via demand-pull."""
        return self.ensure_awake(instance_id, trigger="sigcont",
                                 priority=priority)

    # ------------------------------------------------------------- cluster
    def detach(self, instance_id: str, target: Optional[str] = None) -> None:
        """Migration commit on the *source* node: drop the instance from
        the table without firing EVICT (the state machine already walked
        MIGRATE -> MIGRATE_DONE -> DEAD) and remember where it went so a
        straggler request can be rerouted.  The caller owns releasing the
        instance's disk state (swap-store refs, REAP file)."""
        with self._lock:
            self.instances.pop(instance_id, None)
            self._wake_locks.pop(instance_id, None)
            if target is not None:
                self.migrated[instance_id] = target
        if self.prefix_registry is not None:
            self.prefix_registry.forget_owner(instance_id)
        self.governor.forget(instance_id)
        if self.on_evict is not None:
            self.on_evict(instance_id)
        self.events.append((time.monotonic(), "migrate_out", instance_id))

    def admit(self, inst: ModelInstance) -> None:
        """Migration commit on the *target* node: install a rebuilt
        instance (hibernated: weights/KV are digests in this node's store,
        REAP file rebuilt, recorder state shipped)."""
        with self._lock:
            self.instances[inst.instance_id] = inst
            self.migrated.pop(inst.instance_id, None)
        self.events.append((time.monotonic(), "migrate_in",
                            inst.instance_id))

    def evict(self, instance_id: str) -> None:
        """TERMINATED: destroy the container — release its shared mmap
        ref, its prefix sharer slots, and its swap files (§3.4); zygotes
        retire through here too (``(ZYGOTE, EVICT) -> DEAD``)."""
        with self._lock:
            inst = self.instances.pop(instance_id)
            self._wake_locks.pop(instance_id, None)
        # refcount-balanced: a ladder descent (mmap_clean/partial/full
        # deflate) already released the shared mmap; the flag knows
        self.hib._release_mmap(inst)
        inst.sm.fire(Event.EVICT)
        # release the evicted tenant's prefix sharer slots BEFORE terminate
        # frees its pool owner: a last-sharer-down spill must still find
        # the registry's own refs alive to content-address the pages
        if self.prefix_registry is not None:
            self.prefix_registry.forget_owner(instance_id)
        inst.terminate()                       # swap files deleted (§3.4)
        self.governor.forget(instance_id)
        if self.zygotes is not None:
            self.zygotes.note_evicted(instance_id)
        if self.on_evict is not None:
            self.on_evict(instance_id)
        self.events.append((time.monotonic(), "evict", instance_id))

    # ------------------------------------------------------------- policy
    def resident_bytes(self) -> int:
        """Deployment-wide resident application bytes, PSS-accounted:
        private weights + proportional pool shares per tenant, shared
        base weights once per loaded ``base_id``, the prefix registry's
        own pinned share once."""
        tot = 0
        seen_shared = set()
        with self._lock:
            insts = list(self.instances.values())
        for inst in insts:
            tot += inst.weight_bytes(resident_only=True, include_shared=False)
            # PSS, not RSS: prefix pages COW-adopted by several tenants
            # (and pinned by the registry itself) are charged one
            # proportional share per mapper, never once per mapper in full
            tot += int(inst.pool.pss_bytes(inst.instance_id))
        if self.prefix_registry is not None:
            tot += int(self.pool.pss_bytes(PREFIX_OWNER))
        for inst in insts:
            if self.shared and inst.base_id and \
                    inst.base_id not in seen_shared and \
                    self.shared.is_loaded(inst.base_id) and inst.shared_paths:
                tot += inst.shared_weight_bytes()
                seen_shared.add(inst.base_id)
        return tot

    def handle_memory_pressure(self, target_bytes: Optional[int] = None,
                               try_lock: Optional[Callable] = None,
                               now: Optional[float] = None) -> List[str]:
        """Reclaim memory down to a target by walking victims down the
        deflation ladder — delegates to the :class:`MemoryGovernor`
        (cost/benefit victim selection, proportional reclaim).

        ``target_bytes=None`` uses the configured node budget
        (``ManagerConfig.memory_budget_bytes``); passing a value enforces
        a one-off target.  ``try_lock(instance_id)`` (optional) must
        return a lock to acquire non-blocking around each deflate;
        instances currently being served are skipped instead of racing
        the engine's state machine.  Returns the ids acted on.
        """
        actions = self.governor.step(now=now, try_lock=try_lock,
                                     budget_bytes=target_bytes)
        acted = list(dict.fromkeys(a.instance_id for a in actions))
        self.events.append((time.monotonic(), "pressure", tuple(acted)))
        return acted

    def states(self) -> Dict[str, str]:
        """``{instance_id: state value}`` snapshot of the table."""
        with self._lock:
            return {k: v.state.value for k, v in self.instances.items()}

"""Traffic forecasting: the predictive half of the autoscaling control plane.

The governor's per-tenant EWMA of inter-arrival gaps is *memoryless*: it
answers "how often does this tenant arrive" but not "when will it arrive
next".  A diurnal tenant that sleeps all night looks permanently idle at
07:59 and pays a cold wake at 08:00 — exactly the leading-edge latency
the deflation ladder exists to hide.  This module upgrades the signal:

  * **Seasonal bins** — each tenant accumulates arrivals into
    ``n_bins`` phase bins of a repeating ``season_period_s`` window
    (diurnal by default, virtual-time scale in benchmarks).  Completed
    periods fold into a per-bin rate EWMA, so the model learns *where in
    the period* the tenant is active.
  * **Trend + flash-crowd detection** — short-window vs long-window
    arrival rates; a short rate ``burst_ratio`` times the background
    rate (with a minimum arrival count, so two packets are not a crowd)
    flags an active burst.
  * **Confidence-weighted blend** — the seasonal prediction is mixed
    with the caller's memoryless EWMA gap by a confidence weight built
    from sample count, observed periods, and per-bin consistency
    (signal-to-noise of the bin's rate EWMA vs its absolute-error EWMA).
    A sparse or anti-seasonal tenant degrades gracefully to the reactive
    EWMA — never below it.

:class:`ForecastDaemon` is the actuator: it walks deflated tenants whose
blended prediction says a request is due within the pre-inflate margin
and wakes them through the existing low-priority wake pipeline
(``InstanceManager.predictive_wake``), and revives a deployment's
spilled KV prefixes by digest ahead of the burst so the first request
COW-adopts instead of paying revive + prefill.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

__all__ = [
    "ForecastConfig",
    "TenantModel",
    "TrafficForecaster",
    "ForecastDaemon",
]


@dataclass
class ForecastConfig:
    """Knobs for :class:`TrafficForecaster` and :class:`ForecastDaemon`.

    The defaults are wall-clock diurnal; virtual-time benchmarks shrink
    ``season_period_s`` to their trace period.  All windows are in the
    same (virtual or wall) clock the caller feeds to ``observe``.
    """

    #: length of one repeating seasonal window (default: one day)
    season_period_s: float = 86400.0
    #: phase bins per period (48 = half-hour bins at the default period)
    n_bins: int = 48
    #: cross-period EWMA smoothing for per-bin arrival rates
    bin_alpha: float = 0.4
    #: flash-crowd short window (the "now" rate)
    short_window_s: float = 5.0
    #: background window the short rate is compared against
    long_window_s: float = 60.0
    #: short rate must exceed ``burst_ratio`` x background to flag a burst
    burst_ratio: float = 4.0
    #: ... and at least this many arrivals must land inside the short
    #: window (two packets are not a crowd)
    burst_min_arrivals: int = 6
    #: a bin's seasonal rate only earns trust after this many completed
    #: periods with data
    min_periods: int = 2
    #: arrivals needed for full sample-count confidence
    confidence_arrivals: int = 32
    #: arrival timestamps kept per tenant for the rate windows (bounds
    #: memory at hundreds-of-tenants scale)
    history: int = 256
    #: pre-inflate lead: the daemon wakes a tenant whose blended
    #: prediction puts its next request within this margin
    preinflate_margin_s: float = 5.0
    #: minimum blend confidence before the daemon acts on a seasonal
    #: prediction (bursts bypass this — they are direct observations)
    preinflate_min_confidence: float = 0.25
    #: revive the deployment's spilled KV prefixes ahead of the burst too
    preinflate_prefixes: bool = True
    #: per-pass cap on daemon wakes (a forecast must not stampede IO)
    max_preinflates_per_pass: int = 8


@dataclass
class TenantModel:
    """Per-tenant forecast state (one per observed key)."""

    #: recent arrival timestamps (bounded; newest right)
    history: Deque[float] = field(default_factory=deque)
    #: per-bin arrival-rate EWMA (arrivals/sec), folded at period rollover
    bin_rate: List[float] = field(default_factory=list)
    #: per-bin EWMA of |observed - predicted| rate (consistency signal)
    bin_dev: List[float] = field(default_factory=list)
    #: completed periods each bin has folded
    bin_periods: List[int] = field(default_factory=list)
    #: arrivals accumulated in the bin's *current* period
    bin_pending: List[int] = field(default_factory=list)
    #: absolute period index each bin last folded/accumulated in
    bin_stamp: List[int] = field(default_factory=list)
    total_arrivals: int = 0


class TrafficForecaster:
    """Per-key seasonal + trend arrival model.

    Keys are opaque strings — per-tenant instance ids in the governor,
    but any stream of timestamped events works.  Time is always injected
    (``now``), so virtual-time benchmarks and tests drive it
    deterministically; the forecaster never reads a clock.

    Thread-safe: the governor observes from request threads while the
    platform daemon reads predictions.
    """

    def __init__(self, cfg: Optional[ForecastConfig] = None):
        self.cfg = cfg or ForecastConfig()
        if self.cfg.n_bins < 1:
            raise ValueError("n_bins must be >= 1")
        self._tenants: Dict[str, TenantModel] = {}
        self._lock = threading.RLock()
        self.observations = 0
        self.bursts_flagged = 0

    # ------------------------------------------------------------- helpers
    def _bin_width(self) -> float:
        return self.cfg.season_period_s / self.cfg.n_bins

    def _bin_of(self, now: float) -> Tuple[int, int]:
        """(absolute period index, bin index) of a timestamp."""
        period = int(now // self.cfg.season_period_s)
        phase = now - period * self.cfg.season_period_s
        b = min(int(phase / self._bin_width()), self.cfg.n_bins - 1)
        return period, b

    def _model(self, key: str) -> TenantModel:
        m = self._tenants.get(key)
        if m is None:
            n = self.cfg.n_bins
            m = TenantModel(
                history=deque(maxlen=self.cfg.history),
                bin_rate=[0.0] * n, bin_dev=[0.0] * n,
                bin_periods=[0] * n, bin_pending=[0] * n,
                bin_stamp=[-1] * n)
            self._tenants[key] = m
        return m

    def _fold(self, m: TenantModel, b: int, period: int) -> None:
        """Fold a bin's pending count into its rate EWMA when a *newer*
        period touches it; the observed rate's deviation from the prior
        EWMA feeds the consistency signal."""
        if m.bin_stamp[b] < 0 or m.bin_stamp[b] >= period:
            return
        a = self.cfg.bin_alpha
        observed = m.bin_pending[b] / self._bin_width()
        err = abs(observed - m.bin_rate[b])
        if m.bin_periods[b] == 0:
            m.bin_rate[b] = observed
            m.bin_dev[b] = 0.0
        else:
            m.bin_rate[b] = a * observed + (1 - a) * m.bin_rate[b]
            m.bin_dev[b] = a * err + (1 - a) * m.bin_dev[b]
        m.bin_periods[b] += 1
        m.bin_pending[b] = 0

    def _rate_of_bin(self, m: TenantModel, b: int, period: int) -> float:
        """Best estimate of a bin's seasonal rate, including a pending
        count from a *completed* earlier period that never folded
        (tenant skipped the bin since)."""
        rate = m.bin_rate[b]
        if 0 <= m.bin_stamp[b] < period and m.bin_pending[b] > 0:
            pend = m.bin_pending[b] / self._bin_width()
            rate = pend if m.bin_periods[b] == 0 else \
                self.cfg.bin_alpha * pend + (1 - self.cfg.bin_alpha) * rate
        return rate

    # ------------------------------------------------------------- inputs
    def observe(self, key: str, now: float) -> None:
        """Record one arrival for ``key`` at (virtual) time ``now``."""
        with self._lock:
            m = self._model(key)
            period, b = self._bin_of(now)
            self._fold(m, b, period)
            if m.bin_stamp[b] != period:
                # entering the bin in a new period starts a fresh count
                m.bin_pending[b] = 0
            m.bin_pending[b] += 1
            m.bin_stamp[b] = period
            m.history.append(now)
            m.total_arrivals += 1
            self.observations += 1

    def forget(self, key: str) -> None:
        """Drop all state for a key (tenant evicted/terminated)."""
        with self._lock:
            self._tenants.pop(key, None)

    # ------------------------------------------------------------- trend
    def _window_count(self, m: TenantModel, now: float,
                      window_s: float) -> int:
        cutoff = now - window_s
        n = 0
        for ts in reversed(m.history):
            if ts < cutoff:
                break
            n += 1
        return n

    def burst_factor(self, key: str, now: float) -> float:
        """Short-window rate over background rate (1.0 = steady state).

        The background floor is one arrival per long window, so a tenant
        arriving from total silence still registers as bursting rather
        than dividing by zero."""
        with self._lock:
            m = self._tenants.get(key)
            if m is None:
                return 1.0
            short = self._window_count(m, now, self.cfg.short_window_s) \
                / max(self.cfg.short_window_s, 1e-9)
            long_ = self._window_count(m, now, self.cfg.long_window_s) \
                / max(self.cfg.long_window_s, 1e-9)
            floor = 1.0 / max(self.cfg.long_window_s, 1e-9)
            return short / max(long_, floor)

    def in_burst(self, key: str, now: float) -> bool:
        """True while a flash crowd is hitting the key *right now*:
        enough arrivals inside the short window, at a rate
        ``burst_ratio`` above the background."""
        with self._lock:
            m = self._tenants.get(key)
            if m is None:
                return False
            if self._window_count(m, now, self.cfg.short_window_s) \
                    < self.cfg.burst_min_arrivals:
                return False
        hot = self.burst_factor(key, now) >= self.cfg.burst_ratio
        if hot:
            self.bursts_flagged += 1
        return hot

    # ------------------------------------------------------------- seasonal
    def confidence(self, key: str, now: float) -> float:
        """Blend weight in [0, 1] for the seasonal prediction at ``now``.

        Three multiplicative terms, each in [0, 1]: sample count
        (``total_arrivals / confidence_arrivals``), period coverage of
        the judged bin (``bin_periods / min_periods``), and bin
        consistency (rate EWMA vs absolute-error EWMA — an anti-seasonal
        tenant whose bins disagree period-to-period scores near zero).
        The judged bin is the *highest-rate* bin on the path from
        ``now`` to the predicted next arrival, not where ``now`` sits:
        the prediction being blended is about that arrival, and a
        diurnal tenant is judged in its learned hot bin even while the
        current phase is (correctly) silent.  (Judging strictly where
        :meth:`seasonal_gap`'s integral completes would be wrong — one
        expected arrival accumulates at the *end* of the hot bin's
        mass, often a phase step past it, so a sharp one-bin spike
        would be judged at the empty bin after the spike.)  Zero
        history or a never-observed path means 0.0 — the pure reactive
        fallback."""
        with self._lock:
            m = self._tenants.get(key)
            if m is None or m.total_arrivals == 0:
                return 0.0
        gap = self.seasonal_gap(key, now)
        with self._lock:
            period, b0 = self._bin_of(now)
            if gap is None:
                b = b0
            else:
                _, b_end = self._bin_of(now + gap)
                span = (b_end - b0) % self.cfg.n_bins
                b = max(((b0 + i) % self.cfg.n_bins
                         for i in range(span + 1)),
                        key=lambda bb: m.bin_rate[bb])
            samples = min(1.0, m.total_arrivals
                          / max(self.cfg.confidence_arrivals, 1))
            periods = min(1.0, m.bin_periods[b]
                          / max(self.cfg.min_periods, 1))
            rate, dev = m.bin_rate[b], m.bin_dev[b]
            consistency = rate / (rate + dev + 1e-12) if rate > 0 else 0.0
        return samples * periods * consistency

    def seasonal_gap(self, key: str, now: float) -> Optional[float]:
        """Expected seconds to the next arrival from the seasonal model:
        integrate the per-bin rate forward from ``now`` until one
        expected arrival accumulates (non-homogeneous Poisson).  Sitting
        in a quiet bin just before a learned hot bin therefore predicts
        "due when the hot bin starts" — the signal pre-inflate needs.
        ``None`` when the model expects less than one arrival over a
        full period (no seasonal signal)."""
        with self._lock:
            m = self._tenants.get(key)
            if m is None or m.total_arrivals == 0:
                return None
            width = self._bin_width()
            period, b0 = self._bin_of(now)
            phase_in_bin = (now % self.cfg.season_period_s) - b0 * width
            expected, t = 0.0, 0.0
            for i in range(self.cfg.n_bins + 1):
                b = (b0 + i) % self.cfg.n_bins
                p = period + (b0 + i) // self.cfg.n_bins
                span = width - phase_in_bin if i == 0 else width
                rate = self._rate_of_bin(m, b, p)
                if rate > 0:
                    need = (1.0 - expected) / rate
                    if need <= span:
                        return t + need
                    expected += rate * span
                t += span
            return None

    def rate(self, key: str, now: float) -> float:
        """Current blended arrival rate (arrivals/sec): the seasonal
        bin's rate weighted by confidence, plus the short-window
        observed rate weighted by the remainder."""
        with self._lock:
            m = self._tenants.get(key)
            if m is None:
                return 0.0
            period, b = self._bin_of(now)
            seasonal = self._rate_of_bin(m, b, period)
            short = self._window_count(m, now, self.cfg.long_window_s) \
                / max(self.cfg.long_window_s, 1e-9)
        w = self.confidence(key, now)
        return w * seasonal + (1 - w) * short

    def expected_arrivals(self, key: str, now: float,
                          horizon_s: float) -> float:
        """Expected arrivals for ``key`` within ``horizon_s`` — the
        cluster-elasticity demand signal (scale-out sums this across
        tenants against cluster headroom)."""
        gap = self.predicted_gap(key, now, None)
        if gap is None or gap <= 0:
            return 0.0
        return horizon_s / gap

    # ------------------------------------------------------------- blend
    def predicted_gap(self, key: str, now: float,
                      fallback_gap: Optional[float]) -> Optional[float]:
        """Expected seconds to the next arrival, blended.

        ``fallback_gap`` is the caller's memoryless estimate (the
        governor's inter-arrival EWMA).  An active flash crowd
        short-circuits to the observed short-window gap; otherwise the
        seasonal prediction mixes with the fallback by
        :meth:`confidence`.  With no seasonal signal the fallback is
        returned unchanged — including ``None``, so callers can tell
        "no prediction at all" from "predicted far away".

        A seasonal prediction confident enough to *act on* (past
        ``preinflate_min_confidence``, the bar the
        :class:`ForecastDaemon` pre-inflates at) also lower-bounds the
        blend: a once-a-period crowd tenant's "due in 2s" must not be
        diluted by its ~period-long memoryless EWMA into a gap that
        tells the governor to immediately descend what the daemon just
        pre-inflated."""
        if self.in_burst(key, now):
            with self._lock:
                m = self._tenants[key]
                short = self._window_count(m, now, self.cfg.short_window_s)
            return max(1e-3, self.cfg.short_window_s / max(short, 1))
        seasonal = self.seasonal_gap(key, now)
        if seasonal is None:
            return fallback_gap
        w = self.confidence(key, now)
        if fallback_gap is None:
            return seasonal if w > 0 else None
        blended = w * seasonal + (1 - w) * fallback_gap
        if w >= self.cfg.preinflate_min_confidence:
            blended = min(blended, seasonal)
        return blended

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        """Counters for dashboards and the benchmarks' tables."""
        with self._lock:
            return {
                "tenants": len(self._tenants),
                "observations": self.observations,
                "bursts_flagged": self.bursts_flagged,
            }


class ForecastDaemon:
    """Pre-inflates tenants (and their deployment's spilled prefixes)
    ahead of predicted bursts.

    Pure policy over existing mechanisms: wakes go through
    ``InstanceManager.predictive_wake`` (the low-priority streamed wake
    pipeline — a real request landing mid-stream absorbs it via
    demand-pull), prefix revival through
    ``PrefixRegistry.revive``.  Drive it from the platform's policy
    daemon (wall clock) or directly with virtual time in benchmarks.
    """

    def __init__(self, manager, arch_of: Optional[Dict[str, str]] = None,
                 cfg: Optional[ForecastConfig] = None):
        self.manager = manager
        self.arch_of = arch_of if arch_of is not None else {}
        fc = getattr(manager.governor, "forecaster", None)
        self.cfg = cfg or (fc.cfg if fc is not None else ForecastConfig())
        self.prewarmed_tenants = 0
        self.prewarmed_prefixes = 0
        self.preforked_zygotes = 0
        self.log: List[tuple] = []
        self._last_preinflate: Dict[str, float] = {}

    def _forecaster(self) -> Optional[TrafficForecaster]:
        return getattr(self.manager.governor, "forecaster", None)

    def step(self, now: float) -> List[str]:
        """One pre-inflate pass at (virtual) time ``now``; returns the
        tenant ids acted on.  No-op when the governor has no forecaster
        (reactive mode)."""
        fc = self._forecaster()
        if fc is None:
            return []
        acted: List[str] = []
        margin = self.cfg.preinflate_margin_s
        with self.manager._lock:
            insts = list(self.manager.instances.values())
        from repro.core.manager import WAKEABLE_STATES
        for inst in insts:
            if len(acted) >= self.cfg.max_preinflates_per_pass:
                break
            if inst.state not in WAKEABLE_STATES:
                continue
            iid = inst.instance_id
            burst = fc.in_burst(iid, now)
            if not burst and \
                    fc.confidence(iid, now) \
                    < self.cfg.preinflate_min_confidence:
                continue
            # deliberately NOT the governor's confidence-weighted blend:
            # a confident "due in 2s" seasonal signal diluted by a ~60s
            # memoryless EWMA would never clear the margin, and the
            # confidence gate above already guards acting on the model
            gap = fc.predicted_gap(iid, now, None) if burst \
                else fc.seasonal_gap(iid, now)
            if gap is None or gap > margin:
                continue
            # one shot per prediction: if this tenant was pre-inflated
            # within the margin and is deflated *again*, the governor
            # reclaimed it under pressure — re-inflating every pass
            # would ping-pong the same bytes (and the arrival, if the
            # prediction was right, will wake it anyway)
            if now - self._last_preinflate.get(iid, -1e18) < margin:
                continue
            # never pre-inflate into pressure: if the wake's footprint
            # would breach the budget, the governor would reclaim it
            # right back (possibly descending this very tenant).
            # Instead, make room ahead of the predicted arrival — run a
            # governor pass against a budget tightened by the incoming
            # footprint, displacing the coldest tenants now, off the
            # request path.  Only if nothing is reclaimable (every other
            # tenant is hotter than this prediction) is the pre-inflate
            # skipped.
            gov = self.manager.governor
            if gov.budget_bytes is not None:
                need = gov.inflate_bytes_estimate(iid)
                if gov.pressure_bytes() + need > 0:
                    gov.step(now=now,
                             budget_bytes=gov.budget_bytes - need)
                    if gov.pressure_bytes() + need > 0:
                        continue
            if self.cfg.preinflate_prefixes:
                self._revive_prefixes(iid)
            if self.manager.predictive_wake(iid) is not None:
                self.prewarmed_tenants += 1
                self._last_preinflate[iid] = now
                self.log.append((now, "forecast_wake", iid,
                                 "burst" if burst else "seasonal"))
                acted.append(iid)
        acted += self._prefork_zygotes(now)
        return acted

    def _prefork_zygotes(self, now: float) -> List[str]:
        """Spawn fork donors ahead of predicted *new-tenant* arrivals.

        The zygote pool predicts per-family new-tenant admission gaps
        (its EWMA blended with the forecaster's synthetic
        ``__newtenant__:family`` streams); families due within the
        pre-fork margin and missing a live donor get one spawned here —
        the same pressure-aware make-room-first discipline as tenant
        pre-inflates, so a pre-fork never lands into a breach the
        governor would immediately reclaim."""
        zp = getattr(self.manager, "zygotes", None)
        if zp is None:
            return []
        acted: List[str] = []
        gov = self.manager.governor
        for family in zp.prefork_candidates(now):
            if gov.budget_bytes is not None and zp.cfg.charge_governor:
                if gov.pressure_bytes() > 0:
                    gov.step(now=now)
                    if gov.pressure_bytes() > 0:
                        continue
            inst = zp.ensure(family)
            if inst is not None:
                self.preforked_zygotes += 1
                self.log.append((now, "zygote_prefork", family,
                                 inst.instance_id))
                acted.append(inst.instance_id)
        return acted

    def _revive_prefixes(self, instance_id: str) -> None:
        """Revive the spilled prefixes of the tenant's deployment by
        digest, so the burst's first sessions COW-adopt resident pages
        instead of paying revive + prefill on the serve path."""
        reg = getattr(self.manager, "prefix_registry", None)
        if reg is None:
            return
        arch = self.arch_of.get(instance_id)
        for digest in reg.spilled_digests(arch):
            if reg.revive(digest):
                self.prewarmed_prefixes += 1
                self.log.append((None, "prefix_prewarm", instance_id,
                                 digest))

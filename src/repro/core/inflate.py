"""Streamed wake pipeline: overlap swap-in, decompression, and compute.

The paper's latency claim for a Woken container is that it answers "with
similar response latency to Warm" because only *part* of the deflated
memory must be inflated before the request runs.  The synchronous wake
path (`HibernationManager.wake`) restores the whole REAP batch before the
engine schedules anything; this module converts that serial region into a
three-stage pipeline:

  stage 1  IO        chunked vectored ``preadv`` over the REAP extent
                     list (written in first-touch order), double-buffered:
                     the read for chunk N+1 is issued while chunk N is
                     still being decoded/installed (``preadv`` releases
                     the GIL, as does zlib for store-tier lookahead).
  stage 2  decode    raw extents are materialized into arrays (zlib
                     inflate for SwapStore-tier lookahead fetches).
  stage 3  install   units land in the instance: weight units via
                     ``_set_unit``, KV pages batched through one pool
                     scatter per chunk (`PagedKVCache.install_batch` /
                     the ``page_copy.scatter_pages`` Pallas kernel).

``wake()`` returns as soon as the **prefill-critical prefix** is resident
— embedding blocks + non-expert ("layer-0"-bearing) weight leaves +
layer-0 KV pages + host cache units — while the tail (MoE experts,
deeper-layer KV pages) streams in the background.  Requests arriving
mid-stream *demand-pull* the exact chunks they fault on
(`InflatePipeline.demand`), and the engine turns serviced faults into
lookahead prefetch of the next layer's units.

Cancellation: deflate (or eviction) during an in-flight stream calls
``cancel(drain=True)`` — the streamer stops claiming new chunks, in-flight
chunks finish installing, and the caller can then restore any still-
missing working-set units from the (unmodified) REAP file.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.swap import read_extents

#: chunk states
_PENDING, _INFLIGHT, _DONE = 0, 1, 2


def is_critical_key(key: Hashable) -> bool:
    """Prefill-critical units: the wake pipeline must deliver these before
    the instance is first-schedulable.

      * weight units: embedding blocks and every non-expert leaf (layers
        are stacked, so each dense leaf carries layer 0); MoE expert
        slices are tail — the router reveals them per request;
      * KV pages: layer 0 only — deeper layers stream behind compute;
      * host cache units (SSM state, conv, cross-K/V): always critical,
        prefill reads them at step 0.
    """
    kind = key[0]
    if kind == "w":
        return key[1] == "embed" or key[2] < 0 or "/moe/" not in key[1]
    if kind == "kv":
        return key[2] == 0
    return True


def critical_wake_keys(inst) -> List[Hashable]:
    """The critical prefix of this instance's REAP file, in file order."""
    return [k for k in inst.reap_file.extents if is_critical_key(k)]


def partial_restore_keys(inst) -> List[Hashable]:
    """Rung-aware wake plan for a PARTIAL-rung instance.

    A partial deflate swaps *cold* units into the page-fault tier while
    the prefill-critical prefix stays resident, so a PARTIAL wake has no
    REAP batch to stream — it restores exactly the swapped-out units.
    Ordered for the background restorer: any critical key first (the
    governor never swaps them, but a wake must not starve prefill if one
    slipped through), then hottest-first (lowest REAP-miss count) so the
    units most likely to be touched next arrive before the truly cold
    tail."""
    def swapped(k):
        # a unit may live in the REAP file instead of the page-fault
        # tier: a cancelled mid-stream wake leaves undelivered working-
        # set units there, and a partial deflate does not rewrite it —
        # those are hot, so the restore must cover them too
        return k in inst.swap_file or k in inst.reap_file.extents

    keys: List[Hashable] = [k for k in inst.nonresident_keys()
                            if swapped(k)]
    if inst.kv is not None:
        keys += [k for k in inst.kv.nonresident_logical_keys()
                 if swapped(k)]
    miss = inst.recorder.miss_count
    return sorted(keys, key=lambda k: (not is_critical_key(k), miss(k)))


class InflatorPool:
    """Per-deployment pool of inflator worker threads.

    A lazy thread pool whose daemon workers exit after ``idle_s`` without
    work, so deployments (and tests) that never wake pay zero threads and
    idle deployments shed them.  Used for the pipeline's read prefetch
    (stage-1 double buffering) and for background lookahead fetches."""

    def __init__(self, max_workers: int = 3, idle_s: float = 2.0,
                 name: str = "inflate"):
        self.max_workers = max(1, max_workers)
        self.idle_s = idle_s
        self.name = name
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._workers = 0
        self._idle = 0
        self._seq = 0

    def submit(self, fn, *args) -> Future:
        fut: Future = Future()
        with self._lock:
            self._q.put((fut, fn, args))
            if self._idle == 0 and self._workers < self.max_workers:
                self._workers += 1
                self._seq += 1
                threading.Thread(target=self._worker, daemon=True,
                                 name=f"{self.name}-{self._seq}").start()
        return fut

    def _worker(self) -> None:
        while True:
            with self._lock:
                self._idle += 1
            try:
                item = self._q.get(timeout=self.idle_s)
            except queue.Empty:
                with self._lock:
                    self._idle -= 1
                    if self._q.empty():
                        self._workers -= 1
                        return
                continue
            with self._lock:
                self._idle -= 1
            fut, fn, args = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(fn(*args))
            except BaseException as e:          # worker must survive anything
                fut.set_exception(e)


class _Chunk:
    __slots__ = ("idx", "keys", "extents", "nbytes", "state")

    def __init__(self, idx: int, keys, extents, nbytes: int):
        self.idx = idx
        self.keys: List[Hashable] = keys
        self.extents: List[Tuple[int, int, str, Tuple]] = extents
        self.nbytes = nbytes
        self.state = _PENDING


class InflatePipeline:
    """One in-flight streamed wake of one instance.

    The handle lives on ``inst.wake_pipeline`` for the duration of the
    stream; the wake-storm guard hands it to late arrivals, the fault path
    demand-pulls from it, and deflate cancels it.
    """

    def __init__(self, inst, pool: Optional[InflatorPool], stats, *,
                 chunk_bytes: int = 256 << 10, priority: str = "high"):
        self.inst = inst
        self.pool = pool
        self.stats = stats                     # WakeStats (duck-typed)
        self.priority = priority
        self.chunk_bytes = max(1, chunk_bytes)
        self._cv = threading.Condition()
        self._critical_evt = threading.Event()
        self._done_evt = threading.Event()
        self._cancelled = False
        #: >0 while a request is actively computing on the instance: the
        #: streamer pauses between chunks (the request's own thread
        #: demand-pulls anything it needs), so background installs never
        #: steal the serve path's cycles.  Cheap-to-miss: the tail simply
        #: finishes a little later.
        self._backpressure = 0
        self.failed: Optional[BaseException] = None
        self._t0 = time.monotonic()

        # Plan chunks over the REAP file in first-touch (= file) order,
        # critical keys and tail keys into SEPARATE chunk streams:
        #   * critical chunks are large (8x) — they gate time-to-first-
        #     schedulable, so per-chunk overhead matters more than
        #     demand-pull granularity (each is still a few vectored runs);
        #   * tail chunks stay fine-grained so a mid-stream fault
        #     demand-pulls little more than what it asked for.
        # Within each class the subsequence keeps ascending file offsets,
        # which is what read_extents needs to merge runs.
        self._chunk_of: Dict[Hashable, _Chunk] = {}
        self.chunks: List[_Chunk] = []
        crit_items, tail_items = [], []
        for key, e in inst.reap_file.extents.items():
            dst = crit_items if is_critical_key(key) else tail_items
            dst.append((key, (e.offset, e.nbytes, e.dtype, e.shape)))
        self._remaining_critical = {k for k, _ in crit_items}
        for items, cbytes in ((crit_items, 8 * self.chunk_bytes),
                              (tail_items, self.chunk_bytes)):
            keys, exts, size = [], [], 0
            for key, ext in items:
                keys.append(key)
                exts.append(ext)
                size += ext[1]
                if size >= cbytes:
                    self._push_chunk(keys, exts, size)
                    keys, exts, size = [], [], 0
            if keys:
                self._push_chunk(keys, exts, size)
        # chunk idx order == critical chunks first, then the tail
        self._order = list(self.chunks)
        self._thread: Optional[threading.Thread] = None

    def _push_chunk(self, keys, exts, size) -> None:
        ch = _Chunk(len(self.chunks), keys, exts, size)
        self.chunks.append(ch)
        for k in keys:
            self._chunk_of[k] = ch

    # ---------------------------------------------------------------- state
    @property
    def active(self) -> bool:
        return not self._done_evt.is_set()

    def covers(self, key: Hashable) -> bool:
        return key in self._chunk_of

    def backpressure(self, delta: int) -> None:
        """Engine hook: +1 while a request computes on this instance,
        -1 when it finishes.  While positive, the streamer parks between
        chunks instead of competing with compute for the interpreter."""
        with self._cv:
            self._backpressure += delta
            self._cv.notify_all()

    def installed(self, key: Hashable) -> bool:
        ch = self._chunk_of.get(key)
        return ch is not None and ch.state == _DONE

    # ---------------------------------------------------------------- start
    def start(self) -> "InflatePipeline":
        if not self.chunks:
            self._finish_critical()
            self._done_evt.set()
            return self
        self._thread = threading.Thread(
            target=self._streamer, daemon=True,
            name=f"wake-stream-{self.inst.instance_id}")
        self._thread.start()
        return self

    # ---------------------------------------------------------------- stages
    def _read(self, chunk: _Chunk):
        """Stage 1: one vectored read of the chunk's extents (ascending
        offsets — the REAP file is laid out in stream order, so a chunk is
        a handful of merged sequential runs)."""
        t0 = time.monotonic()
        bufs, calls = read_extents(self.inst.reap_file.fd,
                                   [(off, n) for off, n, _, _ in chunk.extents])
        dt = time.monotonic() - t0
        with self._cv:
            self.stats.io_seconds += dt
            f = self.inst.reap_file
            f.reads += calls
            f.bytes_read += chunk.nbytes
        return bufs

    def _decode_install(self, chunk: _Chunk, bufs) -> None:
        """Stages 2+3: materialize arrays and install them (weights via
        ``_set_unit``, KV pages batched through one pool scatter)."""
        t0 = time.monotonic()
        data: Dict[Hashable, np.ndarray] = {}
        for key, (_, _, dtype, shape), buf in zip(chunk.keys, chunk.extents,
                                                  bufs):
            data[key] = np.frombuffer(buf, dtype).reshape(shape)
        installed = self.inst.install_units(data)
        with self._cv:
            self.stats.inflate_seconds += time.monotonic() - t0
            self.stats.prefetched_bytes += installed
            chunk.state = _DONE
            self._remaining_critical.difference_update(chunk.keys)
            if not self._remaining_critical:
                self._finish_critical()
            if all(c.state == _DONE for c in self.chunks):
                self._done_evt.set()
            self._cv.notify_all()

    def _process(self, chunk: _Chunk) -> None:
        self._decode_install(chunk, self._read(chunk))

    def _finish_critical(self) -> None:
        if not self._critical_evt.is_set():
            self.stats.critical_path_seconds = time.monotonic() - self._t0
            self._critical_evt.set()

    # ---------------------------------------------------------------- stream
    def _claim_next(self) -> Optional[_Chunk]:
        """With ``_cv`` held: claim the first pending chunk in priority
        order (critical-bearing chunks first)."""
        for ch in self._order:
            if ch.state == _PENDING:
                ch.state = _INFLIGHT
                return ch
        return None

    def _streamer(self) -> None:
        """Background stream: double-buffered when priority is high — the
        read of chunk N+1 runs on an inflator-pool thread while chunk N
        decodes/installs here.  Low priority (anticipatory wakes) streams
        one chunk at a time and yields between chunks."""
        try:
            prefetch = self.priority == "high" and self.pool is not None
            pending = None                     # (chunk, read future) in flight
            while True:
                if pending is None:
                    # holding no claimed chunk: safe to park here — a
                    # parked streamer must never own a chunk a demand
                    # (from the very thread applying backpressure) waits on
                    self._park_if_backpressured()
                    with self._cv:
                        cur = None if self._cancelled else self._claim_next()
                    if cur is None:
                        break
                    bufs = self._read(cur)
                else:
                    cur, fut = pending
                    pending = None
                    bufs = fut.result()
                # double-buffer: issue the NEXT chunk's read on a pool
                # thread before installing this one (skip while
                # backpressured — claimed work must drain, not grow)
                if prefetch and not self._backpressured():
                    with self._cv:
                        nxt = None if self._cancelled else self._claim_next()
                    if nxt is not None:
                        pending = (nxt, self.pool.submit(self._read, nxt))
                self._decode_install(cur, bufs)
                if self.priority != "high":
                    time.sleep(0)              # yield to request threads
        except BaseException as e:             # fd closed mid-evict etc.
            self.failed = e
        finally:
            with self._cv:
                self._finish_critical()
                self._done_evt.set()
                self._cv.notify_all()

    def _backpressured(self) -> bool:
        with self._cv:
            return self._backpressure > 0

    def _park_if_backpressured(self) -> None:
        """Wait out active compute on the instance (bounded so cancel and
        serve-finish are both picked up promptly)."""
        with self._cv:
            while self._backpressure > 0 and not self._cancelled:
                self._cv.wait(0.05)

    # ---------------------------------------------------------------- pull
    def demand(self, keys: Sequence[Hashable], timeout: float = 120.0,
               wait: bool = True) -> int:
        """Demand-pull: make ``keys`` resident *now*.

        Pending chunks holding them are claimed and processed inline on
        the calling thread (out of stream order); chunks already in flight
        on the streamer are waited on.  Returns the bytes of demanded keys
        this call actually saw through to installation (chunks already
        done at entry, or never delivered because the stream was
        cancelled, are not billed — the caller's residual fault path
        accounts for those).

        ``wait=False`` is the opportunistic mode for lookahead running on
        inflator-pool workers: claim-and-process what is pending, but
        NEVER block on an in-flight chunk — a pool worker parked in a
        wait can starve the very read (queued on the same pool) that
        would satisfy it (priority inversion).
        """
        need: Dict[_Chunk, None] = {}
        mine: List[_Chunk] = []
        with self._cv:
            billable = [k for k in keys
                        if (ch := self._chunk_of.get(k)) is not None
                        and ch.state != _DONE]
            for k in billable:
                need.setdefault(self._chunk_of[k])
            for ch in need:
                if ch.state == _PENDING:
                    ch.state = _INFLIGHT
                    mine.append(ch)
        for ch in mine:
            try:
                self._process(ch)
            except BaseException as e:         # fd closed mid-evict etc.
                with self._cv:
                    self.failed = e
                    self._done_evt.set()
                    self._cv.notify_all()
                raise
        deadline = time.monotonic() + timeout
        with self._cv:
            while wait and any(ch.state != _DONE for ch in need):
                if self.failed is not None or self._done_evt.is_set():
                    break
                if not self._cv.wait(max(0.0, min(1.0, deadline - time.monotonic()))):
                    if time.monotonic() >= deadline:
                        raise TimeoutError(
                            f"wake pipeline demand timed out on "
                            f"{self.inst.instance_id}")
            return sum(self.inst.reap_file.extents[k].nbytes
                       for k in billable
                       if self._chunk_of[k].state == _DONE)

    # ---------------------------------------------------------------- waits
    def wait_critical(self, timeout: Optional[float] = None) -> bool:
        """Block until the prefill-critical prefix is resident (time-to-
        first-schedulable)."""
        ok = self._critical_evt.wait(timeout)
        if self.failed is not None:
            raise self.failed
        return ok

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the whole stream has drained (or was cancelled)."""
        return self._done_evt.wait(timeout)

    # ---------------------------------------------------------------- cancel
    def cancel(self, drain: bool = True,
               timeout: Optional[float] = 120.0) -> None:
        """Stop the stream: no new chunks are claimed; with ``drain`` the
        in-flight chunks finish installing before this returns, so the
        instance is never left with a half-installed chunk."""
        with self._cv:
            self._cancelled = True
            # pending chunks will never be claimed now; if nothing is in
            # flight the stream is already as drained as it will get
            if all(c.state != _INFLIGHT for c in self.chunks):
                self._finish_critical()
                self._done_evt.set()
                self._cv.notify_all()
        if drain:
            if self._thread is not None:
                self._thread.join(timeout)
            deadline = time.monotonic() + (timeout or 120.0)
            with self._cv:
                while any(c.state == _INFLIGHT for c in self.chunks):
                    if not self._cv.wait(max(0.0, min(
                            1.0, deadline - time.monotonic()))):
                        if time.monotonic() >= deadline:
                            break
                self._finish_critical()
                self._done_evt.set()
                self._cv.notify_all()

from repro.core.bitmap_alloc import (PAGES_PER_BLOCK, USABLE_PER_BLOCK,
                                     BitmapPageAllocator)
from repro.core.governor import (GovernorAction, GovernorConfig,
                                 MemoryGovernor)
from repro.core.hibernate import DeflateStats, HibernationManager, WakeStats
from repro.core.instance import EMBED_BLOCK, ModelInstance, WeightUnit
from repro.core.manager import (InstanceManager, ManagerConfig,
                                SharedWeightsRegistry)
from repro.core.metrics import (LatencyTrace, MemoryReport, memory_report,
                                per_rung_report)
from repro.core.pool import PagePool
from repro.core.reap import ReapRecorder
from repro.core.state import (DEFLATED_STATES, PAUSED_STATES, RUNG_OF,
                              SERVABLE_STATES, TRANSITIONS, ContainerState,
                              Event, InvalidTransition, Rung, StateMachine)
from repro.core.store import StoreClient, StorePolicy, SwapStore
from repro.core.swap import ReapFile, SwapFile, WriteReceipt

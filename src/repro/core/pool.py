"""Shared device page pool — the "host physical memory" of the TPU analogue.

One pool per device holds fixed-size pages (KV-cache pages for every tenant
on that device), managed by the paper's :class:`BitmapPageAllocator`.  Pages
are refcounted, so prefix-shared KV pages (the COW / process-clone analogue)
are held once and accounted proportionally (PSS semantics, matching the
paper's `pmap` methodology).

On this CPU container the backing store is host RAM (numpy); on a real TPU
deployment it is a single preallocated HBM buffer per device and the
``gather``/``scatter`` paths are the ``page_copy`` Pallas kernel.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Sequence, Set

import numpy as np

from repro.core.bitmap_alloc import (PAGES_PER_BLOCK, USABLE_PER_BLOCK,
                                     BitmapPageAllocator)


class PagePool:
    def __init__(self, page_elems: int, dtype=np.float32,
                 capacity_pages: int = 1 << 16):
        self.page_elems = page_elems
        self.dtype = np.dtype(dtype)
        self.capacity_blocks = max(1, capacity_pages // PAGES_PER_BLOCK)
        self.data = np.zeros((self.capacity_blocks * PAGES_PER_BLOCK,
                              page_elems), self.dtype)
        self._free_slots: List[int] = list(range(self.capacity_blocks))[::-1]
        self._slot_of_block: Dict[int, int] = {}
        self.allocator = BitmapPageAllocator(
            max_blocks=self.capacity_blocks,
            grow=self._on_grow, release=self._on_release)
        self._owner_pages: Dict[str, Set[int]] = {}
        # one pool serves every tenant; concurrent serves allocate/free
        # from worker threads, so allocator mutations are lock-guarded
        self._lock = threading.RLock()
        #: route batched scatters through the page_copy Pallas kernel
        #: (TPU deployments; CPU tests flip it to prove equivalence)
        self.use_kernel_scatter = False
        self.scatter_calls = 0

    # -- block <-> physical slot mapping ------------------------------------
    def _on_grow(self, block_id: int) -> None:
        if not self._free_slots:
            raise MemoryError("page pool: out of physical blocks")
        self._slot_of_block[block_id] = self._free_slots.pop()

    def _on_release(self, block_id: int) -> None:
        # "madvise(MADV_DONTNEED)": the physical block returns to the host
        self._free_slots.append(self._slot_of_block.pop(block_id))

    def _phys(self, pages: Sequence[int]) -> np.ndarray:
        return np.array(
            [self._slot_of_block[p >> 10] * PAGES_PER_BLOCK +
             (p & (PAGES_PER_BLOCK - 1)) for p in pages], np.int64)

    # -- allocation -----------------------------------------------------------
    def alloc(self, n: int, owner: str) -> List[int]:
        with self._lock:
            ids = self.allocator.alloc_many(n)
            self._owner_pages.setdefault(owner, set()).update(ids)
            return ids

    def share(self, pages: Iterable[int], new_owner: str) -> None:
        """COW-share existing pages with another owner (prefix sharing)."""
        pages = list(pages)
        with self._lock:
            for p in pages:
                self.allocator.incref(p)
            self._owner_pages.setdefault(new_owner, set()).update(pages)

    def refcount(self, page: int) -> int:
        """How many owners/sessions currently map this page."""
        with self._lock:
            return self.allocator.refcount(page)

    def break_cow(self, page: int, owner: str) -> int:
        """Copy-on-write break: give ``owner`` a private copy of ``page``.

        Allocates a fresh page, copies the physical contents, and drops
        this owner's reference on the shared original (which stays alive
        for its other sharers).  Returns the new page id.  The write-fault
        analogue of a COW-mapped guest page being touched."""
        with self._lock:
            new = self.alloc(1, owner)[0]
            src, dst = self._phys([page, new])
            self.data[dst] = self.data[src]
            self.free([page], owner)
            return new

    def free(self, pages: Iterable[int], owner: str) -> int:
        """Decref pages for this owner; returns how many were truly freed."""
        freed = 0
        with self._lock:
            own = self._owner_pages.get(owner, set())
            for p in list(pages):
                own.discard(p)
                if self.allocator.decref(p):
                    freed += 1
        return freed

    def free_owner(self, owner: str) -> int:
        with self._lock:
            pages = list(self._owner_pages.get(owner, ()))
            n = self.free(pages, owner)
            self._owner_pages.pop(owner, None)
            return n

    # -- data movement ----------------------------------------------------------
    def write(self, pages: Sequence[int], data: np.ndarray) -> None:
        d = np.asarray(data, self.dtype).reshape(len(pages), self.page_elems)
        self.data[self._phys(pages)] = d

    def read(self, pages: Sequence[int]) -> np.ndarray:
        return self.data[self._phys(pages)].copy()

    def gather(self, pages: Sequence[int]) -> np.ndarray:
        """Zero-copy-ish view for compute (CPU sim of the paged gather)."""
        return self.data[self._phys(pages)]

    def scatter(self, pages: Sequence[int], rows: np.ndarray, *,
                use_kernel: Optional[bool] = None) -> None:
        """Batched page scatter: install a contiguous buffer of restored
        pages in ONE store — the inflate-side half of the ``page_copy``
        kernel's contract (scattered pool pages <-> one sequential
        stream).  The wake pipeline issues one scatter per chunk instead
        of a per-page ``_set`` copy.

        ``use_kernel`` routes the copy through the ``page_copy.
        scatter_pages`` Pallas kernel (the TPU path; CPU runs it in
        interpret mode).  The kernel path rebinds ``self.data`` to the
        kernel's output buffer, so it must only be enabled when no other
        thread holds page views into the pool — the default numpy path is
        an in-place vectorized store and is always safe."""
        rows = np.asarray(rows, self.dtype).reshape(len(pages),
                                                    self.page_elems)
        with self._lock:
            phys = self._phys(pages)
        if use_kernel is None:
            use_kernel = self.use_kernel_scatter
        if use_kernel and self.page_elems % 128 == 0:
            import jax.numpy as jnp
            from repro.kernels.page_copy import ops as pc_ops
            self.data = np.asarray(pc_ops.scatter_pages(
                jnp.asarray(self.data), jnp.asarray(phys, jnp.int32),
                jnp.asarray(rows)))
        else:
            self.data[phys] = rows
        self.scatter_calls += 1

    # -- accounting (PSS analogue) ------------------------------------------------
    @property
    def page_bytes(self) -> int:
        return self.page_elems * self.dtype.itemsize

    def pages_of(self, owner: str) -> Set[int]:
        return set(self._owner_pages.get(owner, ()))

    def rss_bytes(self, owner: str) -> int:
        return len(self._owner_pages.get(owner, ())) * self.page_bytes

    def pss_bytes(self, owner: str) -> float:
        """Proportional set size: shared pages divided by refcount."""
        tot = 0.0
        for p in self._owner_pages.get(owner, ()):
            tot += self.page_bytes / self.allocator.refcount(p)
        return tot

    @property
    def committed_bytes(self) -> int:
        """Bytes of blocks currently committed (not yet madvise'd away)."""
        return self.allocator.committed_blocks * PAGES_PER_BLOCK * \
            self.page_bytes

    @property
    def used_bytes(self) -> int:
        return self.allocator.allocated_pages * self.page_bytes

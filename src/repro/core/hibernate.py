"""HibernationManager — the 4-step deflation of §3.2, the deflation-ladder
rungs, and all inflate paths.

Full deflate (Warm/Woken/MmapClean/Partial -> Hibernate):
  1. *Pause*: SIGSTOP transition; the engine stops scheduling the instance
     (its compiled executables — the "blocked runtime threads" — stay alive).
     An in-flight wake stream is cancelled and drained first, and any
     working-set unit the cancelled stream never delivered is restored from
     the (unmodified) REAP file before it is rewritten — a deflate racing a
     wake can never lose bytes.
  2. *Reclaim freed memory*: trim KV-cache slack pages back to the shared
     pool (the Bitmap allocator returns fully-free blocks to the host).
  3. *Swap out committed memory*: weight units + live KV pages.  Working-set
     units (from the REAP recorder) go to the REAP file with one batched
     sequential write **in first-touch order**; the rest go to the
     page-fault swap file.
  4. *Clean file-backed mmap*: shared base-weight leaves are decref'd in the
     registry (dropped at zero; re-read from the checkpoint on demand).

Wake — three inflate paths:
  * ``mode="reap"``, pipelined (default deployment config) — the streamed
    wake pipeline (:mod:`repro.core.inflate`): the REAP extent list is
    split into chunks and ``preadv`` double-buffers against decode/install
    workers; ``wake()`` returns as soon as the prefill-critical prefix
    (embedding blocks + layer-0 units) is resident while the tail streams
    in the background.  Faults arriving mid-stream demand-pull their
    chunks; serviced faults trigger lookahead prefetch of the next
    layer's units.
  * ``mode="reap"``, synchronous — one batched sequential read restores
    the whole working set before ``wake()`` returns.
  * ``mode="pagefault"`` — nothing restored upfront; each unit is a random
    read on first access.

Ladder rungs (the governor's incremental deflate, between Warm and the
full Hibernate above):

  * :meth:`HibernationManager.deflate_mmap` — step 4 alone: the §3.5
    file-backed mmap cleanup.  Shared base-weight units are decref'd
    (dropped at refcount zero, re-read from the checkpoint on wake);
    anonymous memory stays resident, so wake is a re-map.
  * :meth:`HibernationManager.deflate_partial` — steps 1+3 on a *victim
    subset*: the given cold unit keys (REAP-miss-ranked experts /
    deep-layer KV pages) are written to the page-fault tier and dropped,
    while the prefill-critical prefix stays resident.  Reuses the wake-
    stream drain logic, so a partial deflate racing a streamed wake never
    loses bytes.  Callable repeatedly for proportional reclaim.

Wakes are rung-aware: a PARTIAL wake has no REAP batch to stream — it
re-maps and restores the swapped units in the background
(:func:`repro.core.inflate.partial_restore_keys`); an MMAP_CLEAN wake is
a pure re-map.  ``WakeStats.rung`` records which rung a wake climbed
from, which is how the governor learns measured per-rung wake costs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.inflate import (InflatePipeline, InflatorPool,
                                partial_restore_keys)
from repro.core.instance import ModelInstance
from repro.core.state import ContainerState, Event


@dataclass
class DeflateStats:
    reap_bytes: int = 0
    swap_bytes: int = 0              # logical (raw) bytes sent to swap tier
    kv_pages_swapped: int = 0
    kv_pages_reclaimed: int = 0
    shared_bytes_released: int = 0
    # content-addressed tier breakdown for a SwapStore-backed instance
    # (a verbatim per-sandbox SwapFile reports stored == swap_bytes)
    swap_stored_bytes: int = 0       # new on-disk bytes (post compression)
    swap_dedup_bytes: int = 0        # satisfied by existing shared segments
    swap_elided_bytes: int = 0       # constant-fill units, metadata only
    seconds: float = 0.0
    #: ladder rung this deflate landed on ("mmap_clean"/"partial"/"hibernated")
    rung: str = "hibernated"


@dataclass
class WakeStats:
    mode: str = "reap"
    prefetched_bytes: int = 0
    faulted_bytes: int = 0
    faults: int = 0
    #: wall time ``wake()``/``fault()`` blocked the caller.  For a
    #: pipelined wake this is the *critical path* only — the tail keeps
    #: streaming after the call returns.
    seconds: float = 0.0
    #: time spent in vectored reads (pipelined: summed across concurrent
    #: chunk reads, may exceed wall time)
    io_seconds: float = 0.0
    #: time spent decoding + installing units (zlib inflate for store-tier
    #: payloads, array materialization + pool scatter for REAP chunks)
    inflate_seconds: float = 0.0
    #: time-to-first-schedulable: from wake start until the prefill-
    #: critical prefix was resident (== ``seconds`` for synchronous wakes)
    critical_path_seconds: float = 0.0
    #: stream was pipelined (the tail may still be inflating)
    pipelined: bool = False
    #: ladder rung this wake climbed from ("mmap_clean"/"partial"/
    #: "hibernated") — the governor's measured per-rung cost signal
    rung: str = "hibernated"


class HibernationManager:
    def __init__(self, shared_registry=None, *,
                 inflator: Optional[InflatorPool] = None,
                 wake_chunk_bytes: int = 256 << 10):
        self.shared_registry = shared_registry      # manager's weight registry
        self.inflator = inflator
        self.wake_chunk_bytes = wake_chunk_bytes
        self.log: List[Tuple[str, str, object]] = []
        #: lookahead-prefetch accounting
        self.lookahead_keys = 0

    # ------------------------------------------------------------- deflate
    def quiesce(self, inst: ModelInstance) -> None:
        """Step 0 of every whole-instance transition (full deflate,
        migration): an in-flight wake stream drains first (no new chunks
        are claimed; in-flight chunks finish installing), and background
        lookahead fetches quiesce — the caller must own the instance."""
        pipe = inst.wake_pipeline
        if pipe is not None:
            pipe.cancel(drain=True)
            inst.wake_pipeline = None
        inst.quiesce_bg()

    def deflate(self, inst: ModelInstance, *,
                event: Event = Event.SIGSTOP) -> DeflateStats:
        """Full deflate.  ``event`` is normally SIGSTOP (④); a cluster
        migration of a not-yet-hibernated tenant passes ``MIGRATE`` — the
        same swap-out body runs, but the state lands on MIGRATING so the
        governor cannot touch the tenant while its snapshot ships."""
        t0 = time.monotonic()
        st = DeflateStats()

        self.quiesce(inst)

        # step 1: pause (SIGSTOP / MIGRATE).  Raises if a request is in
        # flight.
        inst.sm.fire(event)

        # a cancelled stream may have left working-set units undelivered;
        # the REAP file is rewritten below from *resident* state, so
        # restore them now or their bytes would be lost
        self._restore_reap_leftovers(inst)

        # step 2: reclaim freed memory — trim KV slack back to the pool
        if inst.kv is not None:
            st.kv_pages_reclaimed = inst.kv.trim()

        # step 3: swap out committed memory (weights + live KV pages)
        ws = inst.recorder.working_set
        w_reap, w_swap = inst.collect_weight_items(ws)
        kv_reap, kv_swap, n_pages = ([], [], 0)
        if inst.kv is not None:
            kv_reap, kv_swap = inst.kv.export_items(ws)
            n_pages = len(kv_reap) + len(kv_swap)
        # unconditional: an empty working set must CLEAR the REAP file,
        # or a later wake would prefetch a previous cycle's stale extents.
        # The batch is laid out in FIRST-TOUCH order (the recorder's
        # insertion order) so the wake pipeline streams units in the order
        # the sample request needed them.
        order = {k: i for i, k in enumerate(inst.recorder.ordered_working_set)}
        items = sorted(w_reap + kv_reap,
                       key=lambda it: order.get(it[0], len(order)))
        inst.reap_file.write_batch(items)
        # content-address the working set too (cluster inventory): the
        # REAP file keeps the wake path private + sequential, while the
        # CAS copy dedups against every same-deployment tenant on the
        # node — digest-overlap placement affinity and dedup-aware
        # migration transfers (repro.cluster) read it.  For shared base
        # weights this is metadata-only after the first tenant.
        if items and getattr(inst.swap_file, "store", None) is not None:
            inst.swap_file.write_units(items)
        # coldness signal for the store's compression tiers: these units
        # missed the working set this cycle.  Only meaningful when a REAP
        # working set exists — with no recorded set (pagefault-mode
        # deployments) nothing can "miss" it, and hot units must not sink
        # to zlib tiers.  Prune counters for keys that no longer exist
        # (trimmed sessions) so session churn cannot grow the dict
        if ws:
            inst.recorder.note_misses(k for k, _ in w_swap + kv_swap)
            live = set(inst.units)
            live.update(k for k, _ in kv_reap + kv_swap)
            inst.recorder.prune_misses(live)
        receipt = inst.swap_file.write_units(w_swap + kv_swap)
        if receipt is not None:
            st.swap_stored_bytes = receipt.stored_bytes
            st.swap_dedup_bytes = receipt.dedup_bytes
            st.swap_elided_bytes = receipt.elided_bytes
        inst.drop_weights()
        if inst.kv is not None:
            inst.kv.drop_pages()
        st.reap_bytes = sum(a.nbytes for _, a in w_reap + kv_reap)
        st.swap_bytes = sum(a.nbytes for _, a in w_swap + kv_swap)
        st.kv_pages_swapped = n_pages

        # step 4: clean up file-backed (shared) memory.  Guarded by the
        # mmap_dropped flag so a ladder path through MMAP_CLEAN/PARTIAL
        # (which already released) stays refcount-balanced.
        st.shared_bytes_released = self._release_mmap(inst)

        inst.inflated = False
        st.seconds = time.monotonic() - t0
        self.log.append(("deflate", inst.instance_id, st))
        return st

    def _has_mmap(self, inst: ModelInstance) -> bool:
        return (self.shared_registry is not None and bool(inst.base_id)
                and bool(inst.shared_paths))

    def _release_mmap(self, inst: ModelInstance) -> int:
        """Mark the mmap rung descended and release the registry ref if
        one is actually held.  The flag is set even for instances with no
        shared mmap: it also tells ``ensure_awake`` that an MMAP_CLEAN
        instance still needs its (no-op) re-map wake."""
        held = self._has_mmap(inst) and not inst.mmap_dropped
        inst.mmap_dropped = True
        return self.shared_registry.release(inst.base_id) if held else 0

    def remap(self, inst: ModelInstance) -> None:
        """Re-acquire the shared base-weight mmap dropped by a ladder
        descent (clean file-backed pages: re-read from the checkpoint at
        refcount 0->1, free otherwise)."""
        if self._has_mmap(inst) and inst.mmap_dropped:
            self.shared_registry.acquire(inst.base_id, inst)
        inst.mmap_dropped = False

    # --------------------------------------------------------- ladder rungs
    def deflate_mmap(self, inst: ModelInstance) -> DeflateStats:
        """Rung 1 (MMAP_CLEAN): the §3.5 file-backed mmap cleanup alone.

        Shared base-weight units are decref'd in the registry; anonymous
        memory stays resident and the instance remains schedulable, so
        the wake cost is a re-map (plus one checkpoint re-read when this
        tenant was the last sharer).  An in-flight wake stream is left
        alone — it only installs anonymous units."""
        t0 = time.monotonic()
        st = DeflateStats(rung="mmap_clean")
        inst.sm.fire(Event.MMAP_DROP)
        st.shared_bytes_released = self._release_mmap(inst)
        if inst.state == ContainerState.PARTIAL:
            # a WOKEN instance lands in PARTIAL (4a'): its next request
            # must run the re-map wake, so clear the wake-storm guard's
            # "already inflated this cycle" flag
            inst.inflated = False
            st.rung = "partial"
        st.seconds = time.monotonic() - t0
        self.log.append(("deflate", inst.instance_id, st))
        return st

    def deflate_partial(self, inst: ModelInstance, keys) -> DeflateStats:
        """Rung 2 (PARTIAL): swap out only the given *cold* unit keys.

        The prefill-critical prefix stays resident, so a later wake is
        near-warm; the victims (REAP-miss-ranked MoE experts, deep-layer
        KV pages — chosen by the governor) go to the page-fault tier and
        demand-fault back on first touch.  Reuses the full-deflate drain
        logic: an in-flight wake stream is cancelled and drained first so
        a stale background install cannot resurrect a dropped unit.
        Callable repeatedly on an already-PARTIAL instance — proportional
        reclaim takes several small bites instead of one full deflate."""
        t0 = time.monotonic()
        st = DeflateStats(rung="partial")

        self.quiesce(inst)

        inst.sm.fire(Event.PARTIAL_STOP)
        # mmap cleanup rides along: PARTIAL is below MMAP_CLEAN on the
        # ladder, and the flag keeps the refcount balanced if it already ran
        st.shared_bytes_released = self._release_mmap(inst)

        keys = list(dict.fromkeys(keys))
        w_items = inst.collect_weight_items_for(
            [k for k in keys if k and k[0] == "w"])
        kv_items = (inst.kv.export_keys(
            [k for k in keys if k and k[0] in ("kv", "kvh")])
            if inst.kv is not None else [])
        items = w_items + kv_items
        # victims are cold by construction: bump their coldness counters
        # so the store's compression tiers can sink them
        inst.recorder.note_misses(k for k, _ in items)
        receipt = inst.swap_file.write_units(items)
        if receipt is not None:
            st.swap_stored_bytes = receipt.stored_bytes
            st.swap_dedup_bytes = receipt.dedup_bytes
            st.swap_elided_bytes = receipt.elided_bytes
        inst.drop_units([k for k, _ in w_items])
        if kv_items and inst.kv is not None:
            st.kv_pages_swapped = inst.kv.drop_keys([k for k, _ in kv_items])
        st.swap_bytes = sum(a.nbytes for _, a in items)

        inst.inflated = False
        st.seconds = time.monotonic() - t0
        self.log.append(("deflate", inst.instance_id, st))
        return st

    def _restore_reap_leftovers(self, inst: ModelInstance) -> None:
        """Fault in working-set units still sitting only in the REAP file
        (a cancelled mid-stream wake, or pagefault-mode access that never
        touched them) before the file is rewritten."""
        if not inst.reap_file.extents:
            return
        wkeys = [k for k in inst.reap_file.extents
                 if k[0] == "w" and k not in inst.resident]
        if wkeys:
            inst.fault_in(wkeys)
        if inst.kv is not None:
            kvkeys = inst.kv.nonresident_keys(
                [k for k in inst.reap_file.extents
                 if k[0] in ("kv", "kvh")])
            if kvkeys:
                with inst.install_lock:
                    inst.kv.fault_in(kvkeys, inst.swap_file, inst.reap_file)

    # ------------------------------------------------------------- wake
    def wake(self, inst: ModelInstance, mode: str = "reap",
             trigger: str = "request", pipelined: bool = False,
             priority: str = "high") -> WakeStats:
        """Inflate.  ``trigger="sigcont"`` is the predictive control-plane
        wake (⑤); ``trigger="request"`` is the request-driven wake (⑦) —
        the state transition to HIBERNATE_RUNNING is fired by the engine.

        With ``pipelined=True`` the REAP restore streams through an
        :class:`InflatePipeline`: this call returns once the prefill-
        critical prefix is resident (``critical_path_seconds``); the tail
        keeps inflating on ``inst.wake_pipeline``.  Anticipatory wakes
        (``priority="low"``) run the same pipeline without read
        double-buffering and yield between chunks.

        The wake is *rung-aware*: MMAP_CLEAN and PARTIAL instances take
        their cheap paths (:meth:`_wake_mmap` / :meth:`_wake_partial`)
        instead of the full REAP restore."""
        if inst.state == ContainerState.MMAP_CLEAN:
            return self._wake_mmap(inst, trigger)
        if inst.state == ContainerState.PARTIAL:
            return self._wake_partial(inst, trigger, pipelined)
        t0 = time.monotonic()
        st = WakeStats(mode=mode)

        # re-acquire shared base weights (file-backed: from checkpoint)
        self.remap(inst)

        if mode == "reap" and inst.reap_file.extents:
            if pipelined:
                st.pipelined = True
                pipe = InflatePipeline(
                    inst, self.inflator, st,
                    chunk_bytes=self.wake_chunk_bytes, priority=priority)
                inst.wake_pipeline = pipe
                pipe.start()
                pipe.wait_critical()
            else:
                # ONE batched sequential read (preadv), -> weights + KV
                t_io = time.monotonic()
                data = inst.reap_file.read_batch()
                st.io_seconds = time.monotonic() - t_io
                t_inf = time.monotonic()
                st.prefetched_bytes += inst.apply_prefetch(data)
                if inst.kv is not None:
                    st.prefetched_bytes += inst.kv.apply_prefetch(data)
                st.inflate_seconds = time.monotonic() - t_inf
        # pagefault mode restores nothing here; units fault in on access

        # shared-prefix slots are never swapped (the registry pins the
        # pages); re-mapping them is a COW share, not IO — do it eagerly
        # so the woken tenant decodes without compute-path remap faults
        st.prefetched_bytes += self._reattach_prefixes(inst)

        inst.inflated = True
        if trigger == "sigcont":
            inst.sm.fire(Event.SIGCONT)
        st.seconds = time.monotonic() - t0
        if not st.pipelined:
            st.critical_path_seconds = st.seconds
        self.log.append(("wake", inst.instance_id, st))
        return st

    def _wake_mmap(self, inst: ModelInstance, trigger: str) -> WakeStats:
        """MMAP_CLEAN wake: pure re-map — anonymous memory never left."""
        t0 = time.monotonic()
        st = WakeStats(mode="remap", rung="mmap_clean")
        self.remap(inst)
        inst.inflated = True
        if trigger == "sigcont":
            inst.sm.fire(Event.SIGCONT)          # -> WARM
        st.seconds = st.critical_path_seconds = time.monotonic() - t0
        self.log.append(("wake", inst.instance_id, st))
        return st

    def _wake_partial(self, inst: ModelInstance, trigger: str,
                      pipelined: bool) -> WakeStats:
        """PARTIAL wake: the critical prefix is already resident, so the
        caller is schedulable immediately — the swapped cold tail restores
        in the background (demand faults cover anything touched sooner).
        Without an inflator pool (or with ``pipelined=False``) the restore
        runs synchronously instead."""
        t0 = time.monotonic()
        st = WakeStats(mode="partial", rung="partial",
                       pipelined=pipelined and self.inflator is not None)
        self.remap(inst)
        inst.inflated = True
        st.prefetched_bytes += self._reattach_prefixes(inst)
        keys = partial_restore_keys(inst)
        if trigger == "sigcont":
            inst.sm.fire(Event.SIGCONT)          # -> WOKEN
        if st.pipelined:
            st.critical_path_seconds = time.monotonic() - t0
            self.prefetch_async(inst, keys)
        elif keys:
            t_io = time.monotonic()
            wkeys = [k for k in keys if k[0] == "w"]
            st.prefetched_bytes += inst.fault_in(wkeys)
            kvkeys = [k for k in keys if k[0] in ("kv", "kvh")]
            if kvkeys and inst.kv is not None:
                with inst.install_lock:
                    st.prefetched_bytes += inst.kv.fault_in(
                        kvkeys, inst.swap_file, inst.reap_file)
            st.io_seconds = time.monotonic() - t_io
        st.seconds = time.monotonic() - t0
        if not st.pipelined:
            st.critical_path_seconds = st.seconds
        self.log.append(("wake", inst.instance_id, st))
        return st

    def _reattach_prefixes(self, inst: ModelInstance) -> int:
        """Re-map a woken tenant's shared-prefix slots from the registry
        (a COW re-share of resident pages; a spilled prefix revives from
        the CAS store by digest first).  Returns bytes made resident."""
        kv = inst.kv
        if kv is None or kv.registry is None:
            return 0
        missing = kv.prefix_missing_keys()
        if not missing:
            return 0
        with inst.install_lock:
            return kv.fault_in(missing, inst.swap_file, inst.reap_file)

    # ------------------------------------------------------------- faults
    def fault(self, inst: ModelInstance, keys) -> WakeStats:
        """Fault path for weight and KV unit keys.

        Keys covered by an in-flight wake stream are *demand-pulled*: their
        chunks are claimed and processed inline (or waited on if the
        streamer already has them) — a fault never re-reads bytes the
        pipeline is about to deliver.  The remainder batches through the
        vectored swap-file read (`read_units`): extent-sorted, adjacent
        extents merged, one ``preadv`` per run."""
        t0 = time.monotonic()
        st = WakeStats(mode="pagefault")
        pipe = inst.wake_pipeline
        if pipe is not None and pipe.active:
            covered = [k for k in keys if pipe.covers(k)]
            if covered:
                st.faulted_bytes += pipe.demand(covered)
        # the residual path re-checks residency, so anything the pipeline
        # just delivered (or a cancelled stream failed to) is handled
        # exactly once
        wkeys = [k for k in keys if k and k[0] == "w"]
        kvkeys = [k for k in keys if k and k[0] in ("kv", "kvh")]
        st.faulted_bytes += inst.fault_in(wkeys)
        if kvkeys and inst.kv is not None:
            kvkeys_nr = inst.kv.nonresident_keys(kvkeys)
            if kvkeys_nr:
                with inst.install_lock:
                    st.faulted_bytes += inst.kv.fault_in(
                        kvkeys_nr, inst.swap_file, inst.reap_file)
        st.faults += len(wkeys) + len(kvkeys)
        st.seconds = time.monotonic() - t0
        return st

    # ------------------------------------------------------------- lookahead
    def prefetch_async(self, inst: ModelInstance, keys) -> int:
        """Lookahead prefetch: asynchronously make ``keys`` resident on an
        inflator-pool thread so the units the next layer (or the session's
        next KV pages) will touch hit residency instead of faulting.

        Best-effort: errors are swallowed, residency is re-checked under
        the install lock, and deflate quiesces outstanding fetches via the
        instance's background-task counter."""
        keys = [k for k in dict.fromkeys(keys)]
        if not keys or self.inflator is None:
            return 0
        inst.bg_begin()
        self.inflator.submit(self._prefetch_task, inst, keys)
        self.lookahead_keys += len(keys)
        return len(keys)

    def _prefetch_task(self, inst: ModelInstance, keys) -> None:
        try:
            if not inst.inflated:
                return                          # deflated since scheduling
            pipe = inst.wake_pipeline
            if pipe is not None and pipe.active:
                # opportunistic (wait=False): a pool worker must never
                # park waiting on an in-flight chunk — the read that
                # would complete it may be queued behind this very task
                # on the same pool (priority inversion).  In-flight
                # chunks are coming anyway; pending ones process inline.
                covered = [k for k in keys if pipe.covers(k)]
                if covered:
                    pipe.demand(covered, timeout=30.0, wait=False)
                    keys = [k for k in keys if k not in set(covered)]
            swap_ks = [k for k in keys if k in inst.swap_file]
            reap_ks = [k for k in keys if k not in inst.swap_file
                       and k in inst.reap_file.extents]
            for f, ks in ((inst.swap_file, swap_ks),
                          (inst.reap_file, reap_ks)):
                if not ks:
                    continue
                # chunked streaming read: bounded memory, and the install
                # lock is only held per-chunk
                for batch in f.read_units_iter(ks, self.wake_chunk_bytes):
                    inst.install_units(batch)
        except Exception:                      # pragma: no cover - best effort
            pass
        finally:
            inst.bg_end()

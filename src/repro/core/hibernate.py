"""HibernationManager — the 4-step deflation of §3.2 and both inflate paths.

Deflate (Warm/Woken -> Hibernate):
  1. *Pause*: SIGSTOP transition; the engine stops scheduling the instance
     (its compiled executables — the "blocked runtime threads" — stay alive).
  2. *Reclaim freed memory*: trim KV-cache slack pages back to the shared
     pool (the Bitmap allocator returns fully-free blocks to the host).
  3. *Swap out committed memory*: weight units + live KV pages.  Working-set
     units (from the REAP recorder) go to the REAP file with one batched
     sequential write; the rest go to the page-fault swap file.
  4. *Clean file-backed mmap*: shared base-weight leaves are decref'd in the
     registry (dropped at zero; re-read from the checkpoint on demand).

Wake:
  * ``mode="reap"``      — one batched sequential read restores the working
                           set; everything else page-faults later.
  * ``mode="pagefault"`` — nothing restored upfront; each unit is a random
                           read on first access.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.instance import ModelInstance
from repro.core.state import ContainerState, Event


@dataclass
class DeflateStats:
    reap_bytes: int = 0
    swap_bytes: int = 0              # logical (raw) bytes sent to swap tier
    kv_pages_swapped: int = 0
    kv_pages_reclaimed: int = 0
    shared_bytes_released: int = 0
    # content-addressed tier breakdown for a SwapStore-backed instance
    # (a verbatim per-sandbox SwapFile reports stored == swap_bytes)
    swap_stored_bytes: int = 0       # new on-disk bytes (post compression)
    swap_dedup_bytes: int = 0        # satisfied by existing shared segments
    swap_elided_bytes: int = 0       # constant-fill units, metadata only
    seconds: float = 0.0


@dataclass
class WakeStats:
    mode: str = "reap"
    prefetched_bytes: int = 0
    faulted_bytes: int = 0
    faults: int = 0
    seconds: float = 0.0


class HibernationManager:
    def __init__(self, shared_registry=None):
        self.shared_registry = shared_registry      # manager's weight registry
        self.log: List[Tuple[str, str, object]] = []

    # ------------------------------------------------------------- deflate
    def deflate(self, inst: ModelInstance) -> DeflateStats:
        t0 = time.monotonic()
        st = DeflateStats()

        # step 1: pause (SIGSTOP).  Raises if a request is in flight.
        inst.sm.fire(Event.SIGSTOP)

        # step 2: reclaim freed memory — trim KV slack back to the pool
        if inst.kv is not None:
            st.kv_pages_reclaimed = inst.kv.trim()

        # step 3: swap out committed memory (weights + live KV pages)
        ws = inst.recorder.working_set
        w_reap, w_swap = inst.collect_weight_items(ws)
        kv_reap, kv_swap, n_pages = ([], [], 0)
        if inst.kv is not None:
            kv_reap, kv_swap = inst.kv.export_items(ws)
            n_pages = len(kv_reap) + len(kv_swap)
        # unconditional: an empty working set must CLEAR the REAP file,
        # or a later wake would prefetch a previous cycle's stale extents
        inst.reap_file.write_batch(w_reap + kv_reap)
        # coldness signal for the store's compression tiers: these units
        # missed the working set this cycle.  Only meaningful when a REAP
        # working set exists — with no recorded set (pagefault-mode
        # deployments) nothing can "miss" it, and hot units must not sink
        # to zlib tiers.  Prune counters for keys that no longer exist
        # (trimmed sessions) so session churn cannot grow the dict
        if ws:
            inst.recorder.note_misses(k for k, _ in w_swap + kv_swap)
            live = set(inst.units)
            live.update(k for k, _ in kv_reap + kv_swap)
            inst.recorder.prune_misses(live)
        receipt = inst.swap_file.write_units(w_swap + kv_swap)
        if receipt is not None:
            st.swap_stored_bytes = receipt.stored_bytes
            st.swap_dedup_bytes = receipt.dedup_bytes
            st.swap_elided_bytes = receipt.elided_bytes
        inst.drop_weights()
        if inst.kv is not None:
            inst.kv.drop_pages()
        st.reap_bytes = sum(a.nbytes for _, a in w_reap + kv_reap)
        st.swap_bytes = sum(a.nbytes for _, a in w_swap + kv_swap)
        st.kv_pages_swapped = n_pages

        # step 4: clean up file-backed (shared) memory
        if self.shared_registry is not None and inst.base_id:
            st.shared_bytes_released = self.shared_registry.release(
                inst.base_id)

        inst.inflated = False
        st.seconds = time.monotonic() - t0
        self.log.append(("deflate", inst.instance_id, st))
        return st

    # ------------------------------------------------------------- wake
    def wake(self, inst: ModelInstance, mode: str = "reap",
             trigger: str = "request") -> WakeStats:
        """Inflate.  ``trigger="sigcont"`` is the predictive control-plane
        wake (⑤); ``trigger="request"`` is the request-driven wake (⑦) —
        the state transition to HIBERNATE_RUNNING is fired by the engine."""
        t0 = time.monotonic()
        st = WakeStats(mode=mode)

        # re-acquire shared base weights (file-backed: from checkpoint)
        if self.shared_registry is not None and inst.base_id:
            self.shared_registry.acquire(inst.base_id, inst)

        if mode == "reap" and inst.reap_file.extents:
            # ONE batched sequential read (preadv), dispatched to weights + KV
            data = inst.reap_file.read_batch()
            st.prefetched_bytes += inst.apply_prefetch(data)
            if inst.kv is not None:
                st.prefetched_bytes += inst.kv.apply_prefetch(data)
        # pagefault mode restores nothing here; units fault in on access

        inst.inflated = True
        if trigger == "sigcont":
            inst.sm.fire(Event.SIGCONT)
        st.seconds = time.monotonic() - t0
        self.log.append(("wake", inst.instance_id, st))
        return st

    # ------------------------------------------------------------- faults
    def fault(self, inst: ModelInstance, keys) -> WakeStats:
        """Fault path for weight and KV unit keys.  The key set is batched
        through the vectored swap-file read (`read_units`): extent-sorted,
        adjacent extents merged, one `preadv` per run — not one random
        `pread` per unit."""
        t0 = time.monotonic()
        st = WakeStats(mode="pagefault")
        wkeys = [k for k in keys if k and k[0] == "w"]
        kvkeys = [k for k in keys if k and k[0] in ("kv", "kvh")]
        st.faulted_bytes += inst.fault_in(wkeys)
        if kvkeys and inst.kv is not None:
            st.faulted_bytes += inst.kv.fault_in(
                kvkeys, inst.swap_file, inst.reap_file)
        st.faults = len(wkeys) + len(kvkeys)
        st.seconds = time.monotonic() - t0
        return st

"""ModelInstance: one tenant's fully-initialized model — the "container".

Holds the weight leaves (host-simulated HBM), the per-instance KV cache (in
the shared page pool), the compiled-function cache (the "host OS objects"
that hibernation keeps alive), swap files and the REAP recorder.

Weight *resource units* are the swappable granularity:
  * ordinary leaves -> one unit each;
  * MoE expert tensors (leading E axis) -> one unit per expert — so REAP can
    prefetch only the experts a workload actually routes to;
  * the embedding table -> row blocks of ``EMBED_BLOCK`` — only rows of
    tokens actually seen are in the working set.

Shared base weights (§3.5 "file-backed mmap") are *not* swapped: they are
refcounted in the manager's registry, dropped at refcount zero and re-read
from the checkpoint on demand.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np
import jax

from repro.core.reap import ReapRecorder
from repro.core.state import (RUNG_OF, ContainerState, Event, Rung,
                              StateMachine)
from repro.core.swap import ReapFile, SwapFile

EMBED_BLOCK = 4096          # embedding rows per swappable unit


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclass
class WeightUnit:
    key: Tuple                       # ("w", path, sub)
    path: str
    sub: int                         # -1 whole leaf; else expert/block index
    nbytes: int


class ModelInstance:
    def __init__(self, instance_id: str, cfg, params, *, pool,
                 spool_dir: str, shared_paths: Optional[Set[str]] = None,
                 base_id: Optional[str] = None, store=None,
                 metadata_bytes: int = 1 << 16,
                 arch_key: Optional[str] = None):
        self.instance_id = instance_id
        self.cfg = cfg
        self.base_id = base_id
        #: deployment model-identity key — the prefix registry partitions
        #: on it (adoption is only sound between identical weights)
        self.arch_key = arch_key
        self.pool = pool
        self.sm = StateMachine()
        self.recorder = ReapRecorder()
        self.compiled: Dict[Hashable, object] = {}     # kept across hibernation
        self.kv = None                                  # PagedKVCache, set by engine
        self.shared_paths: Set[str] = set(shared_paths or ())

        # host-simulated HBM weight leaves, keyed by path
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        self.treedef = jax.tree_util.tree_structure(params)
        self.paths: List[str] = [_path_str(p) for p, _ in flat]
        self.weights: Dict[str, np.ndarray] = {
            _path_str(p): np.array(v) for p, v in flat}   # writable copies

        # embedding rows per swappable unit: small vocabularies still get
        # >=4 blocks so REAP can keep untouched rows swapped out
        vocab_rows = self.weights["embed"].shape[0] \
            if "embed" in self.weights else EMBED_BLOCK
        self.embed_block = min(EMBED_BLOCK, max(64, vocab_rows // 4))

        self.units: Dict[Tuple, WeightUnit] = {}
        self._build_catalog()
        self.resident: Set[Tuple] = set(self.units)   # all resident at start

        # page-fault tier: the deployment's content-addressed SwapStore
        # when dedup is on, else a private per-sandbox SwapFile.  The REAP
        # file stays per-sandbox either way: its whole point is private
        # sequential locality of ONE tenant's working set.
        if store is not None:
            self.swap_file = store.client(instance_id)
            self.swap_file.hotness = self.recorder.miss_count
        else:
            self.swap_file = SwapFile(f"{spool_dir}/{instance_id}.swap")
        self.reap_file = ReapFile(f"{spool_dir}/{instance_id}.reap")
        self.fault_log: List[Tuple[float, Tuple]] = []
        self._metadata_bytes = metadata_bytes
        self.created_at = time.monotonic()
        self.last_used = self.created_at
        #: True once the current hibernation cycle's upfront inflate ran
        #: (cleared by deflate; the manager's wake-storm guard keys off it)
        self.inflated = True
        #: True while the shared base-weight mmap has been cleaned (rung
        #: MMAP_CLEAN or below).  Guards the registry acquire/release pair
        #: so ladder paths that skip rungs stay refcount-balanced.
        self.mmap_dropped = False
        #: in-flight streamed wake (``repro.core.inflate.InflatePipeline``)
        #: — the wake-storm guard hands this handle to late arrivals and
        #: the fault path demand-pulls from it
        self.wake_pipeline = None
        #: in-flight cluster migration (``repro.cluster.migrate.
        #: MigrationHandle``) while MIGRATING — requests and wakes block
        #: on it, mirroring the wake pipeline's shared-handle semantics
        self.migration = None
        #: serializes unit installation across the wake streamer, demand
        #: pulls, lookahead prefetch and the engine's fault path (re-entrant:
        #: the fault path nests install calls)
        self.install_lock = threading.RLock()
        # background prefetch bookkeeping: deflate/terminate quiesce on it
        self._bg_cv = threading.Condition()
        self._bg_tasks = 0

    # ------------------------------------------------------------------ catalog
    def _is_expert_leaf(self, path: str, arr: np.ndarray) -> bool:
        moe = self.cfg.moe
        return (moe is not None and "/moe/" in path and arr.ndim >= 3
                and path.rsplit("/", 1)[-1] in ("w_gate", "w_up", "w_down")
                and arr.shape[-3] == moe.num_experts)

    def _build_catalog(self) -> None:
        for path, arr in self.weights.items():
            if self._is_expert_leaf(path, arr):
                per = arr.nbytes // arr.shape[-3]
                for e in range(arr.shape[-3]):
                    k = ("w", path, e)
                    self.units[k] = WeightUnit(k, path, e, per)
            elif path == "embed" and arr.shape[0] > self.embed_block:
                nblk = -(-arr.shape[0] // self.embed_block)
                per = arr.nbytes // arr.shape[0] * self.embed_block
                for b in range(nblk):
                    k = ("w", path, b)
                    self.units[k] = WeightUnit(k, path, b, per)
            else:
                k = ("w", path, -1)
                self.units[k] = WeightUnit(k, path, -1, arr.nbytes)

    def _get_unit(self, u: WeightUnit) -> np.ndarray:
        arr = self.weights[u.path]
        if u.sub < 0:
            return arr
        if u.path == "embed":
            eb = self.embed_block
            return arr[u.sub * eb:(u.sub + 1) * eb]
        # expert slice: leading-dims-agnostic (layers may be stacked first)
        return arr[..., u.sub, :, :] if arr.ndim > 3 else arr[u.sub]

    def _set_unit(self, u: WeightUnit, val: np.ndarray) -> None:
        arr = self.weights[u.path]
        if u.sub < 0:
            self.weights[u.path] = np.asarray(val).reshape(arr.shape)
        elif u.path == "embed":
            eb = self.embed_block
            arr[u.sub * eb:(u.sub + 1) * eb] = val
        elif arr.ndim > 3:
            arr[..., u.sub, :, :] = val
        else:
            arr[u.sub] = val

    def _zero_unit(self, u: WeightUnit) -> None:
        if u.sub < 0:
            self.weights[u.path] = np.zeros_like(self.weights[u.path])
        else:
            self._set_unit(u, np.zeros_like(self._get_unit(u)))

    # ------------------------------------------------------------------ params
    def params_pytree(self):
        """Rebuild the params pytree for jitted calls."""
        leaves = [self.weights[p] for p in self.paths]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # ------------------------------------------------------------------ swap
    def swappable_units(self) -> List[WeightUnit]:
        """Anonymous (non-shared) weight units (§3.5)."""
        return [u for u in self.units.values()
                if u.path not in self.shared_paths]

    def collect_weight_items(self, working_set: Optional[frozenset] = None):
        """Partition resident anonymous units into (reap, swap) item lists."""
        ws = working_set or frozenset()
        reap_items, swap_items = [], []
        for u in self.swappable_units():
            if u.key not in self.resident:
                continue
            data = np.ascontiguousarray(self._get_unit(u))
            (reap_items if u.key in ws else swap_items).append((u.key, data))
        return reap_items, swap_items

    def collect_weight_items_for(self, keys) -> List[Tuple[Tuple, "np.ndarray"]]:
        """Materialize the given *resident anonymous* weight unit keys as
        (key, data) items — the partial-deflate victim export."""
        items = []
        for key in keys:
            u = self.units.get(key)
            if u is None or u.path in self.shared_paths or \
                    key not in self.resident:
                continue
            items.append((key, np.ascontiguousarray(self._get_unit(u))))
        return items

    def drop_units(self, keys) -> int:
        """Zero + mark non-resident a specific unit set (partial deflate's
        post-swap-out madvise).  Returns bytes dropped."""
        n = 0
        for key in keys:
            u = self.units.get(key)
            if u is None or u.path in self.shared_paths or \
                    key not in self.resident:
                continue
            self._zero_unit(u)
            self.resident.discard(key)
            n += u.nbytes
        return n

    def drop_weights(self) -> int:
        """Zero every swappable resident unit (post swap-out madvise)."""
        n = 0
        for u in self.swappable_units():
            if u.key in self.resident:
                self._zero_unit(u)
                self.resident.discard(u.key)
                n += u.nbytes
        return n

    def swap_out_weights(self, working_set: Optional[frozenset] = None
                         ) -> Dict[str, int]:
        """Write resident anonymous units to disk, then drop them.

        Working-set units go to the REAP file (batch sequential write);
        everything else goes to the page-fault swap file.
        """
        reap_items, swap_items = self.collect_weight_items(working_set)
        if reap_items:
            self.reap_file.write_batch(reap_items)
        if working_set:
            # only a real working set defines "missing" it (coldness)
            self.recorder.note_misses(k for k, _ in swap_items)
        self.swap_file.write_units(swap_items)
        self.drop_weights()
        return {"reap_bytes": sum(a.nbytes for _, a in reap_items),
                "swap_bytes": sum(a.nbytes for _, a in swap_items)}

    def prefetch_reap(self) -> int:
        """Batch-sequential swap-in of the recorded working set."""
        if not self.reap_file.extents:
            return 0
        return self.apply_prefetch(self.reap_file.read_batch())

    def apply_prefetch(self, data: Dict[Hashable, np.ndarray]) -> int:
        """Install weight units from a batch read (KV keys are skipped —
        :meth:`PagedKVCache.apply_prefetch` owns those)."""
        n = 0
        with self.install_lock:
            for key, arr in data.items():
                if key[0] != "w":
                    continue
                self._set_unit(self.units[key], arr)
                self.resident.add(key)
                n += arr.nbytes
        return n

    def install_units(self, data: Dict[Hashable, np.ndarray]) -> int:
        """Install a mixed batch of swapped-in units (the wake pipeline's
        stage 3): weight units via ``_set_unit``; KV pool pages and host
        cache units batched through :meth:`PagedKVCache.install_batch`
        (one pool scatter per call).  Already-resident weight units are
        skipped, so concurrent installers (streamer, demand pulls,
        lookahead) are idempotent.  Returns bytes newly installed."""
        n = 0
        kv_items: List[Tuple[Tuple, np.ndarray]] = []
        with self.install_lock:
            for key, arr in data.items():
                if key[0] == "w":
                    if key in self.resident:
                        continue
                    self._set_unit(self.units[key], arr)
                    self.resident.add(key)
                    n += arr.nbytes
                else:
                    kv_items.append((key, arr))
            if kv_items and self.kv is not None:
                n += self.kv.install_batch(kv_items)
        return n

    def fault_in(self, keys: Sequence[Tuple]) -> int:
        """Fault swap-in: the key set is coalesced into vectored batch
        reads (one per file, adjacent extents merged) instead of one random
        read per unit."""
        with self.install_lock:
            swap_keys, reap_keys = [], []
            for key in keys:
                if key in self.resident:
                    continue
                if key in self.swap_file:
                    swap_keys.append(key)
                elif key in self.reap_file.extents:
                    # unit was in the REAP file but prefetch didn't run
                    # (pagefault-mode wake) — read it from there
                    reap_keys.append(key)
                else:
                    raise KeyError(f"unit {key} neither resident nor swapped")
            n = 0
            for f, ks in ((self.swap_file, swap_keys),
                          (self.reap_file, reap_keys)):
                if not ks:
                    continue
                now = time.monotonic()
                for key, arr in f.read_units(ks).items():
                    u = self.units[key]
                    self._set_unit(u, arr)
                    self.resident.add(key)
                    self.fault_log.append((now, key))
                    n += u.nbytes
        return n

    def ensure_all_resident(self) -> int:
        return self.fault_in([k for k in self.units
                              if k not in self.resident
                              and self.units[k].path not in self.shared_paths])

    def nonresident_keys(self) -> List[Tuple]:
        return [k for k in self.units if k not in self.resident]

    # ------------------------------------------------------------------ memory
    def weight_bytes(self, resident_only: bool = True,
                     include_shared: bool = True) -> int:
        tot = 0
        for k, u in self.units.items():
            if u.path in self.shared_paths:
                continue
            if not resident_only or k in self.resident:
                tot += u.nbytes
        if include_shared:
            tot += self.shared_weight_bytes()
        return tot

    def shared_weight_bytes(self) -> int:
        return sum(self.weights[p].nbytes for p in self.shared_paths
                   if p in self.weights)

    def kv_bytes(self) -> int:
        n = self.pool.rss_bytes(self.instance_id) if self.pool else 0
        if self.kv is not None:
            n += self.kv.host_bytes()
        return n

    def metadata_bytes(self) -> int:
        """The kept-alive 'host OS objects': page tables, compiled-fn
        handles, state machine — small by design.  Simulation knob
        (``ManagerConfig.husk_metadata_bytes``): the cluster benchmarks
        model paper-realistic husk/warm ratios with it."""
        return self._metadata_bytes

    # ---------------------------------------------------------- background
    def bg_begin(self) -> None:
        """Register an in-flight background prefetch task."""
        with self._bg_cv:
            self._bg_tasks += 1

    def bg_end(self) -> None:
        with self._bg_cv:
            self._bg_tasks -= 1
            self._bg_cv.notify_all()

    def quiesce_bg(self, timeout: float = 120.0) -> bool:
        """Block until outstanding background prefetch tasks drain —
        deflate/terminate must not race a lookahead install."""
        deadline = time.monotonic() + timeout
        with self._bg_cv:
            while self._bg_tasks:
                if not self._bg_cv.wait(max(0.0, min(
                        1.0, deadline - time.monotonic()))):
                    if time.monotonic() >= deadline:
                        return False
            return True

    def terminate(self) -> None:
        if self.wake_pipeline is not None:
            self.wake_pipeline.cancel(drain=True)
            self.wake_pipeline = None
        self.quiesce_bg()
        self.swap_file.delete()
        self.reap_file.delete()
        if self.pool is not None:
            self.pool.free_owner(self.instance_id)

    @property
    def state(self) -> ContainerState:
        return self.sm.state

    @property
    def rung(self) -> Rung:
        """Position on the deflation ladder (running states keep the rung
        they will FINISH back into)."""
        return RUNG_OF[self.sm.state]

"""MemoryGovernor: node-wide budget enforcement over the deflation ladder.

The paper's economics are a *spectrum* between Warm and Hibernate; the
governor is the policy brain that spends the repo's mechanisms (vectored
swap IO, the content-addressed store, the streamed wake pipeline) against
a fixed node memory budget:

  * it watches deployment-wide resident bytes against
    ``ManagerConfig.memory_budget_bytes``;
  * under pressure it deflates victims *incrementally* down the rung
    ladder WARM -> MMAP_CLEAN -> PARTIAL -> HIBERNATED -> TERMINATED,
    freeing only the bytes needed to clear pressure (proportional
    reclaim), not whole instances;
  * victim selection is cost/benefit: the bytes a rung descent frees,
    weighted by how soon the tenant's next request is expected (per-
    tenant EWMA of inter-arrival times, fed by the AsyncPlatform) and by
    the *measured* wake cost of climbing back out of that rung
    (``WakeStats.critical_path_seconds`` EWMA per rung).

The PARTIAL rung swaps only cold units — REAP-miss-ranked MoE experts and
deep-layer KV pages (``inflate.is_critical_key`` == False) — so the
prefill-critical prefix stays resident and wake TTFT stays near-warm.
TERMINATED is last-resort: a hibernated tenant idle past
``terminate_idle_s`` is evicted, releasing its swap-store segment refs
(one tenant's termination never touches bytes another still references).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.forecast import ForecastConfig, TrafficForecaster
from repro.core.inflate import is_critical_key
from repro.core.state import ContainerState, Rung

S = ContainerState

#: states the governor may act on (idle, servable); running states are
#: skipped via the engine's per-instance try-lock anyway.  MIGRATING is
#: deliberately absent: an in-transfer tenant is fenced — the state
#: machine rejects every deflate/evict event on it.
_IDLE_STATES = frozenset({S.WARM, S.WOKEN, S.MMAP_CLEAN, S.PARTIAL,
                          S.HIBERNATE, S.ZYGOTE})

#: states a cluster migration may ship from: the tenant's anon state is
#: (or can cheaply be flushed) on the CAS/REAP disk tier
MIGRATABLE_STATES = frozenset({S.MMAP_CLEAN, S.PARTIAL, S.HIBERNATE})

#: states a scored descent is still applicable from — revalidated under
#: the victim's serve lock, because the instance may have served (or been
#: deflated by keep-alive) between scoring and apply
_APPLICABLE_FROM = {
    Rung.MMAP_CLEAN: frozenset({S.WARM}),
    Rung.PARTIAL: frozenset({S.WARM, S.WOKEN, S.MMAP_CLEAN, S.PARTIAL}),
    Rung.HIBERNATED: frozenset({S.WARM, S.WOKEN, S.MMAP_CLEAN, S.PARTIAL}),
    Rung.TERMINATED: frozenset({S.HIBERNATE, S.ZYGOTE}),
}


@dataclass
class GovernorConfig:
    """Rung-ladder policy knobs."""
    #: after a breach, reclaim down to ``budget * (1 - headroom)`` so the
    #: governor does not thrash at the budget edge
    headroom: float = 0.05
    #: EWMA smoothing for per-tenant inter-arrival gaps
    ewma_alpha: float = 0.3
    #: EWMA smoothing for measured per-rung wake costs
    cost_alpha: float = 0.3
    #: hibernated tenants idle longer than this become TERMINATED victims
    #: (None disables the terminate rung entirely)
    terminate_idle_s: Optional[float] = 3600.0
    #: smallest partial bite worth a swap pass — below this a partial
    #: deflate's syscall overhead beats its benefit
    min_partial_bytes: int = 64 << 10
    #: wake-cost priors (seconds to climb back out of each rung) used
    #: until real wakes are measured; TERMINATED's prior is a cold start
    cost_priors: Tuple[Tuple[Rung, float], ...] = (
        (Rung.WARM, 0.0),
        (Rung.MMAP_CLEAN, 0.0005),
        (Rung.PARTIAL, 0.002),
        (Rung.HIBERNATED, 0.05),
        (Rung.TERMINATED, 2.0),
    )
    #: safety valve: max ladder actions per ``step`` call
    max_actions_per_step: int = 64
    #: traffic-forecast model (seasonal bins + flash-crowd detection)
    #: blended into ``predicted_gap``; None keeps the governor purely
    #: reactive (the memoryless EWMA — the pre-PR-9 behaviour and the
    #: benchmark baseline)
    forecast: Optional[ForecastConfig] = None


@dataclass
class GovernorAction:
    """One applied ladder descent."""
    instance_id: str
    rung_from: Rung
    rung_to: Rung
    freed_bytes: int
    score: float
    seconds: float = 0.0


class MemoryGovernor:
    """One per :class:`~repro.core.manager.InstanceManager`."""

    def __init__(self, manager, budget_bytes: Optional[int] = None,
                 cfg: Optional[GovernorConfig] = None):
        self.manager = manager
        self.budget_bytes = budget_bytes
        self.cfg = cfg or GovernorConfig()
        #: per-tenant arrival model: iid -> (last_arrival_ts, ewma_gap_s)
        self.arrivals: Dict[str, Tuple[float, Optional[float]]] = {}
        #: measured wake cost per rung name ("mmap_clean"/"partial"/...)
        self.wake_cost_ewma: Dict[str, float] = {}
        #: seasonal/trend forecaster blended into ``predicted_gap``
        #: (None = reactive-only)
        self.forecaster: Optional[TrafficForecaster] = \
            TrafficForecaster(self.cfg.forecast) \
            if self.cfg.forecast is not None else None
        #: per-tenant wake footprint: bytes the last deflation freed —
        #: what a pre-inflate (or the elasticity demand model) expects
        #: the tenant to re-occupy on wake
        self.footprint: Dict[str, int] = {}
        self.actions: List[GovernorAction] = []
        self.steps = 0

    # ------------------------------------------------------------- signals
    def observe_arrival(self, instance_id: str, now: Optional[float] = None
                        ) -> None:
        """Fed by the AsyncPlatform on every request submission."""
        now = time.monotonic() if now is None else now
        last, gap = self.arrivals.get(instance_id, (None, None))
        if last is not None:
            a = self.cfg.ewma_alpha
            gap = (now - last) if gap is None else \
                a * (now - last) + (1 - a) * gap
        self.arrivals[instance_id] = (now, gap)
        if self.forecaster is not None:
            self.forecaster.observe(instance_id, now)

    def observe_wake(self, instance_id: str, stats) -> None:
        """Fed by ``InstanceManager.ensure_awake`` after every wake."""
        a = self.cfg.cost_alpha
        prev = self.wake_cost_ewma.get(stats.rung)
        cost = stats.critical_path_seconds
        self.wake_cost_ewma[stats.rung] = cost if prev is None else \
            a * cost + (1 - a) * prev
        # the wake restored the deflated bytes: the tenant's pre-inflate
        # footprint estimate resets until the next descent re-measures it
        self.footprint.pop(instance_id, None)

    def forget(self, instance_id: str) -> None:
        """Drop all per-tenant model state (tenant evicted/migrated)."""
        self.arrivals.pop(instance_id, None)
        self.footprint.pop(instance_id, None)
        if self.forecaster is not None:
            self.forecaster.forget(instance_id)

    # ------------------------------------------------------------- models
    def predicted_gap(self, instance_id: str, now: float, *,
                      last_used: float = 0.0) -> float:
        """Expected seconds until the tenant's next request.

        With an EWMA gap: the memoryless residual is the gap itself —
        Poisson arrivals have no deadline, so an overdue tenant is *not*
        imminent and a recently-served one gets no extra protection.
        With a single observed arrival: the silence since it.  With
        none: idle time — the LRU fallback.

        With a forecaster configured, the memoryless estimate is blended
        with the seasonal/flash-crowd prediction by the forecaster's
        confidence — a sparse or anti-seasonal tenant gets exactly the
        reactive estimate above, a learned-diurnal tenant is protected
        ahead of its active window and released during its quiet one."""
        last, gap = self.arrivals.get(instance_id, (None, None))
        if last is None:
            reactive: float = max(1e-3, now - last_used)
        elif gap is None:
            reactive = max(1e-3, now - last)
        else:
            reactive = max(1e-3, gap)
        if self.forecaster is not None:
            blended = self.forecaster.predicted_gap(instance_id, now,
                                                    reactive)
            if blended is not None:
                return max(1e-3, blended)
        return reactive

    def inflate_bytes_estimate(self, instance_id: str) -> int:
        """Bytes a wake of this (deflated) tenant is expected to bring
        back resident: the sum its ladder descents freed since the last
        wake.  Zero for a tenant that never deflated — the cluster
        elasticity demand model sums this across imminent tenants."""
        return self.footprint.get(instance_id, 0)

    def wake_cost(self, rung: Rung) -> float:
        """Measured (EWMA) seconds to climb back out of a rung, falling
        back to the configured prior."""
        name = {Rung.MMAP_CLEAN: "mmap_clean", Rung.PARTIAL: "partial",
                Rung.HIBERNATED: "hibernated"}.get(rung)
        if name is not None and name in self.wake_cost_ewma:
            return self.wake_cost_ewma[name]
        return dict(self.cfg.cost_priors).get(rung, 1.0)

    # ------------------------------------------------------------- benefit
    def _mmap_benefit(self, inst) -> int:
        """Bytes a file-backed mmap cleanup frees *node-wide*: the shared
        base weights only drop when this tenant is the last sharer."""
        hib = self.manager.hib
        if not hib._has_mmap(inst) or inst.mmap_dropped:
            return 0
        if self.manager.shared.refcount(inst.base_id) != 1:
            return 0
        return inst.shared_weight_bytes()

    def _partial_candidates(self, inst) -> List[Tuple[int, int, Tuple]]:
        """Cold resident units a partial deflate may swap, coldest first:
        (miss_count, nbytes, key), non-critical only — the prefill-
        critical prefix is never a victim."""
        miss = inst.recorder.miss_count
        cands: List[Tuple[int, int, Tuple]] = []
        for u in inst.swappable_units():
            if u.key in inst.resident and not is_critical_key(u.key):
                cands.append((miss(u.key), u.nbytes, u.key))
        if inst.kv is not None:
            for k in inst.kv.resident_keys():
                if k[0] == "kv" and not is_critical_key(k):
                    cands.append((miss(k), inst.kv.key_nbytes(k), k))
        # coldest first (most working-set misses), big units break ties
        cands.sort(key=lambda t: (-t[0], -t[1]))
        return cands

    def _anon_resident_bytes(self, inst) -> int:
        # PSS, not RSS: pages COW-shared with the prefix registry (or a
        # forked sibling session) are charged proportionally — deflating
        # one sharer neither frees nor double-counts bytes another tenant
        # still maps
        return (inst.weight_bytes(resident_only=True, include_shared=False)
                + (int(inst.pool.pss_bytes(inst.instance_id))
                   if inst.pool else 0))

    # ------------------------------------------------------------- step
    def governed_bytes(self) -> int:
        """What the budget is charged for: resident application memory
        plus every live instance's kept-alive metadata (page tables,
        compiled handles) — hibernation shrinks a tenant to its metadata,
        only TERMINATED frees that too (the density ceiling the paper's
        'deflated but alive' containers eventually hit)."""
        with self.manager._lock:
            meta = sum(i.metadata_bytes()
                       for i in self.manager.instances.values())
        total = self.manager.resident_bytes() + meta
        zp = getattr(self.manager, "zygotes", None)
        if zp is not None and not zp.cfg.charge_governor:
            # operator chose to run the pool off-budget: exempt zygote
            # anon + metadata bytes (shared base weights stay charged —
            # live tenants share those buffers)
            total -= zp.uncharged_bytes()
        return total

    def pressure_bytes(self, budget_bytes: Optional[int] = None) -> int:
        """Bytes over budget right now (<= 0 means no pressure)."""
        budget = self.budget_bytes if budget_bytes is None else budget_bytes
        if budget is None:
            return 0
        return self.governed_bytes() - budget

    def step(self, now: Optional[float] = None,
             try_lock: Optional[Callable] = None,
             budget_bytes: Optional[int] = None) -> List[GovernorAction]:
        """One governor pass: on a breach, run scoring *rounds* until
        pressure clears.  Each round scores every (instance, rung)
        descent once and applies them best-first — at most one action per
        instance per round (a tenant needing several rungs descends
        across rounds).  Rounds repeat only while the previous one made
        progress, so a pass is O(rounds x instances x units) with small
        round counts (one per ladder depth), not O(actions x instances x
        units).  Returns the actions applied."""
        budget = self.budget_bytes if budget_bytes is None else budget_bytes
        if budget is None:
            return []
        now = time.monotonic() if now is None else now
        self.steps += 1
        applied: List[GovernorAction] = []
        if self.governed_bytes() <= budget:
            return applied
        # breached: reclaim down past the headroom so the next few
        # allocations do not immediately re-breach
        target = int(budget * (1.0 - self.cfg.headroom))
        need = self.governed_bytes() - target
        # rung 0, cheapest reclaim on the node: resident prefix-registry
        # entries no live session currently maps are pure cache — spill
        # them to the CAS tier first (revive is one vectored read; no
        # tenant is touched, no wake cost is incurred)
        reg = getattr(self.manager, "prefix_registry", None)
        if reg is not None and need > 0:
            for _, digest in sorted(reg.spill_candidates(), reverse=True):
                if need <= 0:
                    break
                need -= reg.spill(digest)
            need = self.governed_bytes() - target
        while need > 0 and len(applied) < self.cfg.max_actions_per_step:
            progress = False
            with self.manager._lock:
                insts = list(self.manager.instances.values())
            scored = []
            for inst in insts:
                if inst.state not in _IDLE_STATES:
                    continue
                if inst.state is S.ZYGOTE:
                    # a zygote's bytes are priced against their *fork-
                    # avoidance* value: the predicted gap until the
                    # family's next new-tenant admission plays the role
                    # the tenant's next-request gap plays below — a
                    # family forking often keeps its donor, a stale one
                    # gives its bytes up first
                    gap = self._zygote_gap(inst, now)
                else:
                    gap = self.predicted_gap(inst.instance_id, now,
                                             last_used=inst.last_used)
                for rung_to, benefit in self._candidates(inst, now, need):
                    if benefit <= 0:
                        continue
                    score = benefit * gap / (self.wake_cost(rung_to) + 1e-6)
                    scored.append((score, inst, rung_to))
            # best first; a victim busy serving (try-lock miss) falls
            # through to the next-best candidate instead of stalling
            scored.sort(key=lambda t: -t[0])
            acted = set()
            for score, inst, rung_to in scored:
                if len(applied) >= self.cfg.max_actions_per_step \
                        or need <= 0:
                    break
                if inst.instance_id in acted:
                    continue
                act = self._apply(inst, rung_to, need, now, score, try_lock)
                acted.add(inst.instance_id)
                if act is not None:
                    applied.append(act)
                    progress = True
                    # within a round, track need by the action's own
                    # freed estimate — the fleet-wide re-measure runs
                    # once per round, not once per action
                    need -= max(act.freed_bytes, 1)
            if not progress:
                break
            need = self.governed_bytes() - target
        self.actions += applied
        return applied

    def _candidates(self, inst, now: float, need: int
                    ) -> List[Tuple[Rung, int]]:
        """(target rung, benefit bytes) descents available to ``inst``.

        Benefits are capped at ``need``: bytes beyond the remaining
        pressure have no value, so equally-sufficient rungs compete on
        wake cost alone — the governor takes the *cheapest* rung that
        clears the breach (proportional reclaim), not the biggest."""
        out: List[Tuple[Rung, int]] = []
        state = inst.state
        if state in (S.WARM, S.WOKEN, S.MMAP_CLEAN):
            # compute the expensive per-instance quantities once: the
            # unit scan (_partial_candidates) and registry lookup feed
            # every rung's benefit below
            mmap_b = self._mmap_benefit(inst)
            cold_bytes = sum(nb for _, nb, _ in
                             self._partial_candidates(inst))
            if state == S.WARM:
                # only WARM lands on MMAP_CLEAN; a WOKEN instance's
                # MMAP_DROP transitions to PARTIAL (its tail is already
                # swapped), so for WOKEN the mmap benefit is priced into
                # the PARTIAL candidate below instead
                out.append((Rung.MMAP_CLEAN, min(mmap_b, need)))
            if cold_bytes + mmap_b > 0:
                out.append((Rung.PARTIAL,
                            min(cold_bytes + mmap_b, need)))
            out.append((Rung.HIBERNATED,
                        min(self._anon_resident_bytes(inst) + mmap_b,
                            need)))
        elif state == S.PARTIAL:
            cold_bytes = sum(nb for _, nb, _ in
                             self._partial_candidates(inst))
            if cold_bytes > 0:
                out.append((Rung.PARTIAL, min(cold_bytes, need)))
            out.append((Rung.HIBERNATED,
                        min(self._anon_resident_bytes(inst), need)))
        elif state == S.HIBERNATE:
            tidle = self.cfg.terminate_idle_s
            if tidle is not None and (now - inst.last_used) > tidle:
                # last resort: frees the kept-alive metadata and releases
                # the tenant's swap-store segment refs (disk GC)
                out.append((Rung.TERMINATED,
                            min(inst.metadata_bytes(), need)))
        elif state == S.ZYGOTE:
            # a zygote has exactly one descent: retire (it holds no
            # tenant state to deflate — its value IS being inflated).
            # No idle gate: fork-avoidance economics, not idleness,
            # decide, via the gap term in ``step``'s scoring.
            out.append((Rung.TERMINATED,
                        min(self._anon_resident_bytes(inst)
                            + self._mmap_benefit(inst)
                            + inst.metadata_bytes(), need)))
        return out

    def _zygote_gap(self, inst, now: float) -> float:
        zp = getattr(self.manager, "zygotes", None)
        if zp is None or inst.arch_key is None:
            return 1.0
        return zp.predicted_fork_gap(inst.arch_key, now)

    # ------------------------------------------------------- cluster tier
    def migration_candidates(self, now: Optional[float] = None
                             ) -> List[Tuple[object, int, float]]:
        """Tenants a cluster router may ship off-node, as
        ``(instance, freed_bytes, predicted_idle_s)`` — most-idle first.

        Migration sits between the ladder's HIBERNATED and TERMINATED
        rungs: it frees everything TERMINATED would (resident anon bytes,
        kept-alive metadata, last-sharer mmap) *without* destroying the
        tenant — the husk moves to a node with headroom instead.  Only
        :data:`MIGRATABLE_STATES` qualify; a WARM/serving tenant is never
        shipped (its state machine would reject ``MIGRATE`` anyway)."""
        now = time.monotonic() if now is None else now
        with self.manager._lock:
            insts = list(self.manager.instances.values())
        out: List[Tuple[object, int, float]] = []
        for inst in insts:
            if inst.state not in MIGRATABLE_STATES:
                continue
            freed = (self._anon_resident_bytes(inst)
                     + self._mmap_benefit(inst) + inst.metadata_bytes())
            idle = self.predicted_gap(inst.instance_id, now,
                                      last_used=inst.last_used)
            out.append((inst, freed, idle))
        out.sort(key=lambda t: -t[2])
        return out

    def migration_score(self, freed_bytes: int, predicted_idle_s: float,
                        transfer_bytes_missing: int,
                        link_bw_bytes_s: float,
                        wake_cost_s: Optional[float] = None) -> float:
        """Cluster-escalation score for one (victim, target) pair:

            bytes_freed * predicted_idle
            / (transfer_bytes_missing / link_bw + wake_cost)

        ``transfer_bytes_missing`` is the dedup-aware transfer — only the
        digests the target's CAS store lacks — so shipping a tenant to a
        node that already holds its base weights is nearly free and wins.
        ``wake_cost`` defaults to the measured HIBERNATED-rung wake EWMA:
        the migrant lands hibernated on the target."""
        if wake_cost_s is None:
            wake_cost_s = self.wake_cost(Rung.HIBERNATED)
        denom = (transfer_bytes_missing / max(link_bw_bytes_s, 1.0)
                 + wake_cost_s + 1e-6)
        return freed_bytes * predicted_idle_s / denom

    def _apply(self, inst, rung_to: Rung, need: int, now: float,
               score: float,
               try_lock: Optional[Callable]) -> Optional[GovernorAction]:
        iid = inst.instance_id
        lock = try_lock(iid) if try_lock else None
        if lock is not None and not lock.acquire(blocking=False):
            return None                  # busy serving: not idle after all
        t0 = time.monotonic()
        try:
            # revalidate under the lock: the instance may have served or
            # been deflated between scoring and apply — a stale descent
            # must neither evict a live tenant nor fire an illegal event
            if inst.state not in _APPLICABLE_FROM[rung_to]:
                return None
            if rung_to == Rung.TERMINATED and inst.state is not S.ZYGOTE \
                    and (self.cfg.terminate_idle_s is None
                         or (now - inst.last_used)
                         <= self.cfg.terminate_idle_s):
                # the idle gate protects *tenants* (losing one costs a
                # cold start); a zygote retire loses nothing a re-spawn
                # cannot rebuild, so it is gated by scoring alone
                return None
            before = self._anon_resident_bytes(inst) \
                + self._mmap_benefit(inst)
            rung_from = inst.rung
            # the ladder speaks the manager's rung-addressed descend API
            if rung_to == Rung.MMAP_CLEAN:
                st = self.manager.descend(iid, rung_to)
                freed = st.shared_bytes_released
            elif rung_to == Rung.PARTIAL:
                # a bite never goes below min_partial_bytes: for a tiny
                # breach the per-pass overhead would beat the benefit
                bite = max(need, self.cfg.min_partial_bytes)
                victims, tot = [], 0
                for _, nb, key in self._partial_candidates(inst):
                    if tot >= bite:
                        break
                    victims.append(key)
                    tot += nb
                st = self.manager.descend(iid, rung_to, keys=victims)
                freed = st.swap_bytes + st.shared_bytes_released
            elif rung_to == Rung.HIBERNATED:
                st = self.manager.descend(iid, rung_to)
                freed = before
            else:                        # TERMINATED
                freed = inst.metadata_bytes()
                if inst.state is S.ZYGOTE:
                    freed += before      # a retire frees resident bytes
                # descend(TERMINATED) evicts (also forgets our arrivals)
                self.manager.descend(iid, rung_to)
            act = GovernorAction(iid, rung_from, rung_to, freed, score,
                                 time.monotonic() - t0)
            return act
        finally:
            if lock is not None:
                lock.release()

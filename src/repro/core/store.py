"""Content-addressed swap store: the Swapping Manager's de-dup table (§3.4),
extended across sandboxes.

The per-sandbox :class:`~repro.core.swap.SwapFile` stores every deflated
unit verbatim, so disk (and page-cache) footprint scales linearly with
tenant count even when tenants run the same model.  The paper's Swapping
Manager keeps a de-dup table so identical swapped-out units are stored
once; REAP-style snapshot work shows most restored pages are identical
across snapshots of one function, and the same holds across tenants that
share a base model.  The :class:`SwapStore` realises that disk tier:

  * units are hashed on deflate (salted BLAKE2b — the salt is generated
    per deployment, so content hashes never leak across deployments and a
    tenant cannot probe another deployment's store by hash);
  * zero/constant payloads are elided to metadata (no disk bytes at all —
    KV pages' unused tails and zero-init params cost nothing);
  * duplicate payloads across sessions *and tenants* are stored once in a
    refcounted segment file; terminating an instance decrefs its segments
    and frees the extents of any that hit refcount zero (GC), so one
    tenant's eviction never touches bytes another tenant still references;
  * cold payloads are transparently compressed: a unit that keeps missing
    the REAP working set keeps coming back through the page-fault tier,
    and its miss count selects a zlib level (:class:`StorePolicy`) —
    payloads only ever *sink* to higher compression, never decompress back
    up a tier.

The inflate path keeps the vectored ``preadv`` batching of the plain swap
files: requested units are dedup'd by digest, segment extents are sorted
and adjacent extents merged into runs (``repro.core.swap.read_extents``),
so a wake storm's fault set is still a handful of sequential disk passes.

Tenants that opt out of dedup (``ManagerConfig.dedup_store=False``) keep
the PR-1 private per-sandbox ``SwapFile`` — the store is interface-
compatible (:class:`StoreClient` duck-types ``SwapFile``), so every layer
above (``HibernationManager``, ``ModelInstance``, ``PagedKVCache``) is
agnostic to which tier backs it.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
import zlib
from dataclasses import dataclass
from typing import (Callable, Dict, Hashable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.swap import WriteReceipt, read_extents


@dataclass
class StorePolicy:
    """Adaptive compression tiers.

    ``tiers`` maps a REAP-working-set miss count threshold to a zlib
    level; the highest threshold <= the unit's miss count wins.  Units
    below ``min_size`` are never compressed (header overhead dominates).
    A segment's level only increases (cold payloads sink); if compression
    does not save at least ``1 - min_ratio`` of the payload it stays raw
    (marginal wins are not worth paying inflate bandwidth on every wake —
    random float mantissas "compress" ~10-15% via exponent-byte structure)
    and the attempted level is remembered so hot loops don't re-deflate
    incompressible data.
    """
    tiers: Tuple[Tuple[int, int], ...] = ((0, 0), (2, 1), (4, 6), (8, 9))
    min_size: int = 512
    min_ratio: float = 0.8

    def level_for(self, miss_count: int, nbytes: int) -> int:
        if nbytes < self.min_size:
            return 0
        lvl = 0
        for thresh, level in self.tiers:
            if miss_count >= thresh:
                lvl = level
        return lvl


@dataclass
class _Segment:
    offset: int
    stored_nbytes: int           # on-disk bytes (post compression)
    raw_nbytes: int
    level: int                   # zlib level the payload is stored at (0=raw)
    refs: int = 0
    tried_level: int = 0         # highest level ever attempted (anti-thrash)
    #: set when a peer transfer installed this segment at refcount zero
    #: and no adopt_extents has claimed it yet — a transfer that dies
    #: between import and adopt leaves these, and the orphan sweep
    #: (:meth:`SwapStore.sweep_orphans`) reclaims them
    imported_at: Optional[float] = None
    #: CRC32 of the *stored* payload, computed when the bytes were last
    #: known-good (put/import/repair); every read path verifies it, so a
    #: flipped bit on disk surfaces as :class:`CorruptSegmentError`
    #: instead of silently feeding bad bytes to every sharer
    crc: int = 0
    #: replica pins (cluster anti-entropy): a pinned segment survives GC
    #: even at refcount zero — it is another node's recovery substrate
    pins: int = 0
    #: quarantined: a read/scrub found the on-disk bytes disagree with
    #: ``crc``.  The extent is kept (never handed back to the allocator)
    #: until a repair overwrites it or GC frees it; readers refuse it
    corrupt: bool = False


class CorruptSegmentError(RuntimeError):
    """On-disk payload failed its checksum and could not be repaired."""

    def __init__(self, msg: str, digest: bytes = b""):
        super().__init__(msg)
        self.digest = digest


@dataclass
class UnitMeta:
    """Per-(owner, key) record: either a constant fill or a digest into
    the shared segment table."""
    digest: Optional[bytes]      # None -> constant-elided
    fill: int                    # byte value when elided
    nbytes: int
    dtype: str
    shape: Tuple[int, ...]


class SwapStore:
    """One per deployment (``InstanceManager``): the shared, refcounted,
    content-addressed segment file all tenants' page-fault tiers ride."""

    def __init__(self, path: str, *, salt: Optional[bytes] = None,
                 policy: Optional[StorePolicy] = None):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.fd: Optional[int] = os.open(
            path, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o600)
        #: per-deployment hash salt (security: content hashes are not
        #: comparable across deployments)
        self.salt = os.urandom(16) if salt is None else salt
        self.policy = policy or StorePolicy()
        self._segments: Dict[bytes, _Segment] = {}
        self._free: List[Tuple[int, int]] = []       # coalesced (off, nbytes)
        self._append_at = 0
        #: reads run outside the lock; extents freed while any read is in
        #: flight are quarantined here so a reader's snapshot can never be
        #: overwritten by a concurrent allocation
        self._active_reads = 0
        self._quarantine: List[Tuple[int, int]] = []
        self._clients: Dict[str, "StoreClient"] = {}
        self._lock = threading.RLock()
        #: cluster hook: ``repair_source(digest) -> (level, raw_nbytes,
        #: payload) | None`` fetches a known-good copy from a replica
        #: peer; the router wires it.  Repairs verify the content digest
        #: before installing, so a corrupt replica cannot "repair" us.
        self.repair_source: Optional[
            Callable[[bytes], Optional[Tuple[int, int, bytes]]]] = None
        self._scrubber: Optional["StoreScrubber"] = None
        self._scrub_cursor: bytes = b""
        # counters (store-wide; clients keep their own read/write counters)
        self.puts = 0
        self.dedup_hits = 0
        self.elisions = 0
        self.sink_events = 0                          # recompressions
        self.bytes_written = 0                        # on-disk bytes written
        self.writes = 0                               # write syscalls
        self.reads = 0                                # read syscalls
        self.corruptions = 0                          # checksum failures seen
        self.repairs = 0                              # segments restored
        self.import_rejects = 0                       # wire frames that failed
        #                                             # content verification

    # ------------------------------------------------------------- clients
    def client(self, owner: str) -> "StoreClient":
        with self._lock:
            c = self._clients.get(owner)
            if c is None:
                c = self._clients[owner] = StoreClient(self, owner)
            return c

    # ------------------------------------------------------------- hashing
    def keyed_digest(self, buf: bytes) -> bytes:
        """The store's salted content hash (keyed BLAKE2b-16).  Public so
        sibling subsystems that content-address by the same deployment
        salt — the prefix registry's token-hash keys — share one digest
        discipline instead of re-deriving it."""
        return hashlib.blake2b(buf, digest_size=16, key=self.salt).digest()

    def _digest(self, buf: bytes) -> bytes:
        return self.keyed_digest(buf)

    # ------------------------------------------------------------- extents
    def _alloc(self, n: int) -> int:
        """First-fit from the GC free list, else append."""
        for i, (off, sz) in enumerate(self._free):
            if sz >= n:
                if sz == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (off + n, sz - n)
                return off
        off = self._append_at
        self._append_at += n
        return off

    def _release_extent(self, off: int, n: int) -> None:
        """Return an extent to the free list, coalescing neighbours.
        While reads are in flight the extent is quarantined instead: an
        unlocked reader may still be preadv-ing those bytes."""
        if n <= 0:
            return
        if self._active_reads:
            self._quarantine.append((off, n))
            return
        self._free.append((off, n))
        self._free.sort()
        merged: List[Tuple[int, int]] = []
        for o, s in self._free:
            if merged and merged[-1][0] + merged[-1][1] == o:
                merged[-1] = (merged[-1][0], merged[-1][1] + s)
            else:
                merged.append((o, s))
        # trailing free space shrinks the append frontier (and the file)
        if merged and merged[-1][0] + merged[-1][1] == self._append_at:
            o, _ = merged.pop()
            self._append_at = o
            os.ftruncate(self.fd, o)
        self._free = merged

    # ------------------------------------------------------------- encode
    def _encode(self, buf: bytes, level: int) -> Tuple[bytes, int]:
        if level > 0:
            comp = zlib.compress(buf, level)
            if len(comp) <= self.policy.min_ratio * len(buf):
                return comp, level
        return buf, 0

    def _install_payload(self, seg: _Segment, payload: bytes,
                         level: int) -> None:
        """Write a known-good payload into a fresh extent and point the
        segment at it (repair / sink commit).  The old extent is released
        (quarantine-aware) — a crash between pwrite and the metadata flip
        just leaves the new extent unreferenced; the old bytes are intact
        because nothing ever overwrites a live extent in place."""
        old_off, old_n = seg.offset, seg.stored_nbytes
        seg.offset = self._alloc(len(payload))
        seg.stored_nbytes = len(payload)
        seg.level = level
        seg.crc = zlib.crc32(payload)
        seg.corrupt = False
        os.pwrite(self.fd, payload, seg.offset)
        self.bytes_written += len(payload)
        self.writes += 1
        self._release_extent(old_off, old_n)

    def _repair_locked(self, digest: bytes, seg: _Segment) -> bool:
        """Restore a quarantined segment from the replica peer hook.
        The fetched payload is verified end-to-end (content digest over
        the *decompressed* bytes), so a lying or equally-corrupt peer is
        rejected rather than installed."""
        src = self.repair_source
        if src is None:
            return False
        got = src(digest)
        if got is None:
            return False
        level, raw_nbytes, payload = got
        try:
            raw = zlib.decompress(payload) if level else payload
        except zlib.error:
            return False
        if self._digest(raw) != digest or len(raw) != raw_nbytes:
            return False
        self._install_payload(seg, payload, level)
        self.repairs += 1
        return True

    def _mark_corrupt(self, digest: bytes, seg: _Segment) -> None:
        if not seg.corrupt:
            seg.corrupt = True
            self.corruptions += 1

    def _restore_from_raw(self, seg: _Segment, raw: bytes) -> None:
        """Repair a quarantined segment from raw bytes already in hand
        (a dedup-hit writer is its own replica)."""
        payload, level = self._encode(raw, seg.level or seg.tried_level)
        self._install_payload(seg, payload, level)
        self.repairs += 1

    def _payload(self, seg: _Segment, digest: bytes = b"") -> bytes:
        blob = os.pread(self.fd, seg.stored_nbytes, seg.offset)
        self.reads += 1
        if zlib.crc32(blob) != seg.crc:
            self._mark_corrupt(digest, seg)
            if not self._repair_locked(digest, seg):
                raise CorruptSegmentError(
                    f"segment {digest.hex()} failed checksum "
                    f"({seg.stored_nbytes}B @ {seg.offset}); no replica "
                    f"could repair it", digest)
            blob = os.pread(self.fd, seg.stored_nbytes, seg.offset)
            self.reads += 1
        return zlib.decompress(blob) if seg.level else blob

    def _read_repaired(self, digest: bytes) -> bytes:
        """Slow path for :meth:`read`: quarantine + replica repair +
        re-read, under the lock."""
        with self._lock:
            seg = self._segments[digest]
            self._mark_corrupt(digest, seg)
            if not self._repair_locked(digest, seg):
                raise CorruptSegmentError(
                    f"segment {digest.hex()} failed checksum on read; "
                    f"no replica could repair it", digest)
            return self._payload(seg, digest)

    def _maybe_sink(self, seg: _Segment, want_level: int,
                    digest: bytes = b"") -> None:
        """Re-store a segment at a higher zlib level (cold payloads sink)."""
        if want_level <= max(seg.level, seg.tried_level) or \
                seg.raw_nbytes < self.policy.min_size:
            return
        raw = self._payload(seg, digest)
        seg.tried_level = want_level
        comp, level = self._encode(raw, want_level)
        if level == 0 or len(comp) >= seg.stored_nbytes:
            return                          # incompressible: stays put
        self._install_payload(seg, comp, level)
        self.sink_events += 1

    # ------------------------------------------------------------- put/get
    def put(self, client: "StoreClient", key: Hashable, arr: np.ndarray,
            miss_count: int = 0) -> WriteReceipt:
        arr = np.ascontiguousarray(arr)
        buf = arr.tobytes()
        r = WriteReceipt(logical_bytes=len(buf))
        with self._lock:
            self.puts += 1
            # constant-fill elision: zero pages (and any single-byte fill)
            # become pure metadata
            if len(buf) == 0 or buf.count(buf[:1]) == len(buf):
                self._drop_meta(client.extents.pop(key, None))
                client.extents[key] = UnitMeta(
                    None, buf[0] if buf else 0, len(buf),
                    str(arr.dtype), arr.shape)
                self.elisions += 1
                r.elided_bytes = len(buf)
                return r
            digest = self._digest(buf)
            old = client.extents.get(key)
            if old is not None and old.digest == digest:
                # rewrite-identical (every re-deflate of unchanged weights):
                # no disk IO, no refcount change
                self.dedup_hits += 1
                r.dedup_bytes = len(buf)
                seg = self._segments[digest]
                if seg.corrupt:
                    # the writer holds the raw bytes: cheapest repair there is
                    self._restore_from_raw(seg, buf)
                self._maybe_sink(seg,
                                 self.policy.level_for(miss_count, len(buf)),
                                 digest)
                client.extents[key] = UnitMeta(
                    digest, 0, len(buf), str(arr.dtype), arr.shape)
                return r
            self._drop_meta(client.extents.pop(key, None))
            seg = self._segments.get(digest)
            level = self.policy.level_for(miss_count, len(buf))
            if seg is None:
                payload, stored_level = self._encode(buf, level)
                seg = _Segment(self._alloc(len(payload)), len(payload),
                               len(buf), stored_level, refs=0,
                               tried_level=level, crc=zlib.crc32(payload))
                os.pwrite(self.fd, payload, seg.offset)
                self.bytes_written += len(payload)
                self.writes += 1
                self._segments[digest] = seg
                r.stored_bytes = len(payload)
            else:
                self.dedup_hits += 1
                r.dedup_bytes = len(buf)
                if seg.corrupt:
                    self._restore_from_raw(seg, buf)
                self._maybe_sink(seg, level, digest)
            seg.refs += 1
            seg.imported_at = None      # a local writer now references it
            client.extents[key] = UnitMeta(
                digest, 0, len(buf), str(arr.dtype), arr.shape)
            return r

    def read(self, client: "StoreClient", keys: Sequence[Hashable]
             ) -> Dict[Hashable, np.ndarray]:
        """Vectored batch read: keys dedup by digest, segment extents are
        sorted and adjacent extents merged — one ``preadv`` per run.

        The lock is held only to snapshot the extent plan: the disk IO and
        zlib inflate run unlocked so concurrent tenants' wakes overlap
        (a wake storm must not serialize on the deployment-wide store).
        The snapshot stays valid because (a) the caller holds a ref on
        every segment it reads, so GC cannot free them, and (b) extents
        freed by *other* tenants' GC or by sinking are quarantined until
        in-flight reads drain (`_release_extent`)."""
        with self._lock:
            metas = [(k, client.extents[k]) for k in keys]
            by_digest: Dict[bytes, List[Tuple[Hashable, UnitMeta]]] = {}
            constants: List[Tuple[Hashable, UnitMeta]] = []
            for key, m in metas:
                if m.digest is None:
                    constants.append((key, m))
                else:
                    by_digest.setdefault(m.digest, []).append((key, m))
            plan = sorted(((d, self._segments[d].offset,
                            self._segments[d].stored_nbytes,
                            self._segments[d].level,
                            self._segments[d].crc) for d in by_digest),
                          key=lambda p: p[1])
            self._active_reads += 1
        out: Dict[Hashable, np.ndarray] = {}
        calls = nbytes = 0
        try:
            for key, m in constants:       # materialized outside the lock
                out[key] = np.frombuffer(
                    bytes([m.fill]) * m.nbytes if m.nbytes else b"",
                    m.dtype).reshape(m.shape).copy()
            bufs, calls = read_extents(self.fd,
                                       [(off, n) for _, off, n, _, _ in plan])
            for (d, _, _, level, crc), buf in zip(plan, bufs):
                # integrity gate: checksum verified before any sharer sees
                # the bytes; a mismatch quarantines the extent and repairs
                # from a replica peer inline (the wake then proceeds on
                # the repaired bytes — no caller ever observes bad data)
                if zlib.crc32(buf) != crc:
                    raw = self._read_repaired(d)
                else:
                    try:
                        raw = zlib.decompress(bytes(buf)) if level else buf
                    except zlib.error:
                        raw = self._read_repaired(d)
                for key, m in by_digest[d]:
                    out[key] = np.frombuffer(
                        raw, m.dtype, count=m.nbytes
                        // np.dtype(m.dtype).itemsize
                    ).reshape(m.shape).copy()
                    nbytes += m.nbytes
        finally:
            with self._lock:
                self._active_reads -= 1
                if not self._active_reads and self._quarantine:
                    pending, self._quarantine = self._quarantine, []
                    for off, n in pending:
                        self._release_extent(off, n)
                self.reads += calls
                client.reads += calls
                client.bytes_read += nbytes
        return out

    def read_iter(self, client: "StoreClient", keys: Sequence[Hashable],
                  chunk_bytes: int = 1 << 20):
        """Streaming variant of :meth:`read`: yields ``{key: array}`` dicts
        of ~``chunk_bytes`` (logical) each.  Every chunk snapshots its own
        extent plan under the lock and runs its IO + zlib inflate unlocked,
        so a long stream never starves concurrent tenants' wakes — the
        chunk granularity is what the wake pipeline double-buffers."""
        batch: List[Hashable] = []
        pending = 0
        for k in keys:
            batch.append(k)
            with self._lock:
                pending += client.extents[k].nbytes
            if pending >= chunk_bytes:
                yield self.read(client, batch)
                batch, pending = [], 0
        if batch:
            yield self.read(client, batch)

    # ------------------------------------------------------------- cluster
    def digests(self) -> frozenset:
        """Digests of every live segment — the node's content inventory
        the cluster router scores digest-overlap affinity against."""
        with self._lock:
            return frozenset(self._segments)

    def missing_digests(self, digests) -> List[bytes]:
        """Subset of ``digests`` this store does NOT hold — what a peer
        transfer must actually ship (dedup-aware migration: everything
        else is already on this node's disk).  Quarantined segments count
        as missing: asking the peer to re-ship one IS the repair."""
        with self._lock:
            return [d for d in digests
                    if d not in self._segments or self._segments[d].corrupt]

    def stored_bytes_of(self, digests) -> int:
        """On-disk (post-compression) bytes of the given segments."""
        with self._lock:
            return sum(self._segments[d].stored_nbytes for d in digests
                       if d in self._segments)

    def export_segments(self, digests
                        ) -> List[Tuple[bytes, int, int, bytes]]:
        """Read segments out as ``(digest, level, raw_nbytes, payload)``
        wire tuples.  Payloads ship at their stored compression level —
        a cold zlib-tier segment crosses the link compressed and lands on
        the target at the same tier."""
        out: List[Tuple[bytes, int, int, bytes]] = []
        with self._lock:          # sinking relocates extents: stay locked
            for d in digests:
                seg = self._segments[d]
                blob = os.pread(self.fd, seg.stored_nbytes, seg.offset)
                self.reads += 1
                if zlib.crc32(blob) != seg.crc:
                    # never ship bad bytes: quarantine, repair, re-read —
                    # or fail the export rather than poison the peer
                    self._mark_corrupt(d, seg)
                    if not self._repair_locked(d, seg):
                        raise CorruptSegmentError(
                            f"segment {d.hex()} failed checksum on "
                            f"export; no replica could repair it", d)
                    blob = os.pread(self.fd, seg.stored_nbytes, seg.offset)
                    self.reads += 1
                out.append((d, seg.level, seg.raw_nbytes, blob))
        return out

    def export_segments_iter(self, digests, chunk_bytes: int = 4 << 20):
        """Chunked :meth:`export_segments`: yields wire-tuple batches of
        ~``chunk_bytes`` stored payload each, so a multi-GB transfer
        streams through bounded memory and the transport can apply
        flow control per chunk instead of per migration."""
        batch: List[bytes] = []
        pending = 0
        for d in digests:
            with self._lock:
                seg = self._segments.get(d)
                size = seg.stored_nbytes if seg is not None else 0
            batch.append(d)
            pending += size
            if pending >= chunk_bytes:
                yield self.export_segments(batch)
                batch, pending = [], 0
        if batch:
            yield self.export_segments(batch)

    def import_segments(self, items: Sequence[Tuple[bytes, int, int, bytes]]
                        ) -> List[bytes]:
        """Install wire segments from a peer at refcount zero; the
        follow-up :meth:`adopt_extents` call takes the references.  The
        digest is the *cluster-wide* content address, so both stores must
        share a salt (the router seeds every node from one deployment
        salt).  Newly installed segments are stamped ``imported_at`` and
        stay orphans until adopted; returns their digests so the transfer
        channel can sweep them if the migration aborts mid-bundle.

        Every frame is verified end-to-end before install: the payload is
        inflated and its salted content hash must equal the digest it
        claims.  A frame corrupted or truncated on the wire is rejected
        (counted in ``import_rejects``) — the transfer then aborts at
        adopt time with the digest missing, instead of this store serving
        poisoned bytes to every future sharer.  A verified frame whose
        digest is already present *but quarantined* repairs it in place
        (re-shipping IS the anti-entropy repair; refs and pins are
        preserved)."""
        new: List[bytes] = []
        now = time.monotonic()
        with self._lock:
            for digest, level, raw_nbytes, payload in items:
                try:
                    raw = zlib.decompress(payload) if level else payload
                except zlib.error:
                    self.import_rejects += 1
                    continue
                if self._digest(raw) != digest or len(raw) != raw_nbytes:
                    self.import_rejects += 1
                    continue
                seg = self._segments.get(digest)
                if seg is not None:
                    if seg.corrupt:
                        self._install_payload(seg, payload, level)
                        self.repairs += 1
                    else:
                        self.dedup_hits += 1
                    continue
                seg = _Segment(self._alloc(len(payload)), len(payload),
                               raw_nbytes, level, refs=0, tried_level=level,
                               imported_at=now, crc=zlib.crc32(payload))
                os.pwrite(self.fd, payload, seg.offset)
                self.bytes_written += len(payload)
                self.writes += 1
                new.append(digest)
                self._segments[digest] = seg
        return new

    def export_meta(self, client: "StoreClient") -> Dict[Hashable, "UnitMeta"]:
        """Snapshot one owner's extent table (the REAP-metadata half of a
        migration: keys, digests, dtypes, shapes — no payload bytes)."""
        with self._lock:
            return dict(client.extents)

    def adopt_extents(self, owner: str,
                      metas: Dict[Hashable, "UnitMeta"]) -> "StoreClient":
        """Rebuild a migrated tenant's client: its extent table is
        installed verbatim and a reference is taken on every segment it
        names.  Raises ``KeyError`` if a digest was never shipped —
        adoption must follow :meth:`import_segments`, never precede it."""
        with self._lock:
            missing = [m.digest for m in metas.values()
                       if m.digest is not None
                       and m.digest not in self._segments]
            if missing:
                raise KeyError(
                    f"adopt_extents({owner}): {len(missing)} digests "
                    f"absent — transfer incomplete")
            c = self.client(owner)
            for key, meta in metas.items():
                self._drop_meta(c.extents.pop(key, None))
                if meta.digest is not None:
                    seg = self._segments[meta.digest]
                    seg.refs += 1
                    seg.imported_at = None      # adopted: no longer orphan
                c.extents[key] = meta
            return c

    def pin_replicas(self, digests) -> int:
        """Pin segments as another node's recovery replica: a pinned
        segment survives GC even when every local tenant releases it —
        until the router unpins (holder rotation, tenant termination, or
        the replica being promoted by adoption).  ALL digests must be
        present (a partial pin is a lying replica); raises ``KeyError``
        otherwise.  Returns stored bytes pinned."""
        nbytes = 0
        with self._lock:
            missing = [d for d in digests if d not in self._segments]
            if missing:
                raise KeyError(
                    f"pin_replicas: {len(missing)} digests absent — "
                    f"replica incomplete")
            for d in digests:
                seg = self._segments[d]
                seg.pins += 1
                seg.imported_at = None      # pinned: not an orphan
                nbytes += seg.stored_nbytes
        return nbytes

    def unpin_replicas(self, digests) -> int:
        """Drop replica pins; segments left at refcount zero with no
        remaining pins are freed.  Returns on-disk bytes reclaimed."""
        freed = 0
        with self._lock:
            for d in digests:
                seg = self._segments.get(d)
                if seg is None:
                    continue
                seg.pins -= 1
                if seg.refs <= 0 and seg.pins <= 0:
                    del self._segments[d]
                    self._release_extent(seg.offset, seg.stored_nbytes)
                    freed += seg.stored_nbytes
        return freed

    def orphan_digests(self, max_age_s: float = 0.0) -> List[bytes]:
        """Imported-but-never-adopted segments at least ``max_age_s``
        old — what a dead transfer left behind."""
        cutoff = time.monotonic() - max_age_s
        with self._lock:
            return [d for d, s in self._segments.items()
                    if s.refs <= 0 and s.pins <= 0
                    and s.imported_at is not None
                    and s.imported_at <= cutoff]

    def sweep_orphans(self, digests=None, max_age_s: float = 0.0) -> int:
        """Free orphaned imports (refcount zero, ``imported_at`` set).

        A transfer that dies between :meth:`import_segments` and
        :meth:`adopt_extents` leaves payload bytes no client references;
        the aborting peer sweeps the digests it shipped, and the server's
        connection teardown (or a periodic pass with ``max_age_s``)
        catches peers that vanished without aborting.  Segments that were
        adopted, or that a local writer has since referenced, are never
        touched.  Returns on-disk bytes reclaimed."""
        cutoff = time.monotonic() - max_age_s
        freed = 0
        with self._lock:
            if digests is None:
                digests = [d for d, s in self._segments.items()
                           if s.imported_at is not None]
            for d in list(digests):
                seg = self._segments.get(d)
                if (seg is None or seg.refs > 0 or seg.pins > 0
                        or seg.imported_at is None
                        or seg.imported_at > cutoff):
                    continue
                del self._segments[d]
                self._release_extent(seg.offset, seg.stored_nbytes)
                freed += seg.stored_nbytes
        return freed

    # ------------------------------------------------------------- GC
    def _drop_meta(self, meta: Optional[UnitMeta]) -> None:
        if meta is None or meta.digest is None:
            return
        seg = self._segments.get(meta.digest)
        if seg is None:
            return
        seg.refs -= 1
        if seg.refs <= 0 and seg.pins <= 0:
            del self._segments[meta.digest]
            self._release_extent(seg.offset, seg.stored_nbytes)

    def release(self, client: "StoreClient") -> int:
        """Instance termination: decref every segment the owner references;
        segments at refcount zero are freed (their extents return to the
        allocator).  Returns on-disk bytes reclaimed."""
        with self._lock:
            before = self.live_bytes
            for meta in client.extents.values():
                self._drop_meta(meta)
            client.extents.clear()
            self._clients.pop(client.owner, None)
            return before - self.live_bytes

    # ------------------------------------------------------------- scrub
    def scrub(self, max_bytes: int = 64 << 20, repair: bool = True
              ) -> Dict[str, int]:
        """One bounded integrity pass: re-checksum up to ``max_bytes`` of
        stored payload, quarantine mismatches, and (optionally) repair
        them from the replica peer hook.  The cursor is resumable — the
        next call continues where this one stopped, wrapping at the end —
        so a background daemon covers the whole store in bounded slices
        without ever stalling the serve path for long."""
        scanned = segments = found = repaired = 0
        with self._lock:
            order = sorted(self._segments)
            start = 0
            for i, d in enumerate(order):
                if d > self._scrub_cursor:
                    start = i
                    break
            order = order[start:] + order[:start]
            for d in order:
                if scanned >= max_bytes:
                    break
                seg = self._segments.get(d)
                if seg is None:
                    continue
                blob = os.pread(self.fd, seg.stored_nbytes, seg.offset)
                self.reads += 1
                scanned += seg.stored_nbytes
                segments += 1
                self._scrub_cursor = d
                if zlib.crc32(blob) == seg.crc and not seg.corrupt:
                    continue
                self._mark_corrupt(d, seg)
                found += 1
                if repair and self._repair_locked(d, seg):
                    repaired += 1
        return {"scanned_bytes": scanned, "scanned_segments": segments,
                "corrupt_found": found, "repaired": repaired}

    def start_scrubber(self, interval_s: float = 30.0,
                       bytes_per_round: int = 64 << 20) -> "StoreScrubber":
        """Start (or return) the background scrub daemon."""
        with self._lock:
            if self._scrubber is None:
                self._scrubber = StoreScrubber(self, interval_s,
                                               bytes_per_round)
                self._scrubber.start()
            return self._scrubber

    def stop_scrubber(self) -> None:
        s = self._scrubber
        if s is not None:
            self._scrubber = None
            s.stop()

    def close(self) -> None:
        self.stop_scrubber()
        with self._lock:
            if self.fd is not None:
                os.close(self.fd)
                self.fd = None
            if os.path.exists(self.path):
                os.unlink(self.path)
            self._segments.clear()
            self._clients.clear()

    # ------------------------------------------------------------- stats
    @property
    def live_bytes(self) -> int:
        """On-disk bytes referenced by live segments."""
        return sum(s.stored_nbytes for s in self._segments.values())

    @property
    def file_bytes(self) -> int:
        return self._append_at

    def stats(self) -> Dict[str, float]:
        """Resident-vs-unique-vs-compressed accounting (density analysis)."""
        with self._lock:
            segs = list(self._segments.values())
            logical = elided = 0
            for c in self._clients.values():
                for m in c.extents.values():
                    logical += m.nbytes
                    if m.digest is None:
                        elided += m.nbytes
            unique = sum(s.raw_nbytes for s in segs)
            stored = sum(s.stored_nbytes for s in segs)
            return {
                "logical_bytes": logical,    # what verbatim files would hold
                "unique_bytes": unique,      # after dedup + elision
                "stored_bytes": stored,      # after compression (on disk)
                "elided_bytes": elided,
                "segments": len(segs),
                "puts": self.puts,
                "dedup_hits": self.dedup_hits,
                "elisions": self.elisions,
                "sink_events": self.sink_events,
                "free_bytes": sum(n for _, n in self._free),
                "corruptions": self.corruptions,
                "repairs": self.repairs,
                "import_rejects": self.import_rejects,
                "pinned_segments": sum(1 for s in segs if s.pins > 0),
                "pinned_bytes": sum(s.stored_nbytes for s in segs
                                    if s.pins > 0),
                "quarantined": sum(1 for s in segs if s.corrupt),
            }


class StoreScrubber:
    """Background integrity daemon: periodically runs one bounded
    :meth:`SwapStore.scrub` slice.  Stopped by :meth:`SwapStore.close`
    (or explicitly); ``wake()`` forces an immediate pass (tests)."""

    def __init__(self, store: SwapStore, interval_s: float,
                 bytes_per_round: int):
        self.store = store
        self.interval_s = interval_s
        self.bytes_per_round = bytes_per_round
        self.rounds = 0
        self.last: Dict[str, int] = {}
        self._stop = threading.Event()
        self._kick = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name=f"scrub:{store.path}", daemon=True)

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._kick.set()
        self._thread.join(timeout=5.0)

    def wake(self) -> None:
        self._kick.set()

    def _run(self) -> None:
        while not self._stop.is_set():
            self._kick.wait(self.interval_s)
            self._kick.clear()
            if self._stop.is_set():
                return
            with self.store._lock:
                if self.store.fd is None:
                    return
                self.last = self.store.scrub(self.bytes_per_round)
                self.rounds += 1


class StoreClient:
    """One tenant's view of the shared store — duck-typed to
    :class:`~repro.core.swap.SwapFile` so ``ModelInstance`` /
    ``HibernationManager`` / ``PagedKVCache`` work unchanged on either.

    ``hotness(key) -> int`` (wired to the instance's
    :meth:`~repro.core.reap.ReapRecorder.miss_count`) feeds the adaptive
    compression policy at write time.
    """

    def __init__(self, store: SwapStore, owner: str):
        self.store = store
        self.owner = owner
        self.path = store.path
        self.extents: Dict[Hashable, UnitMeta] = {}
        self.hotness: Optional[Callable[[Hashable], int]] = None
        self.bytes_written = 0               # logical (raw) bytes written
        self.bytes_read = 0
        self.reads = 0                       # read syscalls this owner caused
        self.writes = 0                      # unit writes (puts)
        self.last_receipt = WriteReceipt()

    def __contains__(self, key: Hashable) -> bool:
        return key in self.extents

    def _miss(self, key: Hashable) -> int:
        return self.hotness(key) if self.hotness is not None else 0

    # ------------------------------------------------------------- writes
    def write_unit(self, key: Hashable, arr: np.ndarray) -> None:
        r = self.store.put(self, key, arr, self._miss(key))
        self.bytes_written += r.logical_bytes
        self.writes += 1
        self.last_receipt += r

    def write_units(self, items: Sequence[Tuple[Hashable, np.ndarray]]
                    ) -> WriteReceipt:
        r = WriteReceipt()
        for k, a in items:
            r += self.store.put(self, k, a, self._miss(k))
            self.writes += 1
        self.bytes_written += r.logical_bytes
        self.last_receipt = r
        return r

    # ------------------------------------------------------------- reads
    def read_unit(self, key: Hashable) -> np.ndarray:
        return self.store.read(self, [key])[key]

    def read_units(self, keys: Sequence[Hashable]
                   ) -> Dict[Hashable, np.ndarray]:
        return self.store.read(self, keys)

    def read_units_iter(self, keys: Sequence[Hashable],
                        chunk_bytes: int = 1 << 20):
        """Chunk-granular streaming read (duck-types
        :meth:`~repro.core.swap._FileBase.read_units_iter`)."""
        return self.store.read_iter(self, keys, chunk_bytes)

    # ------------------------------------------------------------- admin
    def delete(self) -> None:
        """Sandbox termination (§3.4): release this owner's refs; shared
        segments survive for the tenants still referencing them."""
        self.store.release(self)

    @property
    def logical_bytes(self) -> int:
        return sum(m.nbytes for m in self.extents.values())

    @property
    def file_bytes(self) -> int:
        """Fair-share on-disk footprint (PSS analogue for disk): each
        segment's stored bytes split across its referencing units."""
        with self.store._lock:
            tot = 0.0
            for m in self.extents.values():
                if m.digest is None:
                    continue
                seg = self.store._segments.get(m.digest)
                if seg is not None and seg.refs:
                    tot += seg.stored_nbytes / seg.refs
            return int(tot)

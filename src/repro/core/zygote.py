"""Zygote pool: pre-initialized fork donors for brand-new tenants.

Hibernation only helps tenants that have run at least once — a brand-new
tenant still pays the full cold init (factory load + prefill compile)
the deflated-container design exists to avoid.  Following Pagurus
(arXiv:2108.11240, re-purposing *other* functions' idle containers) and
HotSwap (arXiv:2409.09202, live-sharing initialized dependencies), the
:class:`ZygotePool` keeps a small set of pre-initialized per-model-family
**zygote** instances:

* base weights adopted by refcount from the shared registry (the same
  §3.5 mmap analogue every tenant shares);
* compiled prefill handles pre-built by the engine's precompile hook,
  so the fork inherits warm executables;
* governor-charged: a zygote sits on the ladder as a first-class
  ``ZYGOTE`` state, and under pressure the :class:`~repro.core.governor.
  MemoryGovernor` retires it like any other instance — scored by its
  bytes against its *fork-avoidance* value (the predicted gap until the
  family's next new-tenant admission over the cold-start wake prior).

``InstanceManager.fork_start`` consumes a zygote to admit a new tenant:
the tenant takes its own shared-registry ref *before* the donor releases
(refcount isolation — retiring a zygote never frees a forked tenant's
shared pages), copies the donor's anonymous weights (a memcpy, not an
init), inherits the compiled handles, and enters the state graph through
``(COLD, FORK) -> WARM`` so its history records a warm fork, not a cold
start.  Tenant deltas (tuned weights, KV prefixes, session state) still
arrive through the existing CAS-store / streamed-wake machinery — the
fork replaces only the cold init.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.instance import ModelInstance
from repro.core.state import ContainerState, Event

#: forecaster key namespace for per-family new-tenant arrival streams
#: (synthetic keys so the seasonal model can learn "new tenants of this
#: family show up every morning" without colliding with tenant ids)
NEW_TENANT_KEY = "__newtenant__:"

#: instance-id namespace for zygotes (filename-safe: ids name REAP/spool
#: files, and arch keys contain no path separators)
_ZYGOTE_PREFIX = "__zygote__."


def zygote_id(family: str, seq: int) -> str:
    """The pool's instance-id scheme for a zygote of ``family``."""
    return f"{_ZYGOTE_PREFIX}{family}.{seq}"


def is_zygote_id(instance_id: str) -> bool:
    """True when ``instance_id`` names a pool zygote, not a tenant."""
    return instance_id.startswith(_ZYGOTE_PREFIX)


@dataclass
class ZygoteConfig:
    """Pool sizing and fork-economics knobs."""
    #: live zygotes kept per model family
    per_family: int = 1
    #: hard cap on live zygotes across all families
    max_total: int = 8
    #: charge zygote bytes (anon weights + metadata) to the governor's
    #: budget.  False exempts them — shared base weights stay charged
    #: (tenants share those buffers), and the governor can still retire
    #: a zygote under pressure; only the accounting changes.
    charge_governor: bool = True
    #: the forecast daemon pre-forks a family whose predicted next
    #: new-tenant admission falls within this margin
    prefork_margin_s: float = 10.0
    #: EWMA smoothing for per-family new-tenant inter-admission gaps
    fork_gap_alpha: float = 0.3
    #: retire a zygote idle (unforked) this long even without memory
    #: pressure; None leaves retirement to the governor alone
    retire_idle_s: Optional[float] = None
    #: predicted fork gap for a family with no admission history — large,
    #: so unknown families never outrank tenants in governor scoring
    default_gap_s: float = 3600.0
    #: prompt lengths whose prefill executables the engine pre-builds at
    #: spawn (the compile a forked tenant's first request then skips)
    precompile_prompt_lens: Tuple[int, ...] = (8,)


class ZygotePool:
    """Per-manager pool of pre-initialized fork donors.

    Thread-safe: the pool lock guards its own bookkeeping; instance-table
    mutations go through the owning :class:`~repro.core.manager.
    InstanceManager`'s APIs.  Zygotes live in ``manager.instances`` like
    any tenant (the governor sees and prices them); the pool tracks which
    ids are donors and for which family.
    """

    def __init__(self, manager, cfg: Optional[ZygoteConfig] = None):
        """``manager`` is the owning InstanceManager (not imported to
        avoid a cycle); ``cfg`` defaults to :class:`ZygoteConfig`."""
        self.manager = manager
        self.cfg = cfg or ZygoteConfig()
        self._lock = threading.RLock()
        #: family -> list of live zygote ids (oldest first)
        self._by_family: Dict[str, List[str]] = {}
        self._spawned_at: Dict[str, float] = {}
        #: family -> (last_admission_ts, ewma_gap_s)
        self._admissions: Dict[str, Tuple[float, Optional[float]]] = {}
        #: family -> shared paths remembered from the last spawn/fork, so
        #: a forecast-driven pre-fork spawns donors with the same sharing
        self._shared_paths: Dict[str, Optional[frozenset]] = {}
        #: family -> last pre-fork decision ts (one-margin cooldown)
        self._last_prefork: Dict[str, float] = {}
        self._seq = 0
        #: engine-installed hook ``precompile(inst)`` that pre-builds the
        #: prefill executables a forked tenant inherits
        self.precompile: Optional[Callable[[ModelInstance], None]] = None
        self.spawned = 0
        self.forked = 0
        self.retired = 0

    # ------------------------------------------------------------- spawn
    def spawn(self, family: str, shared_paths=None
              ) -> Optional[ModelInstance]:
        """Pre-initialize one zygote for ``family`` (cap-gated).

        Runs the expensive cold-init work (factory + shared acquire +
        precompile) *now*, off any request path, so a later fork is a
        memcpy.  Returns the zygote instance, or None when the per-family
        or total cap is already met.
        """
        mgr = self.manager
        with self._lock:
            self._prune()
            live = self._by_family.get(family, [])
            total = sum(len(v) for v in self._by_family.values())
            if len(live) >= self.cfg.per_family \
                    or total >= self.cfg.max_total:
                return None
            zid = zygote_id(family, self._seq)
            self._seq += 1
            # reserve the slot before the (slow) init so concurrent
            # spawners cannot overshoot the caps
            self._by_family.setdefault(family, []).append(zid)
            self._spawned_at[zid] = time.monotonic()
            if shared_paths is not None:
                self._shared_paths[family] = frozenset(shared_paths)
            else:
                shared_paths = self._shared_paths.get(family)
        try:
            model_cfg, params = mgr.factory(family)
            inst = ModelInstance(
                zid, model_cfg, params, pool=mgr.pool,
                spool_dir=mgr.cfg.spool_dir,
                shared_paths=shared_paths if mgr.shared else None,
                base_id=family if mgr.shared else None,
                store=mgr.store,
                metadata_bytes=mgr.cfg.husk_metadata_bytes,
                arch_key=family)
            if mgr.shared and inst.base_id and inst.shared_paths:
                mgr.shared.acquire(inst.base_id, inst)
            inst.sm.fire(Event.ZYGOTE_SPAWN)
            with mgr._lock:
                mgr.instances[zid] = inst
            if self.precompile is not None:
                self.precompile(inst)
        except BaseException:
            with self._lock:
                ids = self._by_family.get(family, [])
                if zid in ids:
                    ids.remove(zid)
                self._spawned_at.pop(zid, None)
            raise
        self.spawned += 1
        mgr.events.append((time.monotonic(), "zygote_spawn", zid))
        return inst

    def ensure(self, family: str, shared_paths=None
               ) -> Optional[ModelInstance]:
        """Spawn a zygote for ``family`` unless one is already live."""
        with self._lock:
            self._prune()
            for zid in self._by_family.get(family, []):
                inst = self.manager.instances.get(zid)
                if inst is not None:
                    return inst
        return self.spawn(family, shared_paths=shared_paths)

    def take(self, family: str) -> Optional[ModelInstance]:
        """Claim a live zygote of ``family`` for a fork (removes it from
        the pool; the manager consumes and terminates the donor)."""
        with self._lock:
            ids = self._by_family.get(family, [])
            while ids:
                zid = ids.pop(0)
                self._spawned_at.pop(zid, None)
                inst = self.manager.instances.get(zid)
                if inst is not None \
                        and inst.state == ContainerState.ZYGOTE:
                    return inst
        return None

    def _prune(self) -> None:
        # drop bookkeeping for zygotes the governor evicted underneath us
        with self._lock:
            for family, ids in list(self._by_family.items()):
                alive = [z for z in ids if z in self.manager.instances]
                if len(alive) != len(ids):
                    self._by_family[family] = alive
                    for z in set(ids) - set(alive):
                        self._spawned_at.pop(z, None)

    def note_evicted(self, instance_id: str) -> None:
        """Manager hook: a zygote left ``instances`` (governor retire)."""
        if not is_zygote_id(instance_id):
            return
        with self._lock:
            for ids in self._by_family.values():
                if instance_id in ids:
                    ids.remove(instance_id)
            self._spawned_at.pop(instance_id, None)

    # ----------------------------------------------------------- economics
    def note_admission(self, family: str,
                       now: Optional[float] = None) -> None:
        """Record a new-tenant admission for ``family`` (fork or cold).

        Feeds the per-family inter-admission EWMA and the forecaster's
        synthetic ``__newtenant__:family`` stream — the fork-avoidance
        signal the governor and the pre-fork daemon both price.
        """
        now = time.monotonic() if now is None else now
        with self._lock:
            last, gap = self._admissions.get(family, (None, None))
            if last is not None:
                a = self.cfg.fork_gap_alpha
                gap = (now - last) if gap is None else \
                    a * (now - last) + (1 - a) * gap
            self._admissions[family] = (now, gap)
        f = self.manager.governor.forecaster
        if f is not None:
            f.observe(NEW_TENANT_KEY + family, now)

    def predicted_fork_gap(self, family: str, now: float) -> float:
        """Expected seconds until the family's next new-tenant admission.

        The reactive inter-admission EWMA (``default_gap_s`` with no
        history), blended with the forecaster's seasonal/burst prediction
        for the family's synthetic arrival stream when one is configured
        — the same degradation discipline as the governor's
        ``predicted_gap``.
        """
        with self._lock:
            last, gap = self._admissions.get(family, (None, None))
        if last is None:
            reactive = self.cfg.default_gap_s
        elif gap is None:
            reactive = max(1e-3, now - last)
        else:
            reactive = max(1e-3, gap)
        f = self.manager.governor.forecaster
        if f is not None:
            blended = f.predicted_gap(NEW_TENANT_KEY + family, now,
                                      reactive)
            if blended is not None:
                return max(1e-3, blended)
        return reactive

    def prefork_candidates(self, now: float) -> List[str]:
        """Families worth pre-forking: no live zygote, predicted next
        new-tenant admission within ``prefork_margin_s``, one-margin
        per-family cooldown (a wrong prediction cannot ping-pong spawns
        every daemon pass)."""
        out: List[str] = []
        margin = self.cfg.prefork_margin_s
        with self._lock:
            self._prune()
            families = set(self._admissions) | set(self._shared_paths)
            for family in sorted(families):
                if self._by_family.get(family):
                    continue
                last = self._last_prefork.get(family)
                if last is not None and (now - last) < margin:
                    continue
                if self.predicted_fork_gap(family, now) <= margin:
                    self._last_prefork[family] = now
                    out.append(family)
        return out

    # ------------------------------------------------------------- retire
    def retire(self, zygote_id_: str) -> None:
        """Evict one zygote (``(ZYGOTE, EVICT) -> DEAD``): the normal
        manager evict path releases its shared-registry ref and deletes
        its spool files; ``note_evicted`` drops the pool bookkeeping."""
        self.manager.evict(zygote_id_)
        self.retired += 1

    def reap_idle(self, now: Optional[float] = None) -> List[str]:
        """Retire zygotes idle past ``retire_idle_s`` (no-op when that
        knob is None).  Returns the retired ids."""
        if self.cfg.retire_idle_s is None:
            return []
        now = time.monotonic() if now is None else now
        with self._lock:
            self._prune()
            stale = [z for z, t in self._spawned_at.items()
                     if (now - t) > self.cfg.retire_idle_s]
        for zid in stale:
            if zid in self.manager.instances:
                self.retire(zid)
        return stale

    # ---------------------------------------------------------- accounting
    def live(self) -> List[ModelInstance]:
        """Live zygote instances across all families."""
        with self._lock:
            self._prune()
            out = []
            for ids in self._by_family.values():
                for zid in ids:
                    inst = self.manager.instances.get(zid)
                    if inst is not None:
                        out.append(inst)
            return out

    def families(self) -> Dict[str, int]:
        """``{family: live zygote count}`` — the node's advertisement."""
        with self._lock:
            self._prune()
            return {f: len(ids) for f, ids in self._by_family.items()
                    if ids}

    def has(self, family: str) -> bool:
        """True when a live zygote of ``family`` is available to fork."""
        with self._lock:
            self._prune()
            return bool(self._by_family.get(family))

    def zygote_bytes(self, family: str) -> int:
        """Bytes of init work a fork of ``family`` would avoid here:
        anonymous weights plus the shared base the donor holds a ref on.
        The router's zygote-affinity placement term."""
        gov = self.manager.governor
        tot = 0
        with self._lock:
            self._prune()
            for zid in self._by_family.get(family, []):
                inst = self.manager.instances.get(zid)
                if inst is not None:
                    tot += gov._anon_resident_bytes(inst)
                    tot += inst.shared_weight_bytes()
        return tot

    def uncharged_bytes(self) -> int:
        """Bytes ``charge_governor=False`` exempts from the governed
        total: every live zygote's anonymous weights + metadata (shared
        base weights stay charged — live tenants share those buffers)."""
        gov = self.manager.governor
        tot = 0
        for inst in self.live():
            tot += gov._anon_resident_bytes(inst) + inst.metadata_bytes()
        return tot

    def stats(self) -> Dict[str, int]:
        """Counter snapshot for logs and benchmarks."""
        with self._lock:
            return {"spawned": self.spawned, "forked": self.forked,
                    "retired": self.retired,
                    "live": sum(len(v) for v in self._by_family.values())}

"""Container state machine — Figure 3 of the paper, extended to a
multi-rung *deflation ladder*.

The paper's spectrum between Warm and Hibernate is a ladder of rungs,
each releasing more memory and costing more to wake:

    WARM -> MMAP_CLEAN -> PARTIAL -> HIBERNATED -> TERMINATED

  * ``MMAP_CLEAN`` — file-backed mmap cleanup (§3.5): re-mappable shared
    base-weight units are decref'd; anonymous memory stays resident, so a
    request only pays a checkpoint re-read when this tenant was the last
    sharer.
  * ``PARTIAL``    — partial deflate: *cold* anonymous units (REAP-miss-
    ranked MoE experts, deep-layer KV pages) are swapped out while the
    prefill-critical prefix stays resident — wake TTFT stays near-warm.
  * ``HIBERNATE``  — the paper's full deflate (Fig. 3): everything
    anonymous on disk, zero CPU.
  * ``DEAD``       — terminated: swap refs released, metadata gone.

The classic Fig. 3 graph (COLD/WARM/RUNNING/HIBERNATE/HIBERNATE_RUNNING/
WOKEN, circled transition numbers) is preserved verbatim; the ladder adds
the two intermediate rungs plus their entry/exit events.  Every
transition is guarded; invalid events raise ``InvalidTransition`` so the
property tests can assert the machine never leaves the graph.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class ContainerState(enum.Enum):
    COLD = "cold"                        # not yet created / evicted
    WARM = "warm"                        # fully initialized, idle, inflated
    RUNNING = "running"                  # processing a request (inflated)
    MMAP_CLEAN = "mmap_clean"            # shared mmap units dropped, anon resident
    PARTIAL = "partial"                  # cold anon units swapped, prefix resident
    HIBERNATE = "hibernate"              # deflated, paused, zero CPU
    HIBERNATE_RUNNING = "hib_running"    # woken by a request, processing
    WOKEN = "woken"                      # request finished, partially inflated
    MIGRATING = "migrating"              # snapshot in transit to another node
    ZYGOTE = "zygote"                    # pre-initialized, unowned fork donor
    DEAD = "dead"                        # evicted / terminated


class Rung(enum.IntEnum):
    """Position on the deflation ladder — ordered: deflating an instance
    moves it to a strictly higher rung, waking moves it lower."""
    WARM = 0
    MMAP_CLEAN = 1
    PARTIAL = 2
    HIBERNATED = 3
    TERMINATED = 4


class Event(enum.Enum):
    COLD_START = "cold_start"            # ① platform spawns a new container
    REQUEST = "request"                  # ②⑥⑦ user request arrives
    FINISH = "finish"                    # ③⑧ request processing done
    MMAP_DROP = "mmap_drop"              # ladder rung 1: clean file-backed mmap
    PARTIAL_STOP = "partial_stop"        # ladder rung 2: swap out cold units
    SIGSTOP = "sigstop"                  # ④⑨ platform deflates (full)
    SIGCONT = "sigcont"                  # ⑤ predictive wake-up
    EVICT = "evict"                      # terminate, delete swap files
    MIGRATE = "migrate"                  # cluster: ship snapshot to a peer node
    MIGRATE_DONE = "migrate_done"        # transfer committed on the target
    MIGRATE_ABORT = "migrate_abort"      # transfer failed: state stays local
    ZYGOTE_SPAWN = "zygote_spawn"        # pool pre-initializes a fork donor
    FORK = "fork"                        # new tenant specializes a zygote


S, E = ContainerState, Event

#: (state, event) -> (next_state, paper transition number / ladder tag)
TRANSITIONS: Dict[Tuple[ContainerState, Event], Tuple[ContainerState, str]] = {
    (S.COLD, E.COLD_START):            (S.WARM, "(1)"),
    (S.WARM, E.REQUEST):               (S.RUNNING, "(2)"),
    (S.RUNNING, E.FINISH):             (S.WARM, "(3)"),
    (S.WARM, E.SIGSTOP):               (S.HIBERNATE, "(4)"),
    (S.HIBERNATE, E.SIGCONT):          (S.WOKEN, "(5)"),
    (S.WOKEN, E.REQUEST):              (S.HIBERNATE_RUNNING, "(6)"),
    (S.HIBERNATE, E.REQUEST):          (S.HIBERNATE_RUNNING, "(7)"),
    (S.HIBERNATE_RUNNING, E.FINISH):   (S.WOKEN, "(8)"),
    (S.WOKEN, E.SIGSTOP):              (S.HIBERNATE, "(9)"),
    # --- deflation ladder: each rung is reachable from every rung above
    # it (the governor may skip an empty rung), never from below
    (S.WARM, E.MMAP_DROP):             (S.MMAP_CLEAN, "(4a)"),
    # a WOKEN instance already has tail units swapped out: cleaning its
    # mmap leaves it *partially* resident, not MMAP_CLEAN-fully-resident
    (S.WOKEN, E.MMAP_DROP):            (S.PARTIAL, "(4a')"),
    (S.WARM, E.PARTIAL_STOP):          (S.PARTIAL, "(4b)"),
    (S.WOKEN, E.PARTIAL_STOP):         (S.PARTIAL, "(4b)"),
    (S.MMAP_CLEAN, E.PARTIAL_STOP):    (S.PARTIAL, "(4b)"),
    # proportional reclaim: the governor may take further bites out of an
    # already-PARTIAL instance (swap more cold units) without changing rung
    (S.PARTIAL, E.PARTIAL_STOP):       (S.PARTIAL, "(4b)"),
    (S.MMAP_CLEAN, E.SIGSTOP):         (S.HIBERNATE, "(4)"),
    (S.PARTIAL, E.SIGSTOP):            (S.HIBERNATE, "(4)"),
    # --- ladder wakes: one SIGCONT climbs back to the servable rung the
    # memory supports (MMAP_CLEAN re-maps -> fully warm; PARTIAL restores
    # in the background -> woken)
    (S.MMAP_CLEAN, E.SIGCONT):         (S.WARM, "(5a)"),
    (S.PARTIAL, E.SIGCONT):            (S.WOKEN, "(5b)"),
    # --- requests on intermediate rungs
    (S.MMAP_CLEAN, E.REQUEST):         (S.RUNNING, "(2a)"),
    (S.PARTIAL, E.REQUEST):            (S.HIBERNATE_RUNNING, "(7b)"),
    # eviction (the TERMINATED rung) is legal from any idle state
    (S.WARM, E.EVICT):                 (S.DEAD, "evict"),
    (S.MMAP_CLEAN, E.EVICT):           (S.DEAD, "evict"),
    (S.PARTIAL, E.EVICT):              (S.DEAD, "evict"),
    (S.HIBERNATE, E.EVICT):            (S.DEAD, "evict"),
    (S.WOKEN, E.EVICT):                (S.DEAD, "evict"),
    # --- cluster migration: a deflated-enough tenant (its anon state is
    # on the CAS/REAP disk tier, or about to be flushed there by
    # migrate_out) ships to a peer node.  MIGRATING is a fenced state:
    # requests block on the transfer handle (mirroring the shared wake
    # pipeline), and the governor may neither deflate nor TERMINATE it —
    # (MIGRATING, EVICT) is deliberately NOT in this table, so a stale
    # governor descent can never free swap state a transfer still reads.
    (S.MMAP_CLEAN, E.MIGRATE):         (S.MIGRATING, "(10)"),
    (S.PARTIAL, E.MIGRATE):            (S.MIGRATING, "(10)"),
    (S.HIBERNATE, E.MIGRATE):          (S.MIGRATING, "(10)"),
    (S.MIGRATING, E.MIGRATE_DONE):     (S.DEAD, "(11)"),
    (S.MIGRATING, E.MIGRATE_ABORT):    (S.HIBERNATE, "(11')"),
    # --- zygote pool: a pre-initialized, tenant-less fork donor.  A
    # ZYGOTE never serves (REQUEST is deliberately NOT legal here) — it
    # exists only to be consumed by a fork or retired by the governor.
    # The forked *tenant* enters the graph through (COLD, FORK), so its
    # history distinguishes a warm fork from a true cold start.
    (S.COLD, E.ZYGOTE_SPAWN):          (S.ZYGOTE, "(z1)"),
    (S.COLD, E.FORK):                  (S.WARM, "(z2)"),
    (S.ZYGOTE, E.FORK):                (S.DEAD, "(z3)"),
    (S.ZYGOTE, E.EVICT):               (S.DEAD, "retire"),
}

#: states in which the instance holds *no* device memory for app state
DEFLATED_STATES = frozenset({S.HIBERNATE, S.MIGRATING})
#: states in which the instance consumes zero scheduler slots ("zero CPU")
PAUSED_STATES = frozenset({S.HIBERNATE, S.MIGRATING, S.DEAD})
#: states from which a request can be served without a cold start
SERVABLE_STATES = frozenset({S.WARM, S.MMAP_CLEAN, S.PARTIAL,
                             S.HIBERNATE, S.WOKEN})

#: ladder position of every non-running state (running states keep the
#: rung of the state they will FINISH back into)
RUNG_OF: Dict[ContainerState, Rung] = {
    S.WARM: Rung.WARM,
    S.RUNNING: Rung.WARM,
    S.WOKEN: Rung.WARM,            # servable without any wake work
    S.HIBERNATE_RUNNING: Rung.WARM,
    S.MMAP_CLEAN: Rung.MMAP_CLEAN,
    S.PARTIAL: Rung.PARTIAL,
    S.HIBERNATE: Rung.HIBERNATED,
    # migrate_out flushes anon state to disk before the state flips, so a
    # MIGRATING instance holds hibernated-rung memory (metadata only)
    S.MIGRATING: Rung.HIBERNATED,
    # a zygote is fully inflated (that is its whole value); its bytes are
    # priced by the governor against fork avoidance, not wake cost
    S.ZYGOTE: Rung.WARM,
    S.DEAD: Rung.TERMINATED,
    S.COLD: Rung.TERMINATED,
}

#: the deflate event that takes an (idle, servable) state to a given rung
DEFLATE_EVENT_FOR: Dict[Rung, Event] = {
    Rung.MMAP_CLEAN: E.MMAP_DROP,
    Rung.PARTIAL: E.PARTIAL_STOP,
    Rung.HIBERNATED: E.SIGSTOP,
    Rung.TERMINATED: E.EVICT,
}


class InvalidTransition(RuntimeError):
    pass


@dataclass
class StateMachine:
    state: ContainerState = ContainerState.COLD
    history: List[Tuple[float, ContainerState, Event, ContainerState, str]] = \
        field(default_factory=list)
    hooks: Dict[Event, List[Callable]] = field(default_factory=dict)

    def can(self, event: Event) -> bool:
        return (self.state, event) in TRANSITIONS

    def fire(self, event: Event, clock: Optional[Callable[[], float]] = None
             ) -> ContainerState:
        key = (self.state, event)
        if key not in TRANSITIONS:
            raise InvalidTransition(
                f"event {event.value!r} invalid in state {self.state.value!r}")
        new, tag = TRANSITIONS[key]
        t = (clock or time.monotonic)()
        self.history.append((t, self.state, event, new, tag))
        self.state = new
        for fn in self.hooks.get(event, ()):
            fn(self)
        return new

    def on(self, event: Event, fn: Callable) -> None:
        self.hooks.setdefault(event, []).append(fn)

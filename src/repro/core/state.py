"""Container state machine — Figure 3 of the paper, exactly.

States: the three conventional ones (COLD start pseudo-state, WARM, RUNNING)
plus the paper's three new states (HIBERNATE, HIBERNATE_RUNNING, WOKEN).
Transitions carry the paper's circled numbers.  Every transition is guarded;
invalid events raise ``InvalidTransition`` so the property tests can assert
the machine never leaves the paper's graph.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class ContainerState(enum.Enum):
    COLD = "cold"                        # not yet created / evicted
    WARM = "warm"                        # fully initialized, idle, inflated
    RUNNING = "running"                  # processing a request (inflated)
    HIBERNATE = "hibernate"              # deflated, paused, zero CPU
    HIBERNATE_RUNNING = "hib_running"    # woken by a request, processing
    WOKEN = "woken"                      # request finished, partially inflated
    DEAD = "dead"                        # evicted / terminated


class Event(enum.Enum):
    COLD_START = "cold_start"            # ① platform spawns a new container
    REQUEST = "request"                  # ②⑥⑦ user request arrives
    FINISH = "finish"                    # ③⑧ request processing done
    SIGSTOP = "sigstop"                  # ④⑨ platform deflates
    SIGCONT = "sigcont"                  # ⑤ predictive wake-up
    EVICT = "evict"                      # terminate, delete swap files


S, E = ContainerState, Event

#: (state, event) -> (next_state, paper transition number)
TRANSITIONS: Dict[Tuple[ContainerState, Event], Tuple[ContainerState, str]] = {
    (S.COLD, E.COLD_START):            (S.WARM, "(1)"),
    (S.WARM, E.REQUEST):               (S.RUNNING, "(2)"),
    (S.RUNNING, E.FINISH):             (S.WARM, "(3)"),
    (S.WARM, E.SIGSTOP):               (S.HIBERNATE, "(4)"),
    (S.HIBERNATE, E.SIGCONT):          (S.WOKEN, "(5)"),
    (S.WOKEN, E.REQUEST):              (S.HIBERNATE_RUNNING, "(6)"),
    (S.HIBERNATE, E.REQUEST):          (S.HIBERNATE_RUNNING, "(7)"),
    (S.HIBERNATE_RUNNING, E.FINISH):   (S.WOKEN, "(8)"),
    (S.WOKEN, E.SIGSTOP):              (S.HIBERNATE, "(9)"),
    # eviction is legal from any idle state
    (S.WARM, E.EVICT):                 (S.DEAD, "evict"),
    (S.HIBERNATE, E.EVICT):            (S.DEAD, "evict"),
    (S.WOKEN, E.EVICT):                (S.DEAD, "evict"),
}

#: states in which the instance holds *no* device memory for app state
DEFLATED_STATES = frozenset({S.HIBERNATE})
#: states in which the instance consumes zero scheduler slots ("zero CPU")
PAUSED_STATES = frozenset({S.HIBERNATE, S.DEAD})
#: states from which a request can be served without a cold start
SERVABLE_STATES = frozenset({S.WARM, S.HIBERNATE, S.WOKEN})


class InvalidTransition(RuntimeError):
    pass


@dataclass
class StateMachine:
    state: ContainerState = ContainerState.COLD
    history: List[Tuple[float, ContainerState, Event, ContainerState, str]] = \
        field(default_factory=list)
    hooks: Dict[Event, List[Callable]] = field(default_factory=dict)

    def can(self, event: Event) -> bool:
        return (self.state, event) in TRANSITIONS

    def fire(self, event: Event, clock: Optional[Callable[[], float]] = None
             ) -> ContainerState:
        key = (self.state, event)
        if key not in TRANSITIONS:
            raise InvalidTransition(
                f"event {event.value!r} invalid in state {self.state.value!r}")
        new, tag = TRANSITIONS[key]
        t = (clock or time.monotonic)()
        self.history.append((t, self.state, event, new, tag))
        self.state = new
        for fn in self.hooks.get(event, ()):
            fn(self)
        return new

    def on(self, event: Event, fn: Callable) -> None:
        self.hooks.setdefault(event, []).append(fn)

"""Deployment-wide resident KV prefix registry (cross-tenant COW adoption).

The CAS store dedups *swapped* pages; shared prompt prefixes (system
prompts, few-shot headers) still duplicate **resident** KV in every tenant
that serves them.  The registry closes that gap: a freshly prefilled
prompt is snapshotted under a salted token-hash, and any later session —
same tenant or another tenant on the node — whose prompt hashes to a
registered prefix *adopts* the existing pool pages by COW refcount instead
of recomputing prefill (HotSwap's live sharing of initialized state;
Pagurus's inter-container reuse).

Keys follow the store's keyed-BLAKE2b digest discipline: the hash is
salted with the deployment salt, so prefix digests never leak across
deployments and a tenant cannot probe another deployment's registry by
hash.  Within a deployment the trust stance is deliberate: adoption is
only sound because every instance of one ``arch_key`` is built by the
same deterministic factory (identical weights — the digest partitions on
the arch/base id precisely so tenants with different weights never share).

Lifecycle:

  * ``register`` — snapshot a prefilled session's pages under the digest;
    the registry takes its own pool references (owner ``"__prefix__"``)
    and immediately *write-throughs* the pages into the CAS store, so the
    prefix is content-addressed from birth;
  * ``adopt`` / ``reattach`` — COW-share the registry's pages into a
    session (never copied, never overwritten: the cache's write path
    breaks COW on refcount > 1, so adopted decode is bit-exact);
  * ``spill`` — last-sharer-down (every sharer deflated or gone): the
    registry frees its resident references; the pages live on as CAS
    segments and ``revive`` rebuilds them by digest instead of prefill;
  * migration ships registry *records* (digests + token ids, no page
    payloads): the target rebuilds from its own registry or store.
"""
from __future__ import annotations

import hashlib
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

#: pool/store owner the registry holds its references under
PREFIX_OWNER = "__prefix__"


@dataclass
class PrefixEntry:
    digest: bytes
    arch_key: str
    token_ids: Tuple[int, ...]
    num_tokens: int
    #: prefill's argmax token — adoption emits it without a forward pass
    first_token: int
    n_layers: int
    #: total page count (stable across spill/revive — sizes inventory math)
    n_pages: int
    #: pages[layer][i] = pool page id while resident; None while spilled
    pages: Optional[List[List[int]]]
    #: host units (SSM state, conv, cross-K/V) keyed (layer, kind) — small,
    #: kept resident even while the pool pages are spilled
    host_units: Dict[Tuple, np.ndarray] = field(default_factory=dict)
    #: sessions currently mapping this prefix: (instance_id, session_id)
    sharers: Set[Tuple[str, str]] = field(default_factory=set)
    #: the subset of sharers whose prefix slots are currently resident
    resident_sharers: Set[Tuple[str, str]] = field(default_factory=set)
    adoptions: int = 0

    @property
    def resident(self) -> bool:
        return self.pages is not None

    def page_ids(self) -> List[int]:
        return [p for layer in (self.pages or []) for p in layer]


class PrefixRegistry:
    """Node-local half of the deployment-wide prefix registry.

    One per :class:`~repro.core.manager.InstanceManager`; the cluster
    router reads each node's :meth:`inventory` for the placement
    prefix-affinity term, and migration moves entries as records via
    :meth:`export_records` / :meth:`install_records`.
    """

    def __init__(self, pool, store=None, *, salt: Optional[bytes] = None,
                 min_tokens: int = 4):
        self.pool = pool
        self.store = store
        self.salt = (store.salt if store is not None
                     else (salt if salt is not None else os.urandom(16)))
        #: prompts shorter than this are not worth registry metadata
        self.min_tokens = min_tokens
        self._entries: Dict[bytes, PrefixEntry] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.registrations = 0
        self.spills = 0
        self.revives = 0

    # ------------------------------------------------------------- hashing
    def digest_of(self, arch_key: str, token_ids: Sequence[int]) -> bytes:
        """Salted token-hash: the store's keyed-BLAKE2b discipline applied
        to (arch, token ids) instead of page payloads."""
        buf = arch_key.encode() + b"\x00" + \
            np.asarray(list(token_ids), np.int64).tobytes()
        if self.store is not None:
            return self.store.keyed_digest(buf)
        return hashlib.blake2b(buf, digest_size=16, key=self.salt).digest()

    def get(self, digest: bytes) -> Optional[PrefixEntry]:
        return self._entries.get(digest)

    def lookup(self, arch_key: str,
               token_ids: Sequence[int]) -> Optional[PrefixEntry]:
        """Exact-match lookup (token ids are compared, not just the hash —
        a digest collision must never alias two prompts)."""
        with self._lock:
            e = self._entries.get(self.digest_of(arch_key, token_ids))
            if e is None or e.arch_key != arch_key or \
                    tuple(e.token_ids) != tuple(token_ids):
                self.misses += 1
                return None
            self.hits += 1
            return e

    # ------------------------------------------------------------- register
    def register(self, arch_key: str, kv, session_id: str,
                 first_token: int) -> Optional[PrefixEntry]:
        """Snapshot a freshly prefilled session as a shareable prefix.

        The registry COW-shares the session's pages under its own owner
        (so the prefix outlives the session) and write-throughs the page
        contents into the CAS store — the prefix is content-addressed from
        birth, which is what makes last-sharer-down spill and migration-
        by-digest free."""
        s = kv.sessions[session_id]
        if s.num_tokens < self.min_tokens:
            return None
        with self._lock:
            digest = self.digest_of(arch_key, s.token_ids)
            e = self._entries.get(digest)
            if e is not None:
                # concurrent private prefill of an already-known prompt:
                # attach as a sharer, don't re-snapshot
                self._attach(e, kv, s)
                return e
            pages: List[List[int]] = []
            host: Dict[Tuple, np.ndarray] = {}
            for layer in range(len(s.pages)):
                if any(p is None for p in s.pages[layer]):
                    return None            # partially deflated: not a donor
                pages.append(list(s.pages[layer]))
            for k, arr in s.host_units.items():
                if arr is None:
                    return None
                host[(k[2], k[3])] = arr.copy()
            self.pool.share([p for layer in pages for p in layer],
                            PREFIX_OWNER)
            e = PrefixEntry(
                digest=digest, arch_key=arch_key,
                token_ids=tuple(int(t) for t in s.token_ids),
                num_tokens=s.num_tokens,
                first_token=int(first_token), n_layers=len(pages),
                n_pages=sum(len(layer) for layer in pages),
                pages=pages, host_units=host)
            self._entries[digest] = e
            self.registrations += 1
            self._write_through(e, kv)
            self._attach(e, kv, s)
            return e

    def _attach(self, e: PrefixEntry, kv, s) -> None:
        s.prefix_digest = e.digest
        s.prefix_tokens = e.num_tokens
        s.prefix_resident = True
        e.sharers.add((kv.instance_id, s.session_id))
        e.resident_sharers.add((kv.instance_id, s.session_id))

    def _write_through(self, e: PrefixEntry, kv) -> None:
        """Content-address the prefix into the CAS store now (not at
        deflate): spill/revive and cross-node rebuild work by digest."""
        if self.store is None or e.pages is None:
            return
        client = self.store.client(PREFIX_OWNER)
        items = []
        for layer, lpages in enumerate(e.pages):
            for pidx, pid in enumerate(lpages):
                key = ("pfx", e.digest, layer, pidx)
                if key in client:
                    continue
                items.append(
                    (key, kv.export_prefix_page(pid, pidx, e.num_tokens)))
        for (layer, kind), arr in e.host_units.items():
            key = ("pfxh", e.digest, layer, kind)
            if key not in client:
                items.append((key, arr))
        if items:
            client.write_units(items)

    # ------------------------------------------------------------- adopt
    def adopt(self, digest: bytes, kv, session_id: str):
        """Map a registered prefix into a brand-new session: COW page
        refs + host-unit copies.  Returns the new ``KVSession`` (the
        caller emits ``entry.first_token`` instead of running prefill)."""
        with self._lock:
            e = self._entries[digest]
            if e.pages is None:
                self._revive(e)
            s = kv.new_session(session_id)
            s.num_tokens = e.num_tokens
            s.token_ids = list(e.token_ids)
            s.pages = [list(layer) for layer in e.pages]
            self.pool.share(e.page_ids(), kv.instance_id)
            for (layer, kind), arr in e.host_units.items():
                key = ("kvh", session_id, layer, kind)
                s.host_units[key] = arr.copy()
                s.host_shapes[key] = arr.shape
            self._attach(e, kv, s)
            e.adoptions += 1
            return s

    def reattach(self, kv, session_id: str,
                 coords: Sequence[Tuple[int, int]]) -> int:
        """Re-map registry pages into a woken sharer's Not-Present prefix
        slots (the wake-side analogue of adopt).  ``coords`` is the
        (layer, page_idx) set the caller verified is prefix-backed — COW-
        broken slots live in the swap tier and must never come back from
        here.  Returns bytes made resident."""
        s = kv.sessions[session_id]
        if s.prefix_digest is None:
            return 0
        with self._lock:
            e = self._entries.get(s.prefix_digest)
            if e is None:
                raise KeyError(("prefix", s.prefix_digest))
            if e.pages is None:
                self._revive(e)
            shared: List[int] = []
            for layer, pidx in coords:
                if s.pages[layer][pidx] is not None:
                    continue
                pid = e.pages[layer][pidx]
                s.pages[layer][pidx] = pid
                shared.append(pid)
            # adopted host units ride the normal swap tier (private
            # copies), but a spilled copy may be missing there on a
            # migrated husk — restore from the registry template
            for k, arr in s.host_units.items():
                tk = (k[2], k[3])
                if arr is None and tk in e.host_units:
                    s.host_units[k] = e.host_units[tk].copy()
                    shared.append(-1)    # marker: something was restored
            if shared:
                self.pool.share([p for p in shared if p >= 0],
                                kv.instance_id)
                s.prefix_resident = True
                e.resident_sharers.add((kv.instance_id, session_id))
            return sum(self.pool.page_bytes for p in shared if p >= 0)

    # ------------------------------------------------------------- sharers
    def attach_session(self, digest: bytes, instance_id: str,
                       session_id: str) -> bool:
        """Re-register a sharer for an already-installed entry (migration
        target: the shipped session logically maps the prefix and will
        reattach its pages by digest on first wake)."""
        with self._lock:
            e = self._entries.get(digest)
            if e is None:
                return False
            e.sharers.add((instance_id, session_id))
            return True

    def note_detach(self, digest: bytes, instance_id: str,
                    session_id: str) -> None:
        """A sharer deflated: its prefix slots went Not-Present (the
        session still *logically* maps the prefix and will reattach on
        wake)."""
        with self._lock:
            e = self._entries.get(digest)
            if e is not None:
                e.resident_sharers.discard((instance_id, session_id))

    def release_sharer(self, digest: bytes, instance_id: str,
                       session_id: str) -> None:
        """A sharer is gone for good (session trimmed / closed)."""
        with self._lock:
            e = self._entries.get(digest)
            if e is None:
                return
            e.sharers.discard((instance_id, session_id))
            e.resident_sharers.discard((instance_id, session_id))
            self._maybe_spill(e)

    def forget_owner(self, instance_id: str) -> None:
        """Instance evicted/migrated off: drop every sharer it held."""
        with self._lock:
            for e in list(self._entries.values()):
                e.sharers = {t for t in e.sharers if t[0] != instance_id}
                e.resident_sharers = {t for t in e.resident_sharers
                                      if t[0] != instance_id}
                self._maybe_spill(e)

    def _maybe_spill(self, e: PrefixEntry) -> None:
        """Last-sharer-down: with no live sharers the resident copy is
        pure overhead — drop to the CAS tier (or forget entirely when
        there is no store to revive from)."""
        if e.sharers:
            return
        if self.store is not None:
            self._spill(e)
        else:
            if e.pages is not None:
                self.pool.free(e.page_ids(), PREFIX_OWNER)
            self._entries.pop(e.digest, None)

    # ------------------------------------------------------------- spill
    def _spill(self, e: PrefixEntry) -> int:
        if e.pages is None or self.store is None:
            return 0
        pages = e.page_ids()
        self.pool.free(pages, PREFIX_OWNER)
        e.pages = None
        self.spills += 1
        return len(pages) * self.pool.page_bytes

    def spill(self, digest: bytes) -> int:
        """Governor reclaim: free the resident copy of a prefix no
        resident sharer maps (deflated sharers reattach-by-digest on
        wake).  Returns bytes freed."""
        with self._lock:
            e = self._entries.get(digest)
            if e is None or e.resident_sharers:
                return 0
            return self._spill(e)

    def spill_candidates(self) -> List[Tuple[int, bytes]]:
        """(freeable_bytes, digest) for resident entries with no resident
        sharer — what the governor may reclaim without touching any
        tenant's mapped memory."""
        with self._lock:
            return [(len(e.page_ids()) * self.pool.page_bytes, d)
                    for d, e in self._entries.items()
                    if e.pages is not None and not e.resident_sharers
                    and self.store is not None]

    def spilled_digests(self, arch_key: Optional[str] = None
                        ) -> List[bytes]:
        """Digests of spilled (non-resident, revivable) entries,
        optionally filtered to one deployment arch — what the forecast
        daemon revives ahead of a predicted burst."""
        with self._lock:
            return [d for d, e in self._entries.items()
                    if e.pages is None
                    and (arch_key is None or e.arch_key == arch_key)]

    def revive(self, digest: bytes) -> bool:
        """Rebuild a spilled entry's resident pages from the CAS store by
        digest (pre-inflate path: the next ``adopt``/``reattach`` finds
        the pages already resident instead of paying the revive on the
        serve path).  Returns True if a revive happened."""
        with self._lock:
            e = self._entries.get(digest)
            if e is None or e.pages is not None or self.store is None:
                return False
            self._revive(e)
            return True

    def _revive(self, e: PrefixEntry) -> None:
        """Rebuild the resident copy from the CAS store by digest — the
        whole point of write-through: no prefill, one vectored read."""
        if self.store is None:
            raise KeyError(("prefix", e.digest, "spilled without a store"))
        client = self.store.client(PREFIX_OWNER)
        keys = sorted((k for k in client.extents
                       if k[0] == "pfx" and k[1] == e.digest),
                      key=lambda k: (k[2], k[3]))
        data = client.read_units(keys)
        pages: List[List[int]] = [[] for _ in range(e.n_layers)]
        pids, rows = [], []
        for k in keys:
            pid = self.pool.alloc(1, PREFIX_OWNER)[0]
            pages[k[2]].append(pid)
            pids.append(pid)
            rows.append(np.asarray(data[k]).reshape(-1))
        if pids:
            self.pool.scatter(pids, np.stack(rows))
        e.pages = pages
        self.revives += 1

    # ------------------------------------------------------------- cluster
    def entry_bytes(self, e: PrefixEntry) -> int:
        """Logical bytes sharing this prefix saves a would-be prefiller."""
        host = sum(a.nbytes for a in e.host_units.values())
        return e.n_pages * self.pool.page_bytes + host

    def inventory(self) -> Dict[bytes, int]:
        """digest -> shareable bytes: what this node advertises to the
        router's prefix-affinity placement term.  Spilled entries count —
        revive-by-digest still beats recomputing prefill."""
        with self._lock:
            return {d: self.entry_bytes(e) for d, e in self._entries.items()}

    def digests(self) -> frozenset:
        with self._lock:
            return frozenset(self._entries)

    def digests_for_instance(self, instance_id: str) -> List[bytes]:
        with self._lock:
            return [d for d, e in self._entries.items()
                    if any(t[0] == instance_id for t in e.sharers)]

    def resident_bytes(self) -> int:
        """Physical bytes the registry itself pins (PSS share of its
        refcounted pages) — charged once to the node, never per-sharer."""
        return int(self.pool.pss_bytes(PREFIX_OWNER))

    # ------------------------------------------------------------- wire
    def export_records(self, instance_id: str):
        """Migration source: (records, store_metas) for every prefix the
        instance shares.  Records are wire-safe dicts of pure metadata;
        the page payloads travel as CAS segments like everything else
        (dedup-aware: a digest the target already holds ships nothing)."""
        records, metas = [], {}
        with self._lock:
            for d in self.digests_for_instance(instance_id):
                e = self._entries[d]
                records.append({
                    "digest": e.digest, "arch": e.arch_key,
                    "token_ids": tuple(e.token_ids),
                    "num_tokens": e.num_tokens,
                    "first_token": e.first_token,
                    "n_layers": e.n_layers,
                    "n_pages": e.n_pages,
                })
        if self.store is not None and records:
            client = self.store.client(PREFIX_OWNER)
            wanted = {r["digest"] for r in records}
            metas = {k: m for k, m in self.store.export_meta(client).items()
                     if k[1] in wanted}
        return records, metas

    def install_records(self, records) -> int:
        """Migration target: install shipped prefix entries as spilled
        (pages revive lazily by digest from the just-adopted CAS
        extents).  Entries already known locally are kept as-is —
        that is the cross-node win: nothing re-transfers, nothing
        re-prefills.  Returns entries newly installed."""
        n = 0
        with self._lock:
            for r in records:
                if r["digest"] in self._entries:
                    continue
                host: Dict[Tuple, np.ndarray] = {}
                if self.store is not None:
                    client = self.store.client(PREFIX_OWNER)
                    hkeys = [k for k in client.extents
                             if k[0] == "pfxh" and k[1] == r["digest"]]
                    for k, arr in client.read_units(hkeys).items():
                        host[(k[2], k[3])] = arr
                self._entries[r["digest"]] = PrefixEntry(
                    digest=r["digest"], arch_key=r["arch"],
                    token_ids=tuple(r["token_ids"]),
                    num_tokens=int(r["num_tokens"]),
                    first_token=int(r["first_token"]),
                    n_layers=int(r["n_layers"]),
                    n_pages=int(r["n_pages"]),
                    pages=None, host_units=host)
                n += 1
        return n

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._entries),
                "resident_entries": sum(1 for e in self._entries.values()
                                        if e.pages is not None),
                "hits": self.hits, "misses": self.misses,
                "registrations": self.registrations,
                "adoptions": sum(e.adoptions
                                 for e in self._entries.values()),
                "spills": self.spills, "revives": self.revives,
                "resident_bytes": self.resident_bytes(),
            }
